"""Batched serving through the DS control plane (``distributed-serve``).

Request batches are queue jobs; each worker runs the continuous-batching
engine over its batch and uploads completions — Distributed-OmeZarrCreator's
"convert a dataset per job" pattern transplanted to inference.

    PYTHONPATH=src python examples/serve_batch.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.launch.serve  # noqa: F401
import repro.launch.train  # noqa: F401
from repro.core import DSConfig, DSRuntime, FleetFile, JobFile, ThreadRunner


def main() -> int:
    workdir = tempfile.mkdtemp(prefix="ds-serve-")
    cfg = DSConfig(
        app_name="ServeBatch",
        payload="distributed-serve",
        cluster_machines=2,
        machine_type=["sim.large"],
        machine_price=1.0,
        sqs_message_visibility=300.0,
        check_if_done=True,
    )
    rt = DSRuntime(cfg, store_root=os.path.join(workdir, "store"))
    rt.setup()

    # batch2 shares an 8-token system prefix across its requests: with the
    # paged prefix cache the shared pages are prefilled once and stitched
    # into later requests' page tables (prompt_tokens_skipped > 0)
    sys_prompt = [101, 102, 103, 104, 105, 106, 107, 108]
    batches = [
        {"prompts": [[1, 2, 3], [4, 5, 6, 7], [11]], "output_prefix": "serve/batch0"},
        {"prompts": [[8, 9], [10, 11, 12]], "output_prefix": "serve/batch1"},
        {"prompts": [sys_prompt + [31], sys_prompt + [32], sys_prompt + [33]],
         "output_prefix": "serve/batch2"},
    ]
    rt.submit_job(
        JobFile(
            shared={
                "arch": "ds-paper-100m",
                "arch_overrides": "reduced",
                "max_new_tokens": 6,
                "max_len": 64,
                "max_batch": 2,
                # serving perf knobs (docs/serving.md): chunked prefill
                # ingests whole prompt slices per dispatch; fused mode
                # issues ONE decode dispatch per tick for any position mix
                "prefill_chunk": 8,
                "dispatch_mode": "fused",
                # paged KV cache: memory scales with resident tokens, not
                # max_batch * max_len; RESULTS.json gains peak_cache_bytes.
                # total_pages is omitted, so each worker sizes its pool
                # adaptively from the queue depth at submit (logged); the
                # prefix cache (on by default) shares the system-prompt
                # pages across batch2's requests instead of re-prefilling
                "cache_mode": "paged",
                "page_size": 8,
            },
            groups=batches,
        )
    )
    rt.start_cluster(FleetFile(startup_seconds=0.1))
    summary = ThreadRunner(rt).run()
    print(f"served {summary.jobs_done} batches in {summary.wall_time:.1f}s")

    for i in range(len(batches)):
        res = rt.store.get_json(f"serve/batch{i}/RESULTS.json")
        for uid, r in sorted(res["requests"].items()):
            print(f"batch{i} {uid}: prompt={r['prompt']} -> completion={r['completion']}")
        # same denominator as benchmarks/bench_serving.py: every token that
        # crossed the device (emitted + ingested) counts
        toks = max(1, res["tokens_emitted"] + res["prompt_tokens_ingested"])
        print(
            f"batch{i} dispatches: decode={res['decode_dispatches']} "
            f"prefill={res['prefill_dispatches']} "
            f"dispatches/token={res['dispatches'] / toks:.2f} "
            f"prompt_tokens_ingested={res['prompt_tokens_ingested']} "
            f"prompt_tokens_skipped={res['prompt_tokens_skipped']} "
            f"peak_cache={res['peak_cache_bytes']}B "
            f"(dense would reserve {res['dense_cache_bytes']}B, "
            f"pool={res['total_pages']} pages)"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

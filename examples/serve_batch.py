"""Batched serving through the DS control plane (``distributed-serve``).

Default mode: request batches are queue jobs; each worker runs the
continuous-batching engine over its batch and uploads completions —
Distributed-OmeZarrCreator's "convert a dataset per job" pattern
transplanted to inference.

    PYTHONPATH=src python examples/serve_batch.py

``--staggered``: the queue-fed serving tier.  One job is a *serving
lease*; individual requests are messages on a second DurableQueue, and
a submitter thread trickles them in over time while the engine is
already generating.  Freed rows are refilled mid-flight (continuous
batching) — watch the queue-wait/TTFT tick percentiles in the printed
summary; a drain-then-refill loop would stack arrivals behind the whole
batch (benchmarks/bench_serving.py quantifies the gap).

    PYTHONPATH=src python examples/serve_batch.py --staggered
"""

import argparse
import os
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.launch.serve  # noqa: F401
import repro.launch.train  # noqa: F401
from repro.core import DSConfig, DSRuntime, FleetFile, JobFile, ThreadRunner
from repro.core.queue import DurableQueue

SHARED = {
    "arch": "ds-paper-100m",
    "arch_overrides": "reduced",
    "max_new_tokens": 6,
    "max_len": 64,
    "max_batch": 2,
    # serving perf knobs (docs/serving.md): chunked prefill ingests whole
    # prompt slices per dispatch; fused mode issues ONE decode dispatch
    # per tick for any position mix
    "prefill_chunk": 8,
    "dispatch_mode": "fused",
    # paged KV cache: memory scales with resident tokens, not
    # max_batch * max_len; RESULTS.json gains peak_cache_bytes.
    # total_pages is omitted, so each worker sizes its pool adaptively
    # from the queue depth at submit (logged); the prefix cache (on by
    # default) shares system-prompt pages across requests instead of
    # re-prefilling
    "cache_mode": "paged",
    "page_size": 8,
}
SYS_PROMPT = [101, 102, 103, 104, 105, 106, 107, 108]


def _runtime(workdir):
    cfg = DSConfig(
        app_name="ServeBatch",
        payload="distributed-serve",
        cluster_machines=2,
        machine_type=["sim.large"],
        machine_price=1.0,
        sqs_message_visibility=300.0,
        check_if_done=True,
    )
    rt = DSRuntime(cfg, store_root=os.path.join(workdir, "store"))
    rt.setup()
    return rt


def main_batched() -> int:
    rt = _runtime(tempfile.mkdtemp(prefix="ds-serve-"))
    # batch2 shares an 8-token system prefix across its requests: with the
    # paged prefix cache the shared pages are prefilled once and stitched
    # into later requests' page tables (prompt_tokens_skipped > 0)
    batches = [
        {"prompts": [[1, 2, 3], [4, 5, 6, 7], [11]], "output_prefix": "serve/batch0"},
        {"prompts": [[8, 9], [10, 11, 12]], "output_prefix": "serve/batch1"},
        {"prompts": [SYS_PROMPT + [31], SYS_PROMPT + [32], SYS_PROMPT + [33]],
         "output_prefix": "serve/batch2"},
    ]
    rt.submit_job(JobFile(shared=dict(SHARED), groups=batches))
    rt.start_cluster(FleetFile(startup_seconds=0.1))
    summary = ThreadRunner(rt).run()
    print(f"served {summary.jobs_done} batches in {summary.wall_time:.1f}s")

    for i in range(len(batches)):
        res = rt.store.get_json(f"serve/batch{i}/RESULTS.json")
        for uid, r in sorted(res["requests"].items()):
            print(f"batch{i} {uid}: prompt={r['prompt']} -> completion={r['completion']}")
        # same denominator as benchmarks/bench_serving.py: every token that
        # crossed the device (emitted + ingested) counts
        toks = max(1, res["tokens_emitted"] + res["prompt_tokens_ingested"])
        print(
            f"batch{i} dispatches: decode={res['decode_dispatches']} "
            f"prefill={res['prefill_dispatches']} "
            f"dispatches/token={res['dispatches'] / toks:.2f} "
            f"prompt_tokens_ingested={res['prompt_tokens_ingested']} "
            f"prompt_tokens_skipped={res['prompt_tokens_skipped']} "
            f"peak_cache={res['peak_cache_bytes']}B "
            f"(dense would reserve {res['dense_cache_bytes']}B, "
            f"pool={res['total_pages']} pages)"
        )
    return 0


def main_staggered() -> int:
    workdir = tempfile.mkdtemp(prefix="ds-serve-stream-")
    rt = _runtime(workdir)
    rq_path = os.path.join(workdir, "requests.sqlite")
    rq = DurableQueue(rq_path)

    # three arrival waves, ~0.2s apart: wave 1 saturates the two slots,
    # waves 2-3 land while the engine is mid-generation and are admitted
    # into rows as they free up — never waiting for a full batch drain
    waves = [
        [{"uid": f"w0r{i}", "prompt": SYS_PROMPT + [30 + i]} for i in range(3)],
        [{"uid": f"w1r{i}", "prompt": SYS_PROMPT + [40 + i]} for i in range(3)],
        [{"uid": f"w2r{i}", "prompt": [50 + i, 51 + i]} for i in range(2)],
    ]
    n_total = sum(len(w) for w in waves)

    def submitter():
        for wave in waves:
            rq.send_batch(wave)
            time.sleep(0.2)

    rt.submit_job(JobFile(
        shared=dict(SHARED),
        groups=[{
            "request_queue": rq_path,
            "expected_requests": n_total,
            # generous idle budget: the lease must outlive arrival gaps
            "stream_idle_polls": 200,
            "stream_poll_seconds": 0.02,
            "output_prefix": "serve/stream0",
        }],
    ))
    t = threading.Thread(target=submitter, daemon=True)
    t.start()
    rt.start_cluster(FleetFile(startup_seconds=0.1))
    summary = ThreadRunner(rt).run()
    t.join()
    print(f"stream lease finished in {summary.wall_time:.1f}s "
          f"({summary.jobs_done} lease job)")

    res = rt.store.get_json("serve/stream0/RESULTS.json")
    for uid, r in sorted(res["requests"].items()):
        print(f"stream {uid}: prompt={r['prompt']} -> completion={r['completion']}")
    tm = res["timing"]
    print(
        f"continuous batching: {res['admissions']} admissions over "
        f"{res['ticks']} ticks on {SHARED['max_batch']} slots "
        f"(prompt_tokens_skipped={res['prompt_tokens_skipped']} via the "
        f"shared system prefix)"
    )
    print(
        f"queue_wait ticks: mean={tm['queue_wait_ticks']['mean']} "
        f"p90={tm['queue_wait_ticks']['p90']}  |  ttft ticks: "
        f"mean={tm['ttft_ticks']['mean']} p90={tm['ttft_ticks']['p90']}"
    )
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--staggered", action="store_true",
                    help="queue-fed serving lease with staggered arrivals")
    args = ap.parse_args()
    return main_staggered() if args.staggered else main_batched()


if __name__ == "__main__":
    raise SystemExit(main())

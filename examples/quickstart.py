"""Quickstart: the paper's four commands driving a real training run.

Trains the ~100M-parameter LM (``ds-paper-100m``) for a configurable
number of steps as checkpoint-delimited step-span jobs, distributed over
a simulated spot fleet of local workers — the complete end-to-end driver
(data pipeline -> train steps -> checkpoints -> monitor teardown).

    PYTHONPATH=src python examples/quickstart.py --steps 20 --span 5 --workers 2
    PYTHONPATH=src python examples/quickstart.py --steps 300 --span 50 --full-size

Defaults run a reduced-width model so the demo completes in ~a minute on
CPU; ``--full-size`` uses the real 12L/768d config (slow on CPU, sized
for a v5e-8 worker).
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.launch.train  # noqa: F401  registers distributed-train
from repro.core import DSConfig, DSRuntime, FleetFile, ThreadRunner, step_span_job_file
from repro.train.checkpoint import latest_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--span", type=int, default=5)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    workdir = args.workdir or tempfile.mkdtemp(prefix="ds-quickstart-")
    print(f"workdir: {workdir}")

    # Step 1: Configuration  (paper: edit config.py, `python run.py setup`)
    cfg = DSConfig(
        app_name="Quickstart",
        payload="distributed-train",
        cluster_machines=args.workers,
        tasks_per_machine=1,
        machine_type=["sim.xlarge"],
        machine_price=2.0,
        sqs_message_visibility=600.0,
        check_if_done=True,
        expected_number_files=1,
    )
    rt = DSRuntime(cfg, store_root=os.path.join(workdir, "store"))
    rt.setup()

    # Step 2: Submit jobs  (`python run.py submitJob files/job.json`)
    job_file = step_span_job_file(
        arch="ds-paper-100m",
        total_steps=args.steps,
        span=args.span,
        run="quickstart",
        shared={
            "arch_overrides": None if args.full_size else "reduced",
            "seq_len": args.seq_len,
            "global_batch": args.batch,
            "lr": 3e-4,
            "warmup_steps": max(2, args.steps // 10),
            "total_steps": args.steps,
            "ckpt_every": args.span,
        },
    )
    n = rt.submit_job(job_file)
    print(f"submitted {n} step-span jobs")

    # Step 3: Start cluster  (`python run.py startCluster files/fleet.json`)
    request_id = rt.start_cluster(FleetFile(startup_seconds=0.1))
    print(f"spot fleet: {request_id}")

    # Step 4: Monitor  (`python run.py monitor ...`) — ThreadRunner runs the
    # workers and the monitor loop until the queue drains, then tears down.
    summary = ThreadRunner(rt).run()
    print(
        f"done: jobs={summary.jobs_done} skipped={summary.jobs_skipped} "
        f"failed(retried)={summary.jobs_failed} wall={summary.wall_time:.1f}s"
    )

    step = latest_step(rt.store, "quickstart")
    print(f"final checkpoint step: {step}")
    for span_start in range(0, args.steps, args.span):
        key = (
            f"runs/quickstart/spans/{span_start:06d}-"
            f"{min(span_start + args.span, args.steps):06d}/DONE.json"
        )
        if rt.store.exists(key):
            d = rt.store.get_json(key)
            print(f"  span {d['span']}: final_loss={d['final_loss']:.4f}")
    assert step == args.steps, "training did not reach the final step"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Hyper-parameter sweep on a preemptible fleet — the paper's core use
case ("many small machines" processing independent groups), plus the
fault-tolerance story: instances are spot-preempted mid-run and the
queue's visibility timeout re-delivers their jobs to survivors.

Each job group is an independent learning-rate run of the reduced 100M
model; the deterministic market seed makes the preemption schedule
reproducible.

Workers here claim jobs in batches (``SimRunner(prefetch=2)`` drives
``DurableQueue.receive_batch`` under one lock/transaction instead of a
round-trip per job); a job buffered on a preempted instance simply
resurfaces after its visibility timeout — same at-least-once story as a
crash — and the monitor's teardown sweep batch-acks any straggler that
reappears between the drain check and queue purge.

    PYTHONPATH=src python examples/sweep_with_preemption.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.launch.train  # noqa: F401
from repro.core import (
    DSConfig,
    DSRuntime,
    FleetFile,
    JobFile,
    SimRunner,
    VirtualClock,
)

LRS = [1e-4, 3e-4, 1e-3, 3e-3]


def main() -> int:
    workdir = tempfile.mkdtemp(prefix="ds-sweep-")
    clk = VirtualClock()
    cfg = DSConfig(
        app_name="LRSweep",
        payload="distributed-train",
        cluster_machines=3,
        machine_type=["sim.large"],
        machine_price=1.0,
        sqs_message_visibility=300.0,
        max_receive_count=6,
        check_if_done=True,
    )
    rt = DSRuntime(cfg, store_root=os.path.join(workdir, "store"), clock=clk)
    rt.setup()

    jf = JobFile(
        shared={
            "arch": "ds-paper-100m",
            "arch_overrides": "reduced",
            "start_step": 0,
            "num_steps": 8,
            "total_steps": 8,
            "seq_len": 64,
            "global_batch": 2,
        },
        groups=[
            {"lr": lr, "run": f"lr{lr:g}", "output_prefix": f"sweep/lr{lr:g}"}
            for lr in LRS
        ],
    )
    rt.submit_job(jf)

    # aggressive preemption: ~3 kills/instance/hour, deterministic seed
    rt.start_cluster(FleetFile(startup_seconds=0.0, preemption_rate_per_hour=3.0, market_seed=13))
    # prefetch=2: one receive_batch transaction claims two jobs; both are
    # processed within the 300s visibility lease at 120s ticks
    summary = SimRunner(rt, tick_seconds=120.0, prefetch=2).run(max_ticks=500)
    print(
        f"sweep complete: done={summary.jobs_done} preemptions={summary.preemptions} "
        f"virtual_time={summary.wall_time / 60:.0f}min"
    )

    print(f"{'lr':>8s} {'final loss':>12s}")
    best = None
    for lr in LRS:
        d = rt.store.get_json(f"sweep/lr{lr:g}/DONE.json")
        print(f"{lr:8g} {d['final_loss']:12.4f}")
        if best is None or d["final_loss"] < best[1]:
            best = (lr, d["final_loss"])
    print(f"best lr: {best[0]:g} (loss {best[1]:.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

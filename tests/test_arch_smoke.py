"""Per-architecture smoke tests: reduced same-family configs, one forward +
train-grad step + decode step on CPU; asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_applicable, get_arch, list_archs, reduced
from repro.models import Model, ModelRuntime

ARCHS = [a for a in list_archs() if a != "ds-paper-100m"]
BATCH, SEQ = 2, 32


def _make_batch(cfg, rng):
    tokens = jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(rng, (BATCH, cfg.encoder_seq, cfg.d_model))
    if cfg.n_vision_tokens:
        batch["patches"] = jax.random.normal(rng, (BATCH, cfg.n_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(moe_strategy="dense"))
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _make_batch(cfg, rng)

    logits = model.forward(
        params, batch["tokens"], frames=batch.get("frames"), patches=batch.get("patches")
    )
    total = SEQ + (cfg.n_vision_tokens if cfg.n_vision_tokens else 0)
    assert logits.shape == (BATCH, total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), "non-finite logits"

    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), "non-finite loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(moe_strategy="dense"))
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    batch = _make_batch(cfg, rng)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), "non-finite grads"
    # at least the embedding must receive gradient signal
    assert float(jnp.abs(grads["embed"]).sum()) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode must match the parallel causal forward."""
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(moe_strategy="dense"))
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    batch = _make_batch(cfg, rng)
    tokens = batch["tokens"]

    ref = model.forward(
        params, tokens, frames=batch.get("frames"), patches=batch.get("patches")
    )
    if cfg.n_vision_tokens:
        pytest.skip("decode parity for VLM covered via text-only path below")

    cache = model.init_cache(BATCH, SEQ)
    if cfg.is_encoder_decoder:
        # prefill the cross-attention cache from the encoder output
        from repro.models.layers import qkv_project

        enc = model._encode(params, batch["frames"])
        ck, cv = [], []
        n = cfg.n_layers
        for i in range(n):
            cp = jax.tree.map(lambda a: a[i], params["cross"])
            _, k, v = qkv_project(cp["attn"], enc, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
            ck.append(k)
            cv.append(v)
        cache["cross_k"] = jnp.stack(ck)
        cache["cross_v"] = jnp.stack(cv)

    step = jax.jit(model.decode_step)
    outs = []
    for t in range(SEQ):
        logits, cache = step(params, cache, tokens[:, t : t + 1], jnp.full((BATCH,), t, jnp.int32))
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_applicability_matrix():
    """The 40-cell matrix: every cell either applicable or has a reason."""
    rows = 0
    for arch in ARCHS + ["ds-paper-100m"]:
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            assert ok or reason
            rows += 1
    assert rows == 77  # 11 archs x 7 shapes (4 original + 3 serving cells)

    assert cell_applicable(get_arch("mamba2-1.3b"), SHAPES["long_500k"])[0]
    assert cell_applicable(get_arch("zamba2-1.2b"), SHAPES["long_500k"])[0]
    assert cell_applicable(get_arch("mixtral-8x7b"), SHAPES["long_500k"])[0]
    assert not cell_applicable(get_arch("nemotron-4-340b"), SHAPES["long_500k"])[0]
    assert not cell_applicable(get_arch("qwen2-72b"), SHAPES["long_500k"])[0]

    # fused serve_prefill gates: MoE / side-input / rolling-window archs
    # fall back to decode-path ingestion (still 1 dispatch per tick)
    assert cell_applicable(get_arch("qwen2-72b"), SHAPES["serve_prefill_32k"])[0]
    assert cell_applicable(get_arch("mamba2-1.3b"), SHAPES["serve_prefill_32k"])[0]
    assert not cell_applicable(get_arch("mixtral-8x7b"), SHAPES["serve_prefill_32k"])[0]
    assert not cell_applicable(get_arch("whisper-tiny"), SHAPES["serve_prefill_32k"])[0]
    for arch in ARCHS + ["ds-paper-100m"]:
        assert cell_applicable(get_arch(arch), SHAPES["serve_ragged_32k"])[0]

    # serve_paged gates: only archs with a pageable KV cache (no O(1)
    # recurrent state, no enc-dec cross cache, no rolling window)
    assert cell_applicable(get_arch("qwen2-72b"), SHAPES["serve_paged_32k"])[0]
    assert cell_applicable(get_arch("deepseek-v2-236b"), SHAPES["serve_paged_32k"])[0]
    assert not cell_applicable(get_arch("mamba2-1.3b"), SHAPES["serve_paged_32k"])[0]
    assert not cell_applicable(get_arch("zamba2-1.2b"), SHAPES["serve_paged_32k"])[0]
    assert not cell_applicable(get_arch("whisper-tiny"), SHAPES["serve_paged_32k"])[0]
    assert not cell_applicable(get_arch("mixtral-8x7b"), SHAPES["serve_paged_32k"])[0]


def test_param_counts_match_published():
    expected = {
        "nemotron-4-340b": 341e9,
        "granite-34b": 34e9,
        "qwen2-72b": 72.7e9,
        "h2o-danube-3-4b": 4.0e9,
        "mixtral-8x7b": 46.7e9,
        "deepseek-v2-236b": 236e9,
        "mamba2-1.3b": 1.35e9,
        "zamba2-1.2b": 1.2e9,
        "whisper-tiny": 39e6,
    }
    for arch, n in expected.items():
        got = get_arch(arch).param_count()
        assert abs(got - n) / n < 0.06, f"{arch}: {got/1e9:.2f}B vs {n/1e9:.2f}B"

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,s,hd,block",
    [
        (2, 128, 64, 64),
        (1, 256, 128, 128),
        (3, 512, 64, 256),
        (2, 128, 192, 64),  # nemotron head_dim
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(bh, s, hd, block, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, s, hd), dtype)
    k = jax.random.normal(ks[1], (bh, s, hd), dtype)
    v = jax.random.normal(ks[2], (bh, s, hd), dtype)
    from repro.kernels.flash_attention import flash_attention_bhsd

    out = flash_attention_bhsd(
        q, k, v, causal=causal, block_q=block, block_k=block, interpret=True
    )
    want = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    s, hd = 256, 64
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (2, s, hd), jnp.float32) for kk in ks)
    from repro.kernels.flash_attention import flash_attention_bhsd

    out = flash_attention_bhsd(
        q, k, v, causal=True, window=window, block_q=64, block_k=64, interpret=True
    )
    want = ref.attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_model_layout():
    b, s, h, hd = 2, 128, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    from repro.models.layers import attention_scores

    want = attention_scores(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)


# ------------------------------------------------- paged flash decode/extend
def _paged_setup(B, Hkv, dk, ps, P, n_pages, offsets, dtype=jnp.float32, seed=0):
    """Random page pools + a permuted page table backing each row's
    positions [0, offsets[b] + T) — the allocator invariant the serving
    engine maintains."""
    rng = np.random.default_rng(seed)
    k_pages = jnp.asarray(rng.standard_normal((n_pages, ps, Hkv, dk)), dtype)
    v_pages = jnp.asarray(rng.standard_normal((n_pages, ps, Hkv, dk)), dtype)
    return k_pages, v_pages


def _alloc_table(B, P, n_pages, frontiers, ps, seed=1):
    """Disjoint physical pages per row covering each row's frontier;
    everything else holds the out-of-bounds sentinel (unallocated)."""
    rng = np.random.default_rng(seed)
    perm = list(rng.permutation(n_pages))
    table = np.full((B, P), n_pages, np.int32)
    for b, frontier in enumerate(frontiers):
        for j in range(-(-frontier // ps)):
            table[b, j] = perm.pop()
    return jnp.asarray(table)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T", [1, 4])  # flash-decode and chunk-extend
@pytest.mark.parametrize("hkv,g", [(2, 4), (1, 8)])  # GQA and MLA-style Hkv=1
def test_paged_attention_vs_reference(T, hkv, g, dtype):
    B, dk, ps, P = 3, 32, 8, 4
    n_pages = 10
    h = hkv * g
    offsets = np.asarray([5, 0, 9], np.int32)  # ragged rows
    k_pages, v_pages = _paged_setup(B, hkv, dk, ps, P, n_pages, offsets, dtype)
    table = _alloc_table(B, P, n_pages, offsets + T, ps)
    q = jnp.asarray(
        np.random.default_rng(2).standard_normal((B, T, h, dk)), dtype
    )
    out = ops.paged_attention(
        q, k_pages, v_pages, table, jnp.asarray(offsets), interpret=True
    )
    want = ref.paged_attention_reference(q, k_pages, v_pages, table, jnp.asarray(offsets))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("k", [1, 3])  # draft window sizes
def test_paged_verify_vs_reference(k):
    """The speculative-verify entry point must match its registered
    oracle on its own contract — k+1 candidate queries against the
    accepted history — not merely delegate to whatever paged_attention
    happens to do (a rewrite of the delegation must keep this green)."""
    B, dk, ps, P = 3, 32, 8, 4
    n_pages = 12
    hkv, g = 2, 4
    T = k + 1
    offsets = np.asarray([5, 0, 9], np.int32)
    k_pages, v_pages = _paged_setup(B, hkv, dk, ps, P, n_pages, offsets)
    table = _alloc_table(B, P, n_pages, offsets + T, ps)
    q = jnp.asarray(
        np.random.default_rng(7).standard_normal((B, T, hkv * g, dk)),
        jnp.float32,
    )
    out = ops.paged_verify(
        q, k_pages, v_pages, table, jnp.asarray(offsets), interpret=True
    )
    oracle = ref.ORACLES["paged_verify"]
    want = oracle(q, k_pages, v_pages, table, jnp.asarray(offsets))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5
    )


def test_oracle_registry_is_total():
    """Every public kernels.ops entry point has a registered oracle (the
    same invariant dslint R6 enforces statically)."""
    import inspect

    public = {
        name for name, fn in vars(ops).items()
        if inspect.isfunction(fn) and fn.__module__ == ops.__name__
        and not name.startswith("_")
    }
    assert public == set(ref.ORACLES), (
        f"ORACLES registry drift: ops has {sorted(public)}, "
        f"registry has {sorted(ref.ORACLES)}"
    )


def test_paged_attention_matches_contiguous_reference():
    """Pages laid out contiguously == plain causal attention over the
    logical sequence: the kernel's page indirection is position-exact."""
    B, T, h, dk, ps = 2, 8, 4, 16, 4
    seq = 16  # rows fully resident: positions 0..seq-1 already written
    P = seq // ps
    n_pages = B * P
    rng = np.random.default_rng(3)
    # identity layout: row b's logical page j is physical page b*P+j
    table = jnp.asarray(
        np.arange(B * P, dtype=np.int32).reshape(B, P)
    )
    kv = rng.standard_normal((B, seq, h, dk)).astype(np.float32)
    vv = rng.standard_normal((B, seq, h, dk)).astype(np.float32)
    k_pages = jnp.asarray(kv.reshape(B * P, ps, h, dk))
    v_pages = jnp.asarray(vv.reshape(B * P, ps, h, dk))
    q = jnp.asarray(rng.standard_normal((B, T, h, dk)), jnp.float32)
    offsets = jnp.full((B,), seq - T, jnp.int32)  # chunk = the last T tokens
    out = ops.paged_attention(q, k_pages, v_pages, table, offsets, interpret=True)
    # oracle: causal attention of the full sequence, last T rows
    full_q = jnp.asarray(rng.standard_normal((B, seq, h, dk)), jnp.float32)
    full_q = full_q.at[:, seq - T :].set(q)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * h, -1, dk)  # noqa: E731
    want = ref.attention_reference(fold(full_q), fold(jnp.asarray(kv)), fold(jnp.asarray(vv)), causal=True)
    want = want.reshape(B, h, seq, dk).transpose(0, 2, 1, 3)[:, seq - T :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_paged_attention_parked_rows_are_finite_zero():
    """Rows whose pages were all freed (OOB sentinel everywhere) must
    produce zeros, not NaNs — the engine discards them but NaNs would
    poison the dispatch."""
    B, T, h, dk, ps, P, n_pages = 2, 1, 4, 16, 8, 4, 6
    rng = np.random.default_rng(4)
    k_pages = jnp.asarray(rng.standard_normal((n_pages, ps, h, dk)), jnp.float32)
    table = np.full((B, P), n_pages, np.int32)
    table[0, 0] = 2  # row 0 live, row 1 parked
    q = jnp.asarray(rng.standard_normal((B, T, h, dk)), jnp.float32)
    out = ops.paged_attention(
        q, k_pages, k_pages, jnp.asarray(table), jnp.asarray([3, 3], jnp.int32),
        interpret=True,
    )
    assert np.isfinite(np.asarray(out)).all()
    assert np.all(np.asarray(out[1]) == 0.0)
    assert np.any(np.asarray(out[0]) != 0.0)


# ----------------------------------------------------------------- SSD kernel
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,p,n,chunk,block_h",
    [
        (2, 64, 4, 16, 16, 16, 2),
        (1, 128, 8, 32, 32, 32, 4),
        (2, 256, 16, 64, 128, 128, 8),  # mamba2-1.3b tile shape
    ],
)
def test_ssd_kernel_sweep(b, l, h, p, n, chunk, block_h, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    B = jax.random.normal(ks[3], (b, l, n), dtype)
    C = jax.random.normal(ks[4], (b, l, n), dtype)

    y, final = ops.ssd(x, dt, A, B, C, chunk=chunk, block_h=block_h, interpret=True)
    y_ref, final_ref = ref.ssd_reference(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(final), np.asarray(final_ref), rtol=1e-3, atol=1e-3
    )


def test_ssd_kernel_matches_sequential_recurrence():
    """Chunk kernel + glue == naive per-token recurrence."""
    b, l, h, p, n, chunk = 1, 32, 2, 8, 8, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n))
    C = jax.random.normal(ks[4], (b, l, n))

    y, final = ops.ssd(x, dt, A, B, C, chunk=chunk, interpret=True)

    from repro.models.ssm import ssd_decode_step

    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        yt, state = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 256), (2, 8, 512), (3, 5, 128)])
def test_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32) + 1.0
    out = ops.rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_reference(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


def test_model_attention_kernel_path():
    """The model's attn_impl='kernel' path equals the direct path."""
    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelRuntime

    cfg = reduced(get_arch("ds-paper-100m"))
    rng = jax.random.PRNGKey(3)
    m_direct = Model(cfg, ModelRuntime(attn_impl="direct"))
    m_kernel = Model(cfg, ModelRuntime(attn_impl="kernel", attn_chunk=16))
    params = m_direct.init(rng)
    toks = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
    a = m_direct.forward(params, toks)
    b = m_kernel.forward(params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_model_ssd_kernel_path():
    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelRuntime

    cfg = reduced(get_arch("mamba2-1.3b"))
    rng = jax.random.PRNGKey(4)
    m_ref = Model(cfg, ModelRuntime(use_ssd_kernel=False))
    m_k = Model(cfg, ModelRuntime(use_ssd_kernel=True))
    params = m_ref.init(rng)
    toks = jax.random.randint(rng, (2, 32), 0, cfg.vocab_size)
    a = m_ref.forward(params, toks)
    b = m_k.forward(params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

"""Shared-prefix radix cache over the paged KV pool: stitching, CoW,
refcount invariants, LRU eviction, preemption recovery, adaptive pool
sizing, and kernel parity with aliased page tables."""

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_cache import PrefixCache


def _setup(arch="ds-paper-100m", seed=0, **rt_kwargs):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(**rt_kwargs))
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


PREFIX = [11, 12, 13, 14, 15, 16, 17, 18, 21, 22, 23, 24, 25, 26, 27, 28]


def _shared_requests(max_new=4, temperature=0.0):
    """Three prompts over one 16-token (2 pages at ps=8) system prefix:
    two divergent tails plus one *identical* full-prefix prompt (the
    full-hit forces the hold-back token's copy-on-write)."""
    return [
        Request(uid="a", prompt=PREFIX + [50, 51], max_new_tokens=max_new,
                temperature=temperature),
        Request(uid="b", prompt=PREFIX + [60, 61, 62], max_new_tokens=max_new,
                temperature=temperature),
        Request(uid="c", prompt=list(PREFIX), max_new_tokens=max_new,
                temperature=temperature),
    ]


def _run(engine, reqs):
    engine.submit(reqs)
    engine.run_to_completion()
    return {r.uid: r.output for r in engine.finished}


# ----------------------------------------------------------- radix unit
def test_radix_match_insert_evict():
    pc = PrefixCache(page_size=4)
    toks = list(range(1, 13))  # 3 full chunks
    assert pc.match(toks) == []
    adopted = pc.insert(toks, [7, 8, 9])
    assert adopted == [7, 8, 9] and pc.n_nodes == 3
    # re-insert with different pages: first writer wins, nothing adopted
    assert pc.insert(toks, [1, 2, 3]) == []
    path = pc.match(toks + [99])  # partial tail ignored
    assert [n.page for n in path] == [7, 8, 9]
    # divergent second chunk matches only the first
    assert [n.page for n in pc.match(toks[:4] + [0, 0, 0, 0])] == [7]
    # eviction is leaf-first and honors active references
    refs = {7: 1, 8: 1, 9: 2}  # page 9 (deepest leaf) still mapped by a slot
    assert pc.evict(5, lambda p: refs[p]) == []  # 9 pinned, 7/8 interior
    refs[9] = 1
    assert pc.evict(5, lambda p: refs[p]) == [9, 8, 7]  # leaves inward
    assert pc.n_nodes == 0


def test_radix_match_partial():
    """match_partial returns the full-page path PLUS the longest common
    token prefix inside the first divergent page."""
    pc = PrefixCache(page_size=4)
    toks = [1, 2, 3, 4, 5, 6, 7, 8]
    pc.insert(toks, [7, 8])
    # diverges 2 tokens into the second page
    path, partial, n = pc.match_partial([1, 2, 3, 4, 5, 6, 99, 98])
    assert [x.page for x in path] == [7]
    assert partial is not None and partial.page == 8 and n == 2
    # no shared token in the divergent page: no partial
    path, partial, n = pc.match_partial([1, 2, 3, 4, 50, 51, 52, 53])
    assert [x.page for x in path] == [7] and partial is None and n == 0
    # the best-matching sibling wins
    pc.insert([1, 2, 3, 4, 5, 6, 70, 71], [7, 9])
    _, partial, n = pc.match_partial([1, 2, 3, 4, 5, 6, 70, 99])
    assert partial.page == 9 and n == 3
    # prompt shorter than a page still partial-matches from the root
    path, partial, n = pc.match_partial([1, 2, 9])
    assert path == [] and partial.page == 7 and n == 2
    # full-page agreement is a match, never a partial
    path, partial, n = pc.match_partial(toks)
    assert [x.page for x in path] == [7, 8] and partial is None


# --------------------------------------------------- sub-page CoW stitch
MIDPAGE = [11, 12, 13, 14, 15, 16, 17, 18, 21, 22, 23, 24]  # 1.5 pages @ ps=8


def _midpage_requests(max_new=4, temperature=0.0):
    """Prompts sharing a 12-token prefix that ends mid-page (ps=8): page-
    aligned matching reuses only page 0; sub-page matching also recovers
    the 4 shared tokens inside page 1 via a CoW copy."""
    return [
        Request(uid="a", prompt=MIDPAGE + [50, 51, 52, 53], max_new_tokens=max_new,
                temperature=temperature),
        Request(uid="b", prompt=MIDPAGE + [60, 61], max_new_tokens=max_new,
                temperature=temperature),
        Request(uid="c", prompt=MIDPAGE + [70, 71, 72], max_new_tokens=max_new,
                temperature=temperature),
    ]


def test_subpage_stitch_matches_dense_and_beats_page_aligned():
    """The sub-page CoW stitch must stay byte-parity with the dense fused
    engine while prefilling strictly fewer prompt tokens than page-
    aligned matching, greedy and seeded temperature."""
    cfg, model, params = _setup()
    for temperature in (0.0, 0.7):
        dense = ServeEngine(model, params, max_batch=2, max_len=32,
                            prefill_chunk=4, rng_seed=7)
        want = _run(dense, _midpage_requests(temperature=temperature))
        aligned = ServeEngine(model, params, max_batch=2, max_len=32,
                              prefill_chunk=4, rng_seed=7,
                              cache_mode="paged", page_size=8, total_pages=12,
                              prefix_match="page")
        got_aligned = _run(aligned, _midpage_requests(temperature=temperature))
        subpage = ServeEngine(model, params, max_batch=2, max_len=32,
                              prefill_chunk=4, rng_seed=7,
                              cache_mode="paged", page_size=8, total_pages=12)
        got = _run(subpage, _midpage_requests(temperature=temperature))
        assert got == want == got_aligned, f"temperature={temperature}"
        assert subpage.prefix_hit_tokens_partial > 0
        assert subpage.cow_partial_stitches > 0
        assert aligned.prefix_hit_tokens_partial == 0
        assert (subpage.prompt_tokens_ingested
                < aligned.prompt_tokens_ingested), (
            "sub-page matching must prefill strictly fewer prompt tokens"
        )
        # the CoW'd partial page is slot-private: never refcounted > 1
        assert all(r >= 0 for r in subpage._page_refs)


def test_subpage_stitch_first_page_divergence():
    """Two prompts diverging INSIDE the first page — the case where page-
    aligned matching shares nothing at all — must still reuse the common
    tokens and stay byte-parity."""
    cfg, model, params = _setup(seed=4)
    def reqs():
        return [Request(uid="a", prompt=[5, 6, 7, 8, 9, 1, 2], max_new_tokens=4),
                Request(uid="b", prompt=[5, 6, 7, 8, 9, 3, 4], max_new_tokens=4)]
    dense = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4)
    want = _run(dense, reqs())
    # max_batch=1: b admits after a finishes and partially matches a's
    # published page 0 (a's 7-token prompt has 0 full chunks at ps=8 —
    # so publish via a longer prime first)
    eng = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=8)
    _run(eng, [Request(uid="warm", prompt=[5, 6, 7, 8, 9, 1, 2, 3, 4],
                       max_new_tokens=1)])
    got = _run(eng, reqs())
    assert got["a"] == want["a"] and got["b"] == want["b"]
    assert eng.cow_partial_stitches >= 2  # both stitched inside page 0
    assert eng.prefix_hit_tokens == 0  # no whole page ever matched
    assert eng.prefix_hit_tokens_partial > 0


def test_subpage_stitch_decode_path_mla():
    """Sub-page reuse must also work for archs that ingest prompts
    through the decode path (MoE/MLA): the unaligned resume position is
    just a per-row pos."""
    cfg, model, params = _setup("deepseek-v2-236b", seed=2)
    dense = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3)
    want = _run(dense, _midpage_requests(max_new=3))
    paged = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3,
                        cache_mode="paged", page_size=8, total_pages=10)
    assert not paged._use_prefill  # moe => decode-path ingestion
    got = _run(paged, _midpage_requests(max_new=3))
    assert got == want
    assert paged.prefix_hit_tokens_partial > 0
    assert paged.cow_partial_stitches > 0


def test_subpage_stitch_on_kernel_impl():
    """The Pallas kernel path (interpret mode) must agree with the jnp
    fallback when prefill resumes from a mid-page offset after a sub-page
    stitch."""
    cfg, model, params = _setup()
    outs = {}
    for impl in ("jnp", "kernel"):
        m = Model(cfg, ModelRuntime(paged_attn_impl=impl))
        eng = ServeEngine(m, params, max_batch=1, max_len=16, prefill_chunk=4,
                          cache_mode="paged", page_size=8, total_pages=6)
        outs[impl] = _run(eng, [
            Request(uid="a", prompt=[1, 2, 3, 4, 5, 6, 7, 8, 9],
                    max_new_tokens=3),
            Request(uid="b", prompt=[1, 2, 3, 4, 5, 9, 8, 7, 6],
                    max_new_tokens=3),
        ])
        assert eng.prefix_hit_tokens_partial > 0, (
            "b should partially match a's first page (5 tokens)"
        )
    assert outs["jnp"] == outs["kernel"]


# ------------------------------------------------- token parity with CoW
def test_prefix_sharing_matches_dense_with_cow():
    """Stitched prefixes + the full-hit hold-back CoW must stay token-
    parity with the dense fused engine, greedy and seeded temperature."""
    cfg, model, params = _setup()
    for temperature in (0.0, 0.7):
        dense = ServeEngine(model, params, max_batch=2, max_len=32,
                            prefill_chunk=4, rng_seed=7)
        want = _run(dense, _shared_requests(temperature=temperature))
        shared = ServeEngine(model, params, max_batch=2, max_len=32,
                             prefill_chunk=4, rng_seed=7,
                             cache_mode="paged", page_size=8, total_pages=10)
        got = _run(shared, _shared_requests(temperature=temperature))
        assert got == want, f"temperature={temperature}"
        assert shared.prefix_hit_tokens > 0
        assert shared.prompt_tokens_skipped > 0
        assert shared.cow_copies > 0, "full-prefix hit never exercised CoW"


def test_interleaved_shared_prefix_isolation():
    """Two requests stitched to the SAME physical pages, generating
    interleaved in one batch, must each match their solo dense run."""
    cfg, model, params = _setup(seed=3)
    want = {}
    for r in _shared_requests(max_new=6)[:2]:
        solo = ServeEngine(model, params, max_batch=1, max_len=32)
        want.update(_run(solo, [Request(uid=r.uid, prompt=list(r.prompt),
                                        max_new_tokens=6)]))
    eng = ServeEngine(model, params, max_batch=3, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=12)
    # warm the cache so both arrivals stitch the same pages, then run the
    # two sharers concurrently (same tick admission => aliased tables)
    _run(eng, [Request(uid="warm", prompt=list(PREFIX), max_new_tokens=1)])
    got = _run(eng, _shared_requests(max_new=6)[:2])
    assert got["a"] == want["a"] and got["b"] == want["b"]
    assert eng.prompt_tokens_skipped >= 2 * (len(PREFIX) - 1)
    assert eng.pages_shared_peak >= 2


def test_prefix_sharing_decode_path_mla():
    """MoE/MLA archs ingest prompts through the decode path; stitching
    and publication must work there too (pages of compressed latent)."""
    cfg, model, params = _setup("deepseek-v2-236b", seed=2)
    dense = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3)
    want = _run(dense, _shared_requests(max_new=3))
    paged = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3,
                        cache_mode="paged", page_size=8, total_pages=10)
    assert not paged._use_prefill  # moe => decode-path ingestion
    got = _run(paged, _shared_requests(max_new=3))
    assert got == want
    assert paged.prompt_tokens_skipped > 0


# ------------------------------------------------- allocator invariants
def test_refcounts_and_drain_baseline():
    """Refcounts never go negative, and after run_to_completion
    pages_in_use returns exactly to the cached-prefix baseline (every
    retained page is indexed by the radix cache with refcount 1)."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=10)
    _run(eng, _shared_requests())
    assert all(r >= 0 for r in eng._page_refs)
    cached = sorted(eng.prefix.pages())
    assert eng.pages_in_use == len(cached) == eng.prefix.n_nodes > 0
    assert all(eng._page_refs[p] == 1 for p in cached)
    # free list + cached pages partition the pool
    assert sorted(eng._free_pages + cached) == list(range(eng.n_pages))
    # a second identical batch reuses the retained prefix immediately
    skipped0 = eng.prompt_tokens_skipped
    _run(eng, _shared_requests())
    assert eng.prompt_tokens_skipped > skipped0
    assert all(r >= 0 for r in eng._page_refs)
    assert eng.pages_in_use == eng.prefix.n_nodes


def test_prefix_cache_disabled_restores_per_slot_drain():
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=10,
                      prefix_cache=False)
    _run(eng, _shared_requests())
    assert eng.prefix is None and eng.prompt_tokens_skipped == 0
    assert eng.pages_in_use == 0  # PR 2 free-on-finish semantics
    assert sorted(eng._free_pages) == list(range(eng.n_pages))


def test_lru_eviction_under_pool_pressure():
    """A pool too small to retain every prefix must evict LRU cached
    pages (not raise) and stay token-parity with the dense engine."""
    cfg, model, params = _setup(seed=1)
    def reqs():
        # three distinct 8-token (1 page) prefixes; retaining all three
        # plus a working set of 2 pages cannot fit a 3-page pool, so the
        # LRU prefix must be evicted mid-run
        return [
            Request(uid=f"r{i}", prompt=[100 + (i % 3)] * 8 + [30 + i],
                    max_new_tokens=4)
            for i in range(4)
        ]
    dense = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4)
    want = _run(dense, reqs())
    tight = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4,
                        cache_mode="paged", page_size=8, total_pages=3)
    got = _run(tight, reqs())
    assert got == want
    assert tight.prefix_evictions > 0, "pool pressure never evicted a prefix"
    assert all(r >= 0 for r in tight._page_refs)


def test_preemption_requeues_and_outputs_identical():
    """Exhaustion beyond eviction preempts the youngest slot; the rerun
    must be byte-identical (deterministic sampling streams) and every
    request must still finish."""
    cfg, model, params = _setup()
    def reqs():
        return [Request(uid=f"r{i}", prompt=[10 + i, 20 + i, 30 + i, 40 + i,
                                             50 + i, 60 + i, 70 + i],
                        max_new_tokens=6, temperature=0.5) for i in range(4)]
    dense = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5)
    want = _run(dense, reqs())
    # each request needs 2 pages; 2 slots want 4 — give 3 so slots collide
    tight = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5,
                        cache_mode="paged", page_size=8, total_pages=3)
    got = _run(tight, reqs())
    assert got == want
    assert len(got) == 4
    assert tight.preemptions > 0, "scenario never forced a preemption"
    # delivery counters are rolled back at preemption: emitted equals
    # tokens actually delivered, the thrown-away work is tracked apart
    assert tight.tokens_emitted == sum(len(o) for o in got.values())
    assert tight.tokens_emitted == dense.tokens_emitted
    assert tight.prompt_tokens_ingested <= dense.prompt_tokens_ingested
    assert tight.tokens_discarded > 0


def test_preemption_deterministic_with_host_sampling():
    """The rerun-is-byte-identical guarantee must hold on the host
    sampling fallback too: draws are keyed on (seed, stream, step), not
    on a shared rng whose sequence a preemption would desync."""
    cfg, model, params = _setup()
    def reqs():
        return [Request(uid=f"r{i}", prompt=[10 + i, 20 + i, 30 + i, 40 + i,
                                             50 + i, 60 + i, 70 + i],
                        max_new_tokens=6, temperature=0.5) for i in range(4)]
    dense = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5, sample_on_device=False)
    want = _run(dense, reqs())
    tight = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5, sample_on_device=False,
                        cache_mode="paged", page_size=8, total_pages=3)
    got = _run(tight, reqs())
    assert got == want
    assert tight.preemptions > 0, "scenario never forced a preemption"


def test_single_oversized_request_still_raises():
    """Recovery has a floor: a lone request that cannot fit in the whole
    pool must still fail loudly, not live-lock."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=1)
    eng.submit([Request(uid="big", prompt=[1, 2, 3, 4, 5, 6, 7],
                        max_new_tokens=8)])
    with pytest.raises(RuntimeError, match="pool exhausted"):
        eng.run_to_completion()


# ------------------------------------------------- adaptive pool sizing
def test_adaptive_total_pages_from_queue(caplog):
    """Omitting total_pages sizes the pool from the queue at submit,
    clamped to the dense reservation, and logs the choice."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=4, max_len=64, prefill_chunk=4,
                      cache_mode="paged", page_size=8)
    assert eng.cache is None and eng.n_pages is None
    dense = ServeEngine(model, params, max_batch=4, max_len=64, prefill_chunk=4)
    want = _run(dense, _shared_requests())
    with caplog.at_level(logging.INFO, logger="repro.serving.cache_manager"):
        got = _run(eng, _shared_requests())
    assert got == want
    dense_pages = eng.max_batch * eng.pages_per_slot
    assert 0 < eng.n_pages < dense_pages  # 3 small requests << dense
    assert any("sized adaptively" in m for m in caplog.messages)
    # pool big enough that sizing never forced a preemption here
    assert eng.preemptions == 0


def test_adaptive_pool_grows_for_later_submits(caplog):
    """A later submit queueing a bigger request than the first sizing saw
    must grow the pool in place (ids preserved, sentinel re-pushed), not
    strand the request on the lone-request exhaustion error."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=64, prefill_chunk=4,
                      cache_mode="paged", page_size=8)
    _run(eng, [Request(uid="tiny", prompt=[1, 2, 3], max_new_tokens=2)])
    small = eng.n_pages
    big_prompt = list(range(1, 41))  # 40 tokens + 8 new = 6 pages alone
    dense = ServeEngine(model, params, max_batch=2, max_len=64, prefill_chunk=4)
    want = _run(dense, [Request(uid="big", prompt=list(big_prompt),
                                max_new_tokens=8)])
    with caplog.at_level(logging.INFO, logger="repro.serving.cache_manager"):
        got = _run(eng, [Request(uid="big", prompt=list(big_prompt),
                                 max_new_tokens=8)])
    assert got["big"] == want["big"]
    assert eng.n_pages > small
    assert eng.n_pages <= eng.max_batch * eng.pages_per_slot
    assert any("grown adaptively" in m for m in caplog.messages)
    assert all(r >= 0 for r in eng._page_refs)


# ------------------------------------- aliased page tables, kernel parity
def test_kernel_matches_jnp_with_aliased_pages():
    """Two rows whose page tables alias the same physical page (stitched
    shared prefix) must decode identically through the Pallas kernel
    (interpret mode on CPU) and the jnp gather fallback — the page-table
    indirection supports aliasing with no kernel changes."""
    cfg, model, params = _setup()
    B, max_len, ps = 2, 32, 8
    P = max_len // ps
    n_pages = 6
    toks = np.asarray([[1, 2, 3, 4, 5, 6, 7, 9]] * 2, np.int32)
    offs = jnp.zeros((B,), jnp.int32)
    lens = jnp.full((B,), 8, jnp.int32)
    outs = {}
    for impl in ("jnp", "kernel"):
        m = Model(cfg, ModelRuntime(paged_attn_impl=impl))
        cache = m.init_cache(B, max_len, paged=True, page_size=ps,
                             n_pages=n_pages)
        # row 0 prefills the shared page 2 (both rows' identical first
        # chunk); row 1's table ALIASES it, plus private pages for the
        # positions each row writes next
        table = np.full((B, P), n_pages, np.int32)
        table[0] = [2, 0, n_pages, n_pages]
        table[1] = [2, 1, n_pages, n_pages]
        cache["page_table"] = jnp.asarray(table)
        # prefill only row 0's copy of the chunk: write goes to page 2
        # once; row 1 never writes it (stitched semantics)
        one_row = jnp.asarray([8, 0], jnp.int32)
        lg, cache = m.prefill_chunk(params, cache, jnp.asarray(toks), offs,
                                    one_row)
        # both rows decode the SAME token stream from pos 8: each writes
        # its private page while reading the shared page-2 history, so
        # their logits must also agree row-to-row
        step_logits = []
        for pos in (8, 9, 10):
            pv = jnp.full((B,), pos, jnp.int32)
            nxt = jnp.asarray([[7], [7]], jnp.int32)
            lg2, cache = m.decode_step(params, cache, nxt, pv)
            step_logits.append(np.asarray(lg2))
        outs[impl] = np.stack(step_logits)
    np.testing.assert_allclose(outs["jnp"], outs["kernel"], rtol=2e-4,
                               atol=2e-4)
    # rows saw the same prefix through one physical page: identical
    # prompts + identical fed tokens => identical logits row-to-row
    np.testing.assert_allclose(outs["jnp"][:, 0], outs["jnp"][:, 1],
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------- randomized sub-page property
def _check_invariants(eng: ServeEngine):
    """Allocator invariants with sub-page CoW pages in play: refcount ==
    holders, free list partitions the pool, and a page mapped by several
    slots always backs the same page-aligned prompt chunk in each (the
    CoW'd partial page is slot-private until its owner publishes it as a
    full chunk, so it can never alias across slots mid-divergence)."""
    ps = eng.page_size
    cached = eng.prefix.pages()
    assert len(set(cached)) == len(cached)
    cached_set = set(cached)
    holders = {pid: [] for pid in range(eng.n_pages)}
    for row, pages in enumerate(eng._slot_pages):
        for j, pid in enumerate(pages):
            holders[pid].append((row, j))
    for pid in range(eng.n_pages):
        want = len(holders[pid]) + (1 if pid in cached_set else 0)
        assert eng._page_refs[pid] == want, (
            f"page {pid}: refcount {eng._page_refs[pid]} != holders {want}"
        )
    assert sorted(eng._free_pages
                  + [p for p in range(eng.n_pages)
                     if eng._page_refs[p] > 0]) == list(range(eng.n_pages))
    for pid, maps in holders.items():
        if len(maps) < 2:
            continue
        chunks = []
        for row, j in maps:
            req = eng.slots[row].req
            assert req is not None, f"parked slot {row} still maps page {pid}"
            assert (j + 1) * ps <= len(req.prompt), (
                f"page {pid} shared inside slot {row}'s generated/partial "
                "region — a CoW'd partial page must stay slot-private"
            )
            chunks.append(tuple(req.prompt[j * ps:(j + 1) * ps]))
        assert len(set(chunks)) == 1, (
            f"page {pid} aliased across unrelated slots: {chunks}"
        )


def test_randomized_subpage_interleaving_byte_parity():
    """Property test: seeded random prompts over shared prefixes that end
    at UNALIGNED offsets, interleaved admission/finish/preemption on a
    tight pool.  At every tick the allocator invariants must hold, and
    the final outputs must be byte-identical to a cold one-shot dense
    run (scheduling, eviction, preemption and sub-page CoW stitching all
    invisible to content)."""
    import random

    cfg, model, params = _setup()
    bases = [[100 + j for j in range(12)], [200 + j for j in range(12)]]
    partial_seen = pressure_seen = False
    for seed in (0, 1, 2):
        rng = random.Random(seed)
        reqs = []
        for i in range(10):
            kind = rng.randrange(4)
            if kind < 3:  # shared prefix, cut at a random UNALIGNED point
                base = bases[kind % 2]
                cut = rng.randrange(3, len(base) + 1)  # mostly mid-page
                p = base[:cut] + [rng.randrange(1, 99)
                                  for _ in range(rng.randrange(0, 5))]
            else:  # cold random prompt
                p = [rng.randrange(1, 99) for _ in range(rng.randrange(1, 13))]
            reqs.append(Request(uid=f"r{i}", prompt=p,
                                max_new_tokens=rng.randrange(1, 5),
                                temperature=0.5))

        dense = ServeEngine(model, params, max_batch=3, max_len=32,
                            prefill_chunk=4, rng_seed=11)
        dense.submit([Request(uid=r.uid, prompt=list(r.prompt),
                              max_new_tokens=r.max_new_tokens,
                              temperature=r.temperature) for r in reqs])
        dense.run_to_completion()
        want = {r.uid: r.output for r in dense.finished}

        eng = ServeEngine(model, params, max_batch=3, max_len=32,
                          prefill_chunk=4, rng_seed=11,
                          cache_mode="paged", page_size=8, total_pages=6)
        queue = list(reqs)
        steps = 0
        while (queue or eng.pending or eng.scheduler.has_active()) and steps < 500:
            if queue and rng.random() < 0.6:
                eng.submit([queue.pop(0)
                            for _ in range(min(len(queue), rng.randrange(1, 4)))])
            eng.step()
            steps += 1
            _check_invariants(eng)
        assert not queue and not eng.pending
        got = {r.uid: r.output for r in eng.finished}
        assert got == want, f"seed {seed}: sub-page paged != one-shot dense"
        # drain baseline: only radix-cached pages remain, each at ref 1
        cached = sorted(eng.prefix.pages())
        assert eng.pages_in_use == len(cached)
        assert all(eng._page_refs[p] == 1 for p in cached)
        partial_seen |= eng.cow_partial_stitches > 0
        pressure_seen |= (eng.preemptions + eng.prefix_evictions) > 0
    assert partial_seen, "no seed ever exercised a sub-page stitch — weak test"
    assert pressure_seen, "pool never came under pressure — weak test"


def test_engine_prefix_sharing_on_kernel_impl():
    """End-to-end: the prefix-sharing engine over the Pallas kernel path
    (interpret mode) matches the jnp-fallback engine token-for-token."""
    cfg, model, params = _setup()
    outs = {}
    for impl in ("jnp", "kernel"):
        m = Model(cfg, ModelRuntime(paged_attn_impl=impl))
        # max_batch=1 => b is admitted after a completes and hits a's
        # published prefix pages
        eng = ServeEngine(m, params, max_batch=1, max_len=16, prefill_chunk=4,
                          cache_mode="paged", page_size=8, total_pages=6)
        outs[impl] = _run(eng, [
            Request(uid="a", prompt=[1, 2, 3, 4, 5, 6, 7, 8, 9],
                    max_new_tokens=3),
            Request(uid="b", prompt=[1, 2, 3, 4, 5, 6, 7, 8, 10],
                    max_new_tokens=3),
        ])
        assert eng.prompt_tokens_skipped >= 8  # b stitched the first page
    assert outs["jnp"] == outs["kernel"]

"""Shared-prefix radix cache over the paged KV pool: stitching, CoW,
refcount invariants, LRU eviction, preemption recovery, adaptive pool
sizing, and kernel parity with aliased page tables."""

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_cache import PrefixCache


def _setup(arch="ds-paper-100m", seed=0, **rt_kwargs):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(**rt_kwargs))
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


PREFIX = [11, 12, 13, 14, 15, 16, 17, 18, 21, 22, 23, 24, 25, 26, 27, 28]


def _shared_requests(max_new=4, temperature=0.0):
    """Three prompts over one 16-token (2 pages at ps=8) system prefix:
    two divergent tails plus one *identical* full-prefix prompt (the
    full-hit forces the hold-back token's copy-on-write)."""
    return [
        Request(uid="a", prompt=PREFIX + [50, 51], max_new_tokens=max_new,
                temperature=temperature),
        Request(uid="b", prompt=PREFIX + [60, 61, 62], max_new_tokens=max_new,
                temperature=temperature),
        Request(uid="c", prompt=list(PREFIX), max_new_tokens=max_new,
                temperature=temperature),
    ]


def _run(engine, reqs):
    engine.submit(reqs)
    engine.run_to_completion()
    return {r.uid: r.output for r in engine.finished}


# ----------------------------------------------------------- radix unit
def test_radix_match_insert_evict():
    pc = PrefixCache(page_size=4)
    toks = list(range(1, 13))  # 3 full chunks
    assert pc.match(toks) == []
    adopted = pc.insert(toks, [7, 8, 9])
    assert adopted == [7, 8, 9] and pc.n_nodes == 3
    # re-insert with different pages: first writer wins, nothing adopted
    assert pc.insert(toks, [1, 2, 3]) == []
    path = pc.match(toks + [99])  # partial tail ignored
    assert [n.page for n in path] == [7, 8, 9]
    # divergent second chunk matches only the first
    assert [n.page for n in pc.match(toks[:4] + [0, 0, 0, 0])] == [7]
    # eviction is leaf-first and honors active references
    refs = {7: 1, 8: 1, 9: 2}  # page 9 (deepest leaf) still mapped by a slot
    assert pc.evict(5, lambda p: refs[p]) == []  # 9 pinned, 7/8 interior
    refs[9] = 1
    assert pc.evict(5, lambda p: refs[p]) == [9, 8, 7]  # leaves inward
    assert pc.n_nodes == 0


# ------------------------------------------------- token parity with CoW
def test_prefix_sharing_matches_dense_with_cow():
    """Stitched prefixes + the full-hit hold-back CoW must stay token-
    parity with the dense fused engine, greedy and seeded temperature."""
    cfg, model, params = _setup()
    for temperature in (0.0, 0.7):
        dense = ServeEngine(model, params, max_batch=2, max_len=32,
                            prefill_chunk=4, rng_seed=7)
        want = _run(dense, _shared_requests(temperature=temperature))
        shared = ServeEngine(model, params, max_batch=2, max_len=32,
                             prefill_chunk=4, rng_seed=7,
                             cache_mode="paged", page_size=8, total_pages=10)
        got = _run(shared, _shared_requests(temperature=temperature))
        assert got == want, f"temperature={temperature}"
        assert shared.prefix_hit_tokens > 0
        assert shared.prompt_tokens_skipped > 0
        assert shared.cow_copies > 0, "full-prefix hit never exercised CoW"


def test_interleaved_shared_prefix_isolation():
    """Two requests stitched to the SAME physical pages, generating
    interleaved in one batch, must each match their solo dense run."""
    cfg, model, params = _setup(seed=3)
    want = {}
    for r in _shared_requests(max_new=6)[:2]:
        solo = ServeEngine(model, params, max_batch=1, max_len=32)
        want.update(_run(solo, [Request(uid=r.uid, prompt=list(r.prompt),
                                        max_new_tokens=6)]))
    eng = ServeEngine(model, params, max_batch=3, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=12)
    # warm the cache so both arrivals stitch the same pages, then run the
    # two sharers concurrently (same tick admission => aliased tables)
    _run(eng, [Request(uid="warm", prompt=list(PREFIX), max_new_tokens=1)])
    got = _run(eng, _shared_requests(max_new=6)[:2])
    assert got["a"] == want["a"] and got["b"] == want["b"]
    assert eng.prompt_tokens_skipped >= 2 * (len(PREFIX) - 1)
    assert eng.pages_shared_peak >= 2


def test_prefix_sharing_decode_path_mla():
    """MoE/MLA archs ingest prompts through the decode path; stitching
    and publication must work there too (pages of compressed latent)."""
    cfg, model, params = _setup("deepseek-v2-236b", seed=2)
    dense = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3)
    want = _run(dense, _shared_requests(max_new=3))
    paged = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3,
                        cache_mode="paged", page_size=8, total_pages=10)
    assert not paged._use_prefill  # moe => decode-path ingestion
    got = _run(paged, _shared_requests(max_new=3))
    assert got == want
    assert paged.prompt_tokens_skipped > 0


# ------------------------------------------------- allocator invariants
def test_refcounts_and_drain_baseline():
    """Refcounts never go negative, and after run_to_completion
    pages_in_use returns exactly to the cached-prefix baseline (every
    retained page is indexed by the radix cache with refcount 1)."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=10)
    _run(eng, _shared_requests())
    assert all(r >= 0 for r in eng._page_refs)
    cached = sorted(eng.prefix.pages())
    assert eng.pages_in_use == len(cached) == eng.prefix.n_nodes > 0
    assert all(eng._page_refs[p] == 1 for p in cached)
    # free list + cached pages partition the pool
    assert sorted(eng._free_pages + cached) == list(range(eng.n_pages))
    # a second identical batch reuses the retained prefix immediately
    skipped0 = eng.prompt_tokens_skipped
    _run(eng, _shared_requests())
    assert eng.prompt_tokens_skipped > skipped0
    assert all(r >= 0 for r in eng._page_refs)
    assert eng.pages_in_use == eng.prefix.n_nodes


def test_prefix_cache_disabled_restores_per_slot_drain():
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=10,
                      prefix_cache=False)
    _run(eng, _shared_requests())
    assert eng.prefix is None and eng.prompt_tokens_skipped == 0
    assert eng.pages_in_use == 0  # PR 2 free-on-finish semantics
    assert sorted(eng._free_pages) == list(range(eng.n_pages))


def test_lru_eviction_under_pool_pressure():
    """A pool too small to retain every prefix must evict LRU cached
    pages (not raise) and stay token-parity with the dense engine."""
    cfg, model, params = _setup(seed=1)
    def reqs():
        # three distinct 8-token (1 page) prefixes; retaining all three
        # plus a working set of 2 pages cannot fit a 3-page pool, so the
        # LRU prefix must be evicted mid-run
        return [
            Request(uid=f"r{i}", prompt=[100 + (i % 3)] * 8 + [30 + i],
                    max_new_tokens=4)
            for i in range(4)
        ]
    dense = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4)
    want = _run(dense, reqs())
    tight = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4,
                        cache_mode="paged", page_size=8, total_pages=3)
    got = _run(tight, reqs())
    assert got == want
    assert tight.prefix_evictions > 0, "pool pressure never evicted a prefix"
    assert all(r >= 0 for r in tight._page_refs)


def test_preemption_requeues_and_outputs_identical():
    """Exhaustion beyond eviction preempts the youngest slot; the rerun
    must be byte-identical (deterministic sampling streams) and every
    request must still finish."""
    cfg, model, params = _setup()
    def reqs():
        return [Request(uid=f"r{i}", prompt=[10 + i, 20 + i, 30 + i, 40 + i,
                                             50 + i, 60 + i, 70 + i],
                        max_new_tokens=6, temperature=0.5) for i in range(4)]
    dense = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5)
    want = _run(dense, reqs())
    # each request needs 2 pages; 2 slots want 4 — give 3 so slots collide
    tight = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5,
                        cache_mode="paged", page_size=8, total_pages=3)
    got = _run(tight, reqs())
    assert got == want
    assert len(got) == 4
    assert tight.preemptions > 0, "scenario never forced a preemption"
    # delivery counters are rolled back at preemption: emitted equals
    # tokens actually delivered, the thrown-away work is tracked apart
    assert tight.tokens_emitted == sum(len(o) for o in got.values())
    assert tight.tokens_emitted == dense.tokens_emitted
    assert tight.prompt_tokens_ingested <= dense.prompt_tokens_ingested
    assert tight.tokens_discarded > 0


def test_preemption_deterministic_with_host_sampling():
    """The rerun-is-byte-identical guarantee must hold on the host
    sampling fallback too: draws are keyed on (seed, stream, step), not
    on a shared rng whose sequence a preemption would desync."""
    cfg, model, params = _setup()
    def reqs():
        return [Request(uid=f"r{i}", prompt=[10 + i, 20 + i, 30 + i, 40 + i,
                                             50 + i, 60 + i, 70 + i],
                        max_new_tokens=6, temperature=0.5) for i in range(4)]
    dense = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5, sample_on_device=False)
    want = _run(dense, reqs())
    tight = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5, sample_on_device=False,
                        cache_mode="paged", page_size=8, total_pages=3)
    got = _run(tight, reqs())
    assert got == want
    assert tight.preemptions > 0, "scenario never forced a preemption"


def test_single_oversized_request_still_raises():
    """Recovery has a floor: a lone request that cannot fit in the whole
    pool must still fail loudly, not live-lock."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                      cache_mode="paged", page_size=8, total_pages=1)
    eng.submit([Request(uid="big", prompt=[1, 2, 3, 4, 5, 6, 7],
                        max_new_tokens=8)])
    with pytest.raises(RuntimeError, match="pool exhausted"):
        eng.run_to_completion()


# ------------------------------------------------- adaptive pool sizing
def test_adaptive_total_pages_from_queue(caplog):
    """Omitting total_pages sizes the pool from the queue at submit,
    clamped to the dense reservation, and logs the choice."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=4, max_len=64, prefill_chunk=4,
                      cache_mode="paged", page_size=8)
    assert eng.cache is None and eng.n_pages is None
    dense = ServeEngine(model, params, max_batch=4, max_len=64, prefill_chunk=4)
    want = _run(dense, _shared_requests())
    with caplog.at_level(logging.INFO, logger="repro.serving.cache_manager"):
        got = _run(eng, _shared_requests())
    assert got == want
    dense_pages = eng.max_batch * eng.pages_per_slot
    assert 0 < eng.n_pages < dense_pages  # 3 small requests << dense
    assert any("sized adaptively" in m for m in caplog.messages)
    # pool big enough that sizing never forced a preemption here
    assert eng.preemptions == 0


def test_adaptive_pool_grows_for_later_submits(caplog):
    """A later submit queueing a bigger request than the first sizing saw
    must grow the pool in place (ids preserved, sentinel re-pushed), not
    strand the request on the lone-request exhaustion error."""
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, max_batch=2, max_len=64, prefill_chunk=4,
                      cache_mode="paged", page_size=8)
    _run(eng, [Request(uid="tiny", prompt=[1, 2, 3], max_new_tokens=2)])
    small = eng.n_pages
    big_prompt = list(range(1, 41))  # 40 tokens + 8 new = 6 pages alone
    dense = ServeEngine(model, params, max_batch=2, max_len=64, prefill_chunk=4)
    want = _run(dense, [Request(uid="big", prompt=list(big_prompt),
                                max_new_tokens=8)])
    with caplog.at_level(logging.INFO, logger="repro.serving.cache_manager"):
        got = _run(eng, [Request(uid="big", prompt=list(big_prompt),
                                 max_new_tokens=8)])
    assert got["big"] == want["big"]
    assert eng.n_pages > small
    assert eng.n_pages <= eng.max_batch * eng.pages_per_slot
    assert any("grown adaptively" in m for m in caplog.messages)
    assert all(r >= 0 for r in eng._page_refs)


# ------------------------------------- aliased page tables, kernel parity
def test_kernel_matches_jnp_with_aliased_pages():
    """Two rows whose page tables alias the same physical page (stitched
    shared prefix) must decode identically through the Pallas kernel
    (interpret mode on CPU) and the jnp gather fallback — the page-table
    indirection supports aliasing with no kernel changes."""
    cfg, model, params = _setup()
    B, max_len, ps = 2, 32, 8
    P = max_len // ps
    n_pages = 6
    toks = np.asarray([[1, 2, 3, 4, 5, 6, 7, 9]] * 2, np.int32)
    offs = jnp.zeros((B,), jnp.int32)
    lens = jnp.full((B,), 8, jnp.int32)
    outs = {}
    for impl in ("jnp", "kernel"):
        m = Model(cfg, ModelRuntime(paged_attn_impl=impl))
        cache = m.init_cache(B, max_len, paged=True, page_size=ps,
                             n_pages=n_pages)
        # row 0 prefills the shared page 2 (both rows' identical first
        # chunk); row 1's table ALIASES it, plus private pages for the
        # positions each row writes next
        table = np.full((B, P), n_pages, np.int32)
        table[0] = [2, 0, n_pages, n_pages]
        table[1] = [2, 1, n_pages, n_pages]
        cache["page_table"] = jnp.asarray(table)
        # prefill only row 0's copy of the chunk: write goes to page 2
        # once; row 1 never writes it (stitched semantics)
        one_row = jnp.asarray([8, 0], jnp.int32)
        lg, cache = m.prefill_chunk(params, cache, jnp.asarray(toks), offs,
                                    one_row)
        # both rows decode the SAME token stream from pos 8: each writes
        # its private page while reading the shared page-2 history, so
        # their logits must also agree row-to-row
        step_logits = []
        for pos in (8, 9, 10):
            pv = jnp.full((B,), pos, jnp.int32)
            nxt = jnp.asarray([[7], [7]], jnp.int32)
            lg2, cache = m.decode_step(params, cache, nxt, pv)
            step_logits.append(np.asarray(lg2))
        outs[impl] = np.stack(step_logits)
    np.testing.assert_allclose(outs["jnp"], outs["kernel"], rtol=2e-4,
                               atol=2e-4)
    # rows saw the same prefix through one physical page: identical
    # prompts + identical fed tokens => identical logits row-to-row
    np.testing.assert_allclose(outs["jnp"][:, 0], outs["jnp"][:, 1],
                               rtol=1e-5, atol=1e-5)


def test_engine_prefix_sharing_on_kernel_impl():
    """End-to-end: the prefix-sharing engine over the Pallas kernel path
    (interpret mode) matches the jnp-fallback engine token-for-token."""
    cfg, model, params = _setup()
    outs = {}
    for impl in ("jnp", "kernel"):
        m = Model(cfg, ModelRuntime(paged_attn_impl=impl))
        # max_batch=1 => b is admitted after a completes and hits a's
        # published prefix pages
        eng = ServeEngine(m, params, max_batch=1, max_len=16, prefill_chunk=4,
                          cache_mode="paged", page_size=8, total_pages=6)
        outs[impl] = _run(eng, [
            Request(uid="a", prompt=[1, 2, 3, 4, 5, 6, 7, 8, 9],
                    max_new_tokens=3),
            Request(uid="b", prompt=[1, 2, 3, 4, 5, 6, 7, 8, 10],
                    max_new_tokens=3),
        ])
        assert eng.prompt_tokens_skipped >= 8  # b stitched the first page
    assert outs["jnp"] == outs["kernel"]

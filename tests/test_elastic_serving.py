"""Elastic serving-fleet robustness: autoscaler policy (hysteresis,
cooldowns, step bounds), deterministic chaos drills (same seed => same
revocation schedule => byte-identical outputs and counters), dead-letter
redrive under churn, placement/deregistration regressions, and the
worker's capped-exponential retry backoff."""

import os

os.environ.setdefault("DS_DEBUG_INVARIANTS", "1")

import jax  # noqa: F401  (initialize the platform before model builds)
import numpy as np

import repro.launch.serve  # noqa: F401  (registers distributed-serve)
import repro.launch.train  # noqa: F401
from repro.core import (
    DSConfig,
    DSRuntime,
    FleetFile,
    JobFile,
    SimRunner,
    VirtualClock,
)
from repro.core.autoscaler import Autoscaler, ProgressBoard
from repro.core.chaos import ChaosEvent, ChaosMonkey
from repro.core.cluster import ECSCluster, Service, TaskDefinition
from repro.core.fleet import SpotFleet
from repro.core.queue import DurableQueue, Message
from repro.core.storage import ObjectStore
from repro.core.worker import _stable_key, backoff_delay
from repro.launch.serve import reset_serve_state
from repro.launch.train import build_model
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_store import PrefixStore

TICK = 30.0

# small-but-real serving job (same reduced arch the stream tests use),
# paged + prefix-store so a drain has publications to flush
DRILL = {
    "arch": "ds-paper-100m",
    "arch_overrides": "reduced",
    # long decodes: one engine step fully prefills a prompt, so the
    # decode tail is what keeps requests in flight when the notice lands
    "max_new_tokens": 12,
    "max_len": 32,
    "max_batch": 2,
    "prefill_chunk": 4,
    "cache_mode": "paged",
    "page_size": 8,
    "prefix_cache": True,
    "prefix_store": True,
}
SYS_PROMPT = [11, 12, 13, 14, 15, 16, 17, 18,
              21, 22, 23, 24, 25, 26, 27, 28]
DRILL_PROMPTS = [SYS_PROMPT + [31 + i] for i in range(6)]

COUNTER_KEYS = (
    "revocation_notices", "drain_requeued_requests", "requests_resumed",
    "lease_slices", "lease_resumes",
    "prefix_store_pages_published", "prefix_store_pages_hydrated",
)


def _reference_outputs(job, prompts, max_new):
    """One-shot static-batch oracle with the payload's own model path."""
    model = build_model(job)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      max_batch=job["max_batch"], max_len=job["max_len"],
                      prefill_chunk=job["prefill_chunk"])
    eng.submit([Request(uid=f"q{i}", prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(prompts)])
    eng.run_to_completion()
    return {r.uid: r.output for r in eng.finished}


def _aggregate_counters(store, out):
    """Sum engine counters over every worker's segment summary under
    ``out``.  Both records a worker leaves are *cumulative* for that
    worker — the slice/drain record under leases/ and the final
    RESULTS-*.json — so exactly one per worker is summed, with the final
    summary superseding the slice record (noop permit summaries carry no
    counters and contribute zero)."""
    finals, slices = {}, {}
    for info in store.list(f"{out}/"):
        if not info.key.endswith(".json"):
            continue
        base = info.key.rsplit("/", 1)[-1][: -len(".json")]
        if "/leases/" in info.key:
            slices[base] = store.get_json(info.key)
        elif "/RESULTS-" in info.key:
            finals[base.split("RESULTS-", 1)[-1]] = store.get_json(info.key)
    totals = {k: 0 for k in COUNTER_KEYS}
    for snap in {**slices, **finals}.values():
        for k in COUNTER_KEYS:
            totals[k] += int(snap.get(k, 0))
    return totals


def _served_outputs(store, out):
    prefix = f"{out}/requests/"
    return {
        info.key[len(prefix):-len(".json")]:
            store.get_json(info.key)["completion"]
        for info in store.list(prefix)
        if info.key.endswith(".json")
    }


# --------------------------------------------------------------- autoscaler
def test_autoscaler_policy_hysteresis_cooldowns_and_step_bounds(tmp_path):
    clk = VirtualClock()
    cfg = DSConfig(
        app_name="Scale", cluster_machines=1,
        machine_type=["sim.large"], machine_price=1.0,
        autoscale="slo", min_workers=1, max_workers=6,
        autoscale_queue_per_worker=4, autoscale_target_p99_ttft=10.0,
        autoscale_up_cooldown_seconds=60.0,
        autoscale_down_cooldown_seconds=600.0,
        autoscale_max_step=2, monitor_poll_seconds=60.0,
    )
    queue = DurableQueue(str(tmp_path / "jobs.sqlite"), clock=clk)
    queue.send_batch([{"n": i} for i in range(8)])
    fleet = SpotFleet(FleetFile(startup_seconds=0.0), clock=clk,
                      app_name="Scale")
    fleet.request(target_capacity=1, bid=1.0, machine_types=["sim.large"])
    cluster = ECSCluster()
    cluster.register_service(Service(
        name="ScaleService",
        task_definition=TaskDefinition.from_config(cfg),
        desired_count=1,
    ))
    board = ProgressBoard()
    asc = Autoscaler(cfg, queue, fleet, cluster, clock=clk, board=board)

    # 1. no serve reports yet: job-queue fallback (8 visible / 4 per
    # worker => 2); a non-serve progress payload must be ignored
    board.put("w0", {"kind": "train", "backlog": 100}, clk.now())
    d = asc.tick()
    assert d.applied and d.desired == 2 and "job-queue" in d.reason
    assert fleet.target_capacity == 2
    # ECS desired count follows the fleet target
    assert cluster.services["ScaleService"].desired_count == 2

    # 2. immediate re-tick with a big reported backlog: up-cooldown blocks
    board.put("w1", {"kind": "serve", "backlog": 40, "p99_ttft": 0.0},
              clk.now())
    d = asc.tick()
    assert not d.applied and "up-cooldown" in d.reason
    assert fleet.target_capacity == 2

    # 3. cooldown elapsed: scale up, but only by max_step (2 -> 4, not 6)
    clk.sleep(60.0)
    board.put("w1", {"kind": "serve", "backlog": 40, "p99_ttft": 0.0},
              clk.now())
    d = asc.tick()
    assert d.applied and d.desired == 4
    assert fleet.target_capacity == 4

    # 4. SLO breach scales up even with an empty queue, clamped to max
    clk.sleep(60.0)
    board.put("w1", {"kind": "serve", "backlog": 0, "p99_ttft": 25.0},
              clk.now())
    d = asc.tick()
    assert d.applied and d.desired == 6 and "slo breach" in d.reason
    assert fleet.target_capacity == 6

    # 5. hysteresis band (target/2, target]: hold, don't shrink
    clk.sleep(60.0)
    board.put("w1", {"kind": "serve", "backlog": 0, "p99_ttft": 7.0},
              clk.now())
    d = asc.tick()
    assert not d.applied and d.desired == 6 and "slo hold" in d.reason
    assert fleet.target_capacity == 6

    # 6. quiet fleet wants to shrink, but the down-cooldown (measured
    # from the LAST SCALE-UP too) blocks the first attempt
    clk.sleep(60.0)
    board.put("w1", {"kind": "serve", "backlog": 4, "p99_ttft": 1.0},
              clk.now())
    d = asc.tick()
    assert not d.applied and "down-cooldown" in d.reason
    assert fleet.target_capacity == 6

    # 7. after the down-cooldown: shrink, step-bounded (6 -> 4, not 1)
    clk.sleep(600.0)
    board.put("w1", {"kind": "serve", "backlog": 4, "p99_ttft": 1.0},
              clk.now())
    d = asc.tick()
    assert d.applied and d.desired == 4
    assert fleet.target_capacity == 4
    assert cluster.services["ScaleService"].desired_count == 4

    # autoscale="off" is a hard no-op
    off = Autoscaler(DSConfig(app_name="Off"), queue, fleet, cluster,
                     clock=clk, board=board)
    assert off.tick() is None
    assert fleet.target_capacity == 4


# ------------------------------------------------------------- chaos drills
def _run_drill(base, tag, *, chaos_seed):
    """One elastic serve run under a seeded revocation drill; returns
    (outputs, chaos log, aggregated counters, run summary, queue)."""
    reset_serve_state()
    clk = VirtualClock()
    cfg = DSConfig(
        app_name="Drill", payload="distributed-serve",
        cluster_machines=1, tasks_per_machine=1,
        machine_type=["sim.large"], machine_price=1.0,
        # fill the machine: placement bin-packs by resources, and a
        # half-size task would put both workers on one instance
        cpu_shares=8192, memory_mb=16384,
        sqs_message_visibility=240.0, check_if_done=False,
        idle_alarm_seconds=100_000.0, monitor_poll_seconds=TICK,
        autoscale="queue", min_workers=1, max_workers=2,
        autoscale_queue_per_worker=2,
        autoscale_up_cooldown_seconds=TICK,
        autoscale_down_cooldown_seconds=3600.0,
    )
    rt = DSRuntime(cfg, store_root=str(base / f"store_{tag}"), clock=clk)
    rt.setup()
    rq_path = str(base / f"requests_{tag}.sqlite")
    rq = DurableQueue(rq_path, default_visibility=240.0,
                      max_receive_count=6, clock=clk)
    rq.send_batch([
        {"uid": f"q{i}", "prompt": p,
         "max_new_tokens": DRILL["max_new_tokens"]}
        for i, p in enumerate(DRILL_PROMPTS)
    ])
    out = "serve/drill"
    rt.submit_job(JobFile(
        shared=dict(
            DRILL,
            request_queue=rq_path,
            expected_requests=len(DRILL_PROMPTS),
            output_prefix=out,
            stream_slice_ticks=2,
            stream_idle_polls=8,
            request_visibility=240.0,
            request_max_receive_count=6,
        ),
        groups=[{} for _ in range(2)],  # one lease permit per worker slot
    ))
    rt.start_cluster(FleetFile(startup_seconds=TICK, market_seed=7))
    chaos = ChaosMonkey.revocation_drill(
        rt.fleet, clk, seed=chaos_seed, n_revocations=1,
        start=3 * TICK, spacing=2 * TICK, notice_seconds=2 * TICK,
        store=rt.store, logs=rt.logs,
    )
    summary = SimRunner(rt, tick_seconds=TICK, chaos=chaos).run(max_ticks=300)
    outputs = _served_outputs(rt.store, out)
    counters = _aggregate_counters(rt.store, out)
    log = [(r.kind, r.target, r.time) for r in chaos.log]
    return outputs, log, counters, summary, rq


def test_chaos_drill_is_deterministic_and_loses_nothing(tmp_path):
    """Same chaos seed => identical revocation schedule => byte-identical
    completions AND identical aggregated counter snapshots across two
    runs — the replay property the churn benchmark's gates rely on."""
    out_a, log_a, ctr_a, summary_a, rq_a = _run_drill(
        tmp_path, "a", chaos_seed=1234)
    # run 1 correctness: the notice was delivered and honoured
    assert summary_a.preemptions >= 1  # the revoked machine terminated
    assert ctr_a["revocation_notices"] >= 1
    assert ctr_a["drain_requeued_requests"] >= 1
    assert ctr_a["requests_resumed"] >= 1  # requeued work found a survivor
    assert ctr_a["prefix_store_pages_published"] > 0
    # every request completed exactly once, byte-identical to the
    # undisturbed static-batch oracle, and none died
    assert rq_a.counts() == {"visible": 0, "in_flight": 0, "dead": 0}
    want = _reference_outputs(DRILL, DRILL_PROMPTS, DRILL["max_new_tokens"])
    assert out_a == want, "churned completions diverged from the oracle"

    out_b, log_b, ctr_b, _, _ = _run_drill(tmp_path, "b", chaos_seed=1234)
    assert log_a == log_b, "same seed must replay the same fault schedule"
    assert out_a == out_b
    assert ctr_a == ctr_b, (ctr_a, ctr_b)


def test_revocation_drill_schedule_is_seeded(tmp_path):
    clk = VirtualClock()
    fleet = SpotFleet(FleetFile(startup_seconds=0.0), clock=clk,
                      app_name="Sched")
    mk = lambda seed: ChaosMonkey.revocation_drill(  # noqa: E731
        fleet, clk, seed=seed, n_revocations=3, start=60.0,
        spacing=120.0, notice_seconds=60.0)
    sched = lambda m: [(e.at, e.victim) for e in m.pending]  # noqa: E731
    assert sched(mk(7)) == sched(mk(7))
    assert sched(mk(7)) != sched(mk(8))


def test_delay_heartbeat_suppresses_liveness_for_the_window():
    clk = VirtualClock()
    fleet = SpotFleet(FleetFile(startup_seconds=0.0), clock=clk,
                      app_name="Hb")
    fleet.request(target_capacity=1, bid=1.0, machine_types=["sim.large"])
    fleet.tick()
    inst = fleet.running()[0]
    chaos = ChaosMonkey(fleet, clk, events=[
        ChaosEvent(kind="delay_heartbeat", at=0.0, victim=0, duration=90.0)
    ])
    assert [r.kind for r in chaos.tick()] == ["delay_heartbeat"]
    assert chaos.counters["heartbeat_delays"] == 1
    assert chaos.allow_heartbeat(inst) is False  # wedged-looking host
    clk.sleep(90.0)
    assert chaos.allow_heartbeat(inst) is True


def test_truncated_prefix_blob_is_a_fetch_miss_not_a_crash(tmp_path):
    store = ObjectStore(str(tmp_path / "store"))
    ps = PrefixStore(store, namespace="chaos-test")
    like = {"k": np.arange(8, dtype=np.float32).reshape(2, 4),
            "v": np.ones((2, 4), np.float32)}
    page = ps.child_key(ps.root_key(), [1, 2, 3])
    ps.publish(page, like)
    got = ps.fetch(page, like)
    assert got is not None and np.array_equal(got["k"], like["k"])
    clk = VirtualClock()
    fleet = SpotFleet(FleetFile(startup_seconds=0.0), clock=clk,
                      app_name="Blob")
    chaos = ChaosMonkey(fleet, clk, store=store, events=[
        ChaosEvent(kind="truncate_blob", at=0.0, victim=0)
    ])
    assert [r.kind for r in chaos.tick()] == ["truncate_blob"]
    assert chaos.counters["blobs_truncated"] == 1
    assert ps.fetch(page, like) is None  # hydration degrades, never raises


# ------------------------------------------------------------- DLQ redrive
def test_dead_letter_redrive_after_revocation_churn(tmp_path):
    """A revocation drain requeues claimed requests WITHOUT refunding
    their receive budget, so churn still marches poison work to the DLQ
    (here: max_receive_count=1, so one drain condemns every in-flight
    request) — and the lease is NOT wedged by them.  An operator redrive
    plus rerun then serves everything byte-identically."""
    reset_serve_state()
    clk = VirtualClock()

    def runtime(queue_name):
        cfg = DSConfig(
            app_name="Dlq", payload="distributed-serve",
            cluster_machines=1, tasks_per_machine=1,
            machine_type=["sim.large"], machine_price=1.0,
            sqs_message_visibility=240.0, check_if_done=False,
            idle_alarm_seconds=100_000.0, monitor_poll_seconds=TICK,
            sqs_queue_name=queue_name,
        )
        rt = DSRuntime(cfg, store_root=str(tmp_path / "store"), clock=clk)
        rt.setup()
        return rt

    prompts = [[1, 2, 3], [4, 5], [7, 8, 9, 10]]
    rq_path = str(tmp_path / "requests.sqlite")
    rq = DurableQueue(rq_path, default_visibility=240.0,
                      max_receive_count=1, clock=clk)
    rq.send_batch([
        {"uid": f"q{i}", "prompt": p, "max_new_tokens": 6}
        for i, p in enumerate(prompts)
    ])
    job = {
        "arch": "ds-paper-100m", "arch_overrides": "reduced",
        "max_new_tokens": 6, "max_len": 32, "max_batch": 2,
        "prefill_chunk": 4,
        "request_queue": rq_path,
        "expected_requests": len(prompts),
        "output_prefix": "serve/dlq",
        "stream_slice_ticks": 1,  # nothing completes before the drain
        "stream_idle_polls": 4,
        "request_visibility": 240.0,
        "request_max_receive_count": 1,
    }
    rt = runtime("DlqJobs1")
    rt.submit_job(JobFile(shared=dict(job), groups=[{}]))
    rt.start_cluster(FleetFile(startup_seconds=TICK))
    # one explicit notice against the (only) serving instance, with two
    # ticks of warning so the drain runs before the machine dies
    chaos = ChaosMonkey(rt.fleet, clk, events=[
        ChaosEvent(kind="revoke", at=2.5 * TICK, victim=0,
                   notice_seconds=2 * TICK)
    ])
    summary = SimRunner(rt, tick_seconds=TICK, chaos=chaos).run(max_ticks=80)
    # the replacement lease DLQ'd the poisoned requests at claim time and
    # exited through the idle path — the fleet tore down instead of
    # wedging on work that can never complete
    assert summary.jobs_done >= 1, f"{summary}"
    assert rq.counts() == {"visible": 0, "in_flight": 0, "dead": 3}
    assert _served_outputs(rt.store, "serve/dlq") == {}

    # operator redrive: receive budgets reset, messages visible again
    assert rq.redrive_dead_letters() == 3
    assert rq.counts()["visible"] == 3

    # rerun against the SAME output prefix with a healthy receive budget
    reset_serve_state()
    rt2 = runtime("DlqJobs2")
    job2 = dict(job, request_max_receive_count=3)
    rt2.submit_job(JobFile(shared=job2, groups=[{}]))
    rt2.start_cluster(FleetFile(startup_seconds=TICK))
    summary2 = SimRunner(rt2, tick_seconds=TICK).run(max_ticks=120)
    assert summary2.jobs_done == 1, f"{summary2}"
    assert rq.counts() == {"visible": 0, "in_flight": 0, "dead": 0}
    got = _served_outputs(rt2.store, "serve/dlq")
    want = _reference_outputs(job, prompts, 6)
    assert got == want, "redriven requests diverged from the oracle"


# ------------------------------------------------- cluster regressions
def test_deregister_service_drops_its_tasks_and_reregister_counts_live():
    clk = VirtualClock()
    fleet = SpotFleet(FleetFile(startup_seconds=0.0), clock=clk,
                      app_name="App")
    fleet.request(target_capacity=2, bid=1.0, machine_types=["sim.large"])
    fleet.tick()
    cluster = ECSCluster()

    def td():
        # a FRESH definition object each time: placement and teardown
        # must match by config equality, not object identity
        return TaskDefinition(family="AppTask", payload="p",
                              cpu_shares=1024, memory_mb=1024,
                              docker_cores=1)

    cluster.register_service(Service(name="AppService",
                                     task_definition=td(), desired_count=2))
    assert len(cluster.place("AppService", fleet, clk.now())) == 2
    # re-registering (equal config, new TaskDefinition object) must see
    # its live tasks and place nothing more
    cluster.register_service(Service(name="AppService",
                                     task_definition=td(), desired_count=2))
    assert cluster.place("AppService", fleet, clk.now()) == []
    assert len(cluster.tasks) == 2
    # deregistration drops the task records too (the family is "AppTask"
    # while the service is "AppService": a name-prefix match never fires)
    cluster.deregister_service("AppService")
    assert cluster.tasks == {}
    cluster.deregister_service("AppService")  # idempotent


# ---------------------------------------------------------------- backoff
def test_backoff_delay_is_capped_exponential_with_stable_jitter():
    # deterministic: same (key, attempt) always yields the same delay
    assert (backoff_delay(5.0, 3, cap=240.0, key="k")
            == backoff_delay(5.0, 3, cap=240.0, key="k"))
    # distinct keys de-synchronize (the anti-thundering-herd property)
    assert (backoff_delay(5.0, 3, cap=240.0, key="k")
            != backoff_delay(5.0, 3, cap=240.0, key="other"))
    # jitter=0: exact doubling from the base, capped at the visibility
    assert backoff_delay(5.0, 1, cap=240.0, key="k", jitter=0) == 5.0
    assert backoff_delay(5.0, 2, cap=240.0, key="k", jitter=0) == 10.0
    assert backoff_delay(5.0, 4, cap=240.0, key="k", jitter=0) == 40.0
    assert backoff_delay(5.0, 10, cap=240.0, key="k", jitter=0) == 240.0
    # attempt < 1 clamps to the first step (receive_count starts at 1)
    assert backoff_delay(5.0, 0, cap=240.0, key="k", jitter=0) == 5.0
    # jitter only ever shrinks the delay, never past the schedule
    for attempt in range(1, 9):
        d = backoff_delay(5.0, attempt, cap=240.0, key="k")
        assert 0.0 < d <= min(240.0, 5.0 * 2 ** (attempt - 1))


def test_stable_key_is_content_addressed_across_redeliveries():
    body = {"uid": "q0", "prompt": [1, 2, 3]}
    m1 = Message(id="uuid-a", body=dict(body), receipt="r1", receive_count=1)
    m2 = Message(id="uuid-b", body=dict(body), receipt="r2", receive_count=3)
    # same content => same jitter key, even across fresh message ids
    # (ids are uuid4 — keying on them would break schedule replay)
    assert _stable_key(m1) == _stable_key(m2)
    m3 = Message(id="uuid-c", body={"uid": "q1", "prompt": [9]},
                 receipt="r3", receive_count=1)
    assert _stable_key(m1) != _stable_key(m3)

"""Dry-run machinery guard: build+lower+compile representative cells on a
small forced-device mesh (subprocess).  Catches sharding-spec regressions
without the cost of the full 512-device fleet."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import AxisType
from repro.launch.cells import build_cell, lower_cell
from repro.roofline.analysis import collective_bytes
from repro.roofline.hbm import hbm_traffic

mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
import dataclasses
from repro.configs import get_arch

CASES = [
    ("ds-paper-100m", "train_4k", dict(n_layers=2, d_model=64, n_heads=4, head_dim=16,
                                       n_kv_heads=2, d_ff=128, vocab_size=2048)),
    ("mixtral-8x7b", "decode_32k", dict(n_layers=2, d_model=64, n_heads=4, head_dim=16,
                                        n_kv_heads=2, moe_d_ff=128, n_experts=4,
                                        top_k=2, vocab_size=2048, sliding_window=256)),
    ("mamba2-1.3b", "long_500k", dict(n_layers=2, d_model=64, ssm_state=16,
                                      ssm_headdim=16, vocab_size=2048)),
]
for arch, shape, over in CASES:
    cfg = dataclasses.replace(get_arch(arch), **over)
    cell = build_cell(arch, shape, mesh, cfg_override=cfg)
    compiled = lower_cell(cell, mesh).compile()
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    st = collective_bytes(txt, 8)
    hb = hbm_traffic(txt)
    assert ma.temp_size_in_bytes >= 0 and hb.bytes_jnp > 0
    print(f"CELL-OK {arch} {shape} colls={sum(st.count_by_op.values())}")
print("ALL-OK")
"""


def test_cells_lower_on_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=560,
    )
    assert "ALL-OK" in res.stdout, f"stdout={res.stdout}\nstderr={res.stderr[-3000:]}"

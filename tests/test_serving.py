"""Serving engine: continuous batching correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine


def _setup(seed=0):
    cfg = reduced(get_arch("ds-paper-100m"))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _greedy_reference(model, params, prompt, max_new, max_len):
    """Sequential single-request greedy decode as the oracle."""
    cache = model.init_cache(1, max_len)
    toks = list(prompt)
    out = []
    logits = None
    for pos in range(len(prompt) + max_new - 1):
        t = toks[pos] if pos < len(toks) else out[-1]
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[t]], jnp.int32), jnp.asarray([pos], jnp.int32)
        )
        if pos >= len(prompt) - 1:
            nxt = int(jnp.argmax(logits[0, 0, : model.cfg.vocab_size]))
            out.append(nxt)
            if len(out) >= max_new:
                break
    return out


def test_engine_matches_sequential_reference():
    cfg, model, params = _setup()
    prompts = [[1, 2, 3], [9, 8, 7, 6], [42]]
    max_new = 5
    refs = [_greedy_reference(model, params, p, max_new, 32) for p in prompts]

    engine = ServeEngine(model, params, max_batch=2, max_len=32)
    engine.submit([Request(uid=f"r{i}", prompt=p, max_new_tokens=max_new)
                   for i, p in enumerate(prompts)])
    finished = engine.run_to_completion()
    assert len(finished) == 3
    by_uid = {r.uid: r.output for r in finished}
    for i, ref in enumerate(refs):
        assert by_uid[f"r{i}"] == ref, f"request {i}: {by_uid[f'r{i}']} != {ref}"


def test_engine_continuous_refill_keeps_batch_full():
    """More requests than slots: slots must be reused as requests finish."""
    cfg, model, params = _setup(1)
    engine = ServeEngine(model, params, max_batch=2, max_len=24)
    reqs = [Request(uid=f"r{i}", prompt=[i + 1], max_new_tokens=3) for i in range(5)]
    engine.submit(reqs)
    finished = engine.run_to_completion()
    assert len(finished) == 5
    assert all(len(r.output) == 3 for r in finished)


def test_engine_ragged_lengths_isolated_rows():
    """Rows at different positions must not corrupt each other: results
    must be independent of co-scheduled requests."""
    cfg, model, params = _setup(2)
    long_p = [3, 1, 4, 1, 5, 9, 2, 6]
    short_p = [2, 7]
    solo = ServeEngine(model, params, max_batch=1, max_len=32)
    solo.submit([Request(uid="solo", prompt=long_p, max_new_tokens=4)])
    want = solo.run_to_completion()[0].output

    mixed = ServeEngine(model, params, max_batch=2, max_len=32)
    mixed.submit([
        Request(uid="long", prompt=long_p, max_new_tokens=4),
        Request(uid="short", prompt=short_p, max_new_tokens=6),
    ])
    got = {r.uid: r.output for r in mixed.run_to_completion()}
    assert got["long"] == want

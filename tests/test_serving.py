"""Serving engine: continuous batching correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine


def _setup(seed=0):
    cfg = reduced(get_arch("ds-paper-100m"))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _greedy_reference(model, params, prompt, max_new, max_len):
    """Sequential single-request greedy decode as the oracle."""
    cache = model.init_cache(1, max_len)
    toks = list(prompt)
    out = []
    logits = None
    for pos in range(len(prompt) + max_new - 1):
        t = toks[pos] if pos < len(toks) else out[-1]
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[t]], jnp.int32), jnp.asarray([pos], jnp.int32)
        )
        if pos >= len(prompt) - 1:
            nxt = int(jnp.argmax(logits[0, 0, : model.cfg.vocab_size]))
            out.append(nxt)
            if len(out) >= max_new:
                break
    return out


def test_engine_matches_sequential_reference():
    cfg, model, params = _setup()
    prompts = [[1, 2, 3], [9, 8, 7, 6], [42]]
    max_new = 5
    refs = [_greedy_reference(model, params, p, max_new, 32) for p in prompts]

    engine = ServeEngine(model, params, max_batch=2, max_len=32)
    engine.submit([Request(uid=f"r{i}", prompt=p, max_new_tokens=max_new)
                   for i, p in enumerate(prompts)])
    finished = engine.run_to_completion()
    assert len(finished) == 3
    by_uid = {r.uid: r.output for r in finished}
    for i, ref in enumerate(refs):
        assert by_uid[f"r{i}"] == ref, f"request {i}: {by_uid[f'r{i}']} != {ref}"


def test_engine_continuous_refill_keeps_batch_full():
    """More requests than slots: slots must be reused as requests finish."""
    cfg, model, params = _setup(1)
    engine = ServeEngine(model, params, max_batch=2, max_len=24)
    reqs = [Request(uid=f"r{i}", prompt=[i + 1], max_new_tokens=3) for i in range(5)]
    engine.submit(reqs)
    finished = engine.run_to_completion()
    assert len(finished) == 5
    assert all(len(r.output) == 3 for r in finished)


def _ragged_requests(max_new=4, temperature=0.0):
    """Mixed lengths + more requests than slots => mid-stream refills."""
    prompts = [[1, 2, 3, 4, 5, 6, 7], [9, 8], [42], [5, 4, 3, 2, 1], [17, 23, 31]]
    return [
        Request(uid=f"r{i}", prompt=list(p), max_new_tokens=max_new,
                temperature=temperature)
        for i, p in enumerate(prompts)
    ]


def test_fused_matches_grouped_token_for_token():
    """Tentpole parity: fused chunked prefill + single-dispatch vectorized
    decode must produce token-for-token identical output to the (fixed)
    per-position-group path, greedy AND seeded temperature, on a ragged
    batch with mid-stream refills — while dispatching strictly less."""
    cfg, model, params = _setup()
    for temperature in (0.0, 0.7):
        fused = ServeEngine(model, params, max_batch=2, max_len=32,
                            prefill_chunk=4, rng_seed=7)
        fused.submit(_ragged_requests(temperature=temperature))
        fused.run_to_completion()
        grouped = ServeEngine(model, params, max_batch=2, max_len=32,
                              dispatch_mode="grouped", rng_seed=7)
        grouped.submit(_ragged_requests(temperature=temperature))
        grouped.run_to_completion()
        got_f = {r.uid: r.output for r in fused.finished}
        got_g = {r.uid: r.output for r in grouped.finished}
        assert got_f == got_g, f"temperature={temperature}: {got_f} != {got_g}"
        assert fused._use_prefill, "fused engine must take the prefill path"
        assert fused.dispatches < grouped.dispatches, (
            fused.dispatches, grouped.dispatches
        )


def test_single_decode_dispatch_per_tick_any_position_mix():
    """Acceptance: ServeEngine.step issues exactly ONE jitted decode
    dispatch per tick regardless of slot-position raggedness, and prompt
    ingestion consumes >= chunk-size tokens per prefill dispatch."""
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4)
    engine.submit(_ragged_requests(max_new=6))
    saw_ragged_tick = False
    while engine.pending or any(s.req for s in engine.slots):
        before_decode = engine.decode_dispatches
        before_prefill = engine.prefill_dispatches
        before_ingested = engine.prompt_tokens_ingested
        engine.step()
        active_pos = {s.pos for s in engine.slots if s.req is not None}
        if len(active_pos) > 1:
            saw_ragged_tick = True
        assert engine.decode_dispatches - before_decode <= 1, (
            "more than one decode dispatch in a tick"
        )
        new_prefills = engine.prefill_dispatches - before_prefill
        if new_prefills:
            ingested = engine.prompt_tokens_ingested - before_ingested
            # every prefill dispatch moves a whole chunk per ingesting row
            # (the final slice of a prompt may be shorter than the chunk)
            assert ingested > new_prefills, (
                f"prefill ingested {ingested} tokens in {new_prefills} dispatches"
            )
    assert saw_ragged_tick, "scenario never became ragged — weak test"
    assert len(engine.finished) == 5


def test_fused_prefill_matches_sequential_reference_ssm():
    """SSM/hybrid recurrent state through chunked prefill (conv window
    hand-off + masked-dt SSD) must reproduce the sequential oracle."""
    from repro.configs import get_arch as _ga

    for arch in ("mamba2-1.3b", "zamba2-1.2b"):
        cfg = reduced(_ga(arch))
        model = Model(cfg, ModelRuntime())
        params = model.init(jax.random.PRNGKey(3))
        prompts = [[1, 2, 3, 4, 5, 6, 7], [9, 8], [42]]
        refs = [_greedy_reference(model, params, p, 3, 32) for p in prompts]
        engine = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4)
        engine.submit([Request(uid=f"r{i}", prompt=list(p), max_new_tokens=3)
                       for i, p in enumerate(prompts)])
        finished = engine.run_to_completion()
        assert engine._use_prefill
        by_uid = {r.uid: r.output for r in finished}
        for i, ref in enumerate(refs):
            assert by_uid[f"r{i}"] == ref, f"{arch} request {i}"


def test_refill_resets_correct_row_for_equal_requests():
    """Regression: _Slot/Request are value-comparing dataclasses, so the
    seed's ``slots.index(slot)`` could zero the WRONG row when two slots
    became equal (e.g. identical requests refilled mid-stream)."""
    cfg, model, params = _setup(4)
    prompt = [7, 7, 7]
    solo = ServeEngine(model, params, max_batch=1, max_len=32)
    solo.submit([Request(uid="solo", prompt=list(prompt), max_new_tokens=3)])
    want = solo.run_to_completion()[0].output

    engine = ServeEngine(model, params, max_batch=2, max_len=32)
    engine.submit([Request(uid=f"r{i}", prompt=list(prompt), max_new_tokens=3)
                   for i in range(4)])  # identical => value-equal slots
    finished = engine.run_to_completion()
    assert len(finished) == 4
    for r in finished:
        assert r.output == want, f"{r.uid}: {r.output} != {want}"


def test_host_fallback_sampler_is_stable_for_large_logits():
    """Satellite: the host sampler must subtract the max before exp —
    ``np.exp(lg / T)`` overflowed for large-magnitude logits."""
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, max_batch=1, max_len=16,
                         sample_on_device=False)
    lg = np.array([5000.0, 4999.0, -5000.0, 0.0], np.float32)
    with np.errstate(over="raise", invalid="raise"):
        tok = engine._host_sample(lg, temperature=0.5)
    assert tok in (0, 1)  # mass concentrates on the two large logits
    assert engine._host_sample(lg, temperature=0.0) == 0  # greedy unaffected


def test_engine_host_sampling_mode_completes():
    """sample_on_device=False keeps the old host round-trip working."""
    cfg, model, params = _setup(5)
    engine = ServeEngine(model, params, max_batch=2, max_len=32,
                         prefill_chunk=4, sample_on_device=False)
    engine.submit(_ragged_requests(max_new=3, temperature=0.5))
    finished = engine.run_to_completion()
    assert len(finished) == 5
    assert all(len(r.output) == 3 for r in finished)


def test_engine_ragged_lengths_isolated_rows():
    """Rows at different positions must not corrupt each other: results
    must be independent of co-scheduled requests."""
    cfg, model, params = _setup(2)
    long_p = [3, 1, 4, 1, 5, 9, 2, 6]
    short_p = [2, 7]
    solo = ServeEngine(model, params, max_batch=1, max_len=32)
    solo.submit([Request(uid="solo", prompt=long_p, max_new_tokens=4)])
    want = solo.run_to_completion()[0].output

    mixed = ServeEngine(model, params, max_batch=2, max_len=32)
    mixed.submit([
        Request(uid="long", prompt=long_p, max_new_tokens=4),
        Request(uid="short", prompt=short_p, max_new_tokens=6),
    ])
    got = {r.uid: r.output for r in mixed.run_to_completion()}
    assert got["long"] == want

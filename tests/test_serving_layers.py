"""Layered serving engine: allocator/scheduler invariants under
randomized interleaved admission, completion, and preemption (seeded
``random``, not hypothesis — the env lacks it), scheduler policy knobs
(drain refill, prefill token budget), latency accounting, and the
cross-host prefix store (publish on one engine, hydrate on another)."""

import os

os.environ.setdefault("DS_DEBUG_INVARIANTS", "1")

import random

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.storage import ObjectStore
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_store import PrefixStore


def _setup(seed=0):
    cfg = reduced(get_arch("ds-paper-100m"))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _random_requests(rng: random.Random, n: int):
    """Mixed workload over two shared one-page prefixes plus fully
    random prompts, random tails/budgets, seeded temperature."""
    prefixes = [[100 + j for j in range(8)], [200 + j for j in range(8)]]
    reqs = []
    for i in range(n):
        kind = rng.randrange(3)
        if kind < 2:  # shared-prefix request
            p = list(prefixes[kind]) + [rng.randrange(1, 99) for _ in range(rng.randrange(0, 5))]
        else:  # cold request
            p = [rng.randrange(1, 99) for _ in range(rng.randrange(1, 13))]
        reqs.append(Request(uid=f"r{i}", prompt=p,
                            max_new_tokens=rng.randrange(1, 5),
                            temperature=0.5))
    return reqs


def _check_allocator_invariants(eng: ServeEngine):
    ps = eng.page_size
    # refcount = slots mapping the page + 1 if the radix cache indexes it
    cached = eng.prefix.pages()
    assert len(set(cached)) == len(cached), "page indexed twice in the radix tree"
    cached_set = set(cached)
    holders = {pid: [] for pid in range(eng.n_pages)}
    for row, pages in enumerate(eng._slot_pages):
        for j, pid in enumerate(pages):
            holders[pid].append((row, j))
    for pid in range(eng.n_pages):
        want = len(holders[pid]) + (1 if pid in cached_set else 0)
        assert eng._page_refs[pid] == want, (
            f"page {pid}: refcount {eng._page_refs[pid]} != holders {want}"
        )
    # free list and referenced pages partition the pool
    assert sorted(eng._free_pages + [p for p in range(eng.n_pages)
                                     if eng._page_refs[p] > 0]) == list(range(eng.n_pages))
    assert eng.pages_in_use == sum(1 for p in range(eng.n_pages) if eng._page_refs[p] > 0)
    # no page aliased across UNRELATED slots: every multi-slot page must
    # back the same page-aligned prompt chunk in each mapping slot
    for pid, maps in holders.items():
        if len(maps) < 2:
            continue
        chunks = []
        for row, j in maps:
            req = eng.slots[row].req
            assert req is not None, f"parked slot {row} still maps page {pid}"
            assert (j + 1) * ps <= len(req.prompt), (
                f"page {pid} shared inside slot {row}'s generated region"
            )
            chunks.append(tuple(req.prompt[j * ps:(j + 1) * ps]))
        assert len(set(chunks)) == 1, (
            f"page {pid} aliased across unrelated slots: {chunks}"
        )


def test_randomized_interleaving_invariants_and_one_shot_parity():
    """Drive the paged prefix-sharing engine through a seeded-random
    interleaving of submits and ticks on a pool tight enough to force
    eviction and preemption; allocator invariants must hold at every
    tick, the drain state must return to the cached-prefix baseline, and
    outputs must be byte-identical to the one-shot static dense batch."""
    cfg, model, params = _setup()
    preempted_somewhere = False
    for seed in (0, 1):
        rng = random.Random(seed)
        reqs = _random_requests(rng, 10)
        # one-shot static-batch oracle: everything submitted up front
        dense = ServeEngine(model, params, max_batch=3, max_len=32,
                            prefill_chunk=4, rng_seed=9)
        dense.submit([Request(uid=r.uid, prompt=list(r.prompt),
                              max_new_tokens=r.max_new_tokens,
                              temperature=r.temperature) for r in reqs])
        dense.run_to_completion()
        want = {r.uid: r.output for r in dense.finished}

        eng = ServeEngine(model, params, max_batch=3, max_len=32,
                          prefill_chunk=4, rng_seed=9,
                          cache_mode="paged", page_size=8, total_pages=5)
        queue = list(reqs)
        steps = 0
        while (queue or eng.pending or eng.scheduler.has_active()) and steps < 500:
            if queue and rng.random() < 0.6:
                eng.submit([queue.pop(0) for _ in range(min(len(queue),
                                                            rng.randrange(1, 4)))])
            eng.step()
            steps += 1
            _check_allocator_invariants(eng)
        assert not queue and not eng.pending
        got = {r.uid: r.output for r in eng.finished}
        assert got == want, f"seed {seed}: staggered paged != one-shot dense"
        # drain baseline: only radix-cached pages remain, each at ref 1
        cached = sorted(eng.prefix.pages())
        assert eng.pages_in_use == len(cached)
        assert all(eng._page_refs[p] == 1 for p in cached)
        preempted_somewhere |= (eng.preemptions + eng.prefix_evictions) > 0
    assert preempted_somewhere, "pool never came under pressure — weak test"


def test_drain_refill_policy_admits_only_into_empty_batch():
    """refill_policy='drain' (the benchmark baseline) must not admit
    while any slot is active, and still complete everything correctly."""
    cfg, model, params = _setup(1)
    # ragged budgets: slots free at different ticks, so continuous refill
    # genuinely beats waiting for the batch to drain
    reqs = [Request(uid=f"r{i}", prompt=[i + 1, i + 2],
                    max_new_tokens=2 + (i % 3) * 2)
            for i in range(5)]
    cont = ServeEngine(model, params, max_batch=2, max_len=32)
    cont.submit([Request(uid=r.uid, prompt=list(r.prompt),
                         max_new_tokens=r.max_new_tokens) for r in reqs])
    cont.run_to_completion()
    want = {r.uid: r.output for r in cont.finished}

    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      refill_policy="drain")
    eng.submit(reqs)
    while eng.pending or eng.scheduler.has_active():
        active_before = sum(1 for s in eng.slots if s.req is not None)
        admitted_before = eng.stats.admissions
        eng.step()
        if active_before > 0:
            assert eng.stats.admissions == admitted_before, (
                "drain policy admitted into a non-empty batch"
            )
    assert {r.uid: r.output for r in eng.finished} == want
    # drain waits for the whole batch: strictly more ticks than continuous
    assert eng.stats.ticks > cont.stats.ticks


def test_prefill_token_budget_interleaves_and_stays_token_parity():
    """A finite per-tick prefill budget spreads prompt ingestion over
    ticks (more prefill dispatches, mid-prefill rows sit decode out) but
    must not change a single emitted token."""
    cfg, model, params = _setup(2)
    def reqs():
        return [Request(uid=f"r{i}", prompt=list(range(1 + i, 13 + i)),
                        max_new_tokens=3) for i in range(3)]
    free = ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=8)
    free.submit(reqs())
    free.run_to_completion()
    budgeted = ServeEngine(model, params, max_batch=2, max_len=32,
                           prefill_chunk=8, prefill_token_budget=4)
    budgeted.submit(reqs())
    budgeted.run_to_completion()
    assert ({r.uid: r.output for r in free.finished}
            == {r.uid: r.output for r in budgeted.finished})
    assert budgeted.prefill_dispatches > free.prefill_dispatches
    assert budgeted.prompt_tokens_ingested == free.prompt_tokens_ingested


def test_prefill_budget_mid_prefill_row_cannot_corrupt_shared_page():
    """Regression: a full-prompt radix hit stranded mid-prefill by the
    tick budget keeps a LIVE page table while sitting the decode out;
    the batch-wide decode write at its position must be copy-on-write
    privatized, or it lands garbage KV in the published shared page and
    every later request stitching that prefix reads it."""
    cfg, model, params = _setup(5)
    PRE16 = [11, 12, 13, 14, 15, 16, 17, 18, 21, 22, 23, 24, 25, 26, 27, 28]
    def drive(eng):
        # warm publishes the prefix, a gets decode-ready ALONE, then b+c
        # are admitted together: the 1-token tick budget leaves one of
        # them stranded mid-prefill on ticks where a's decode dispatches.
        # hazard = a decode ran while a stranded row's next write position
        # sat inside a page someone else still references
        hazard = False
        eng.submit([Request(uid="warm", prompt=list(PRE16), max_new_tokens=2)])
        eng.run_to_completion()
        eng.submit([Request(uid="a", prompt=[1, 2], max_new_tokens=12)])
        for _ in range(5):
            eng.step()
        eng.submit([
            Request(uid="b", prompt=list(range(31, 43)), max_new_tokens=2),
            Request(uid="c", prompt=list(PRE16), max_new_tokens=3),
        ])
        while eng.pending or eng.scheduler.has_active():
            before = eng.decode_dispatches
            eng.step()
            if eng.cache_mode != "paged" or eng.decode_dispatches == before:
                continue
            for s in eng.slots:
                # a decode ran while this row, mid-prefill, had its next
                # write position inside its stitched prefix — the exact
                # window where an unprivatized write corrupts the cache
                if (s.req is not None and s.remaining_prompt
                        and s.pos < s.hit_tokens):
                    hazard = True
        eng.submit([Request(uid="d", prompt=PRE16 + [90, 91], max_new_tokens=3)])
        eng.run_to_completion()
        return {r.uid: r.output for r in eng.finished}, hazard

    want, _ = drive(ServeEngine(model, params, max_batch=3, max_len=32,
                                prefill_chunk=8))
    eng = ServeEngine(model, params, max_batch=3, max_len=32, prefill_chunk=8,
                      prefill_token_budget=1,
                      cache_mode="paged", page_size=8, total_pages=16)
    got, hazard = drive(eng)
    assert hazard, "scenario never stranded a stitched row across a decode"
    assert got == want
    # the hazard was real: c was stitched into the published pages and
    # the decode ticked while it sat mid-prefill
    assert eng.prompt_tokens_skipped > 0
    assert eng.cow_copies > 0


def test_preempted_attempt_latency_samples_are_voided():
    """A preempted request's aborted queue-wait/TTFT samples must not
    survive into the percentiles — only the successful attempts count,
    one pair per request."""
    cfg, model, params = _setup()
    reqs = [Request(uid=f"r{i}", prompt=[10 + i, 20 + i, 30 + i, 40 + i,
                                         50 + i, 60 + i, 70 + i],
                    max_new_tokens=6, temperature=0.5) for i in range(4)]
    tight = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=5,
                        cache_mode="paged", page_size=8, total_pages=3)
    tight.submit(reqs)
    tight.run_to_completion()
    assert tight.preemptions > 0, "scenario never forced a preemption"
    t = tight.scheduler.timing()
    assert t["queue_wait_ticks"]["n"] == 4
    assert t["ttft_ticks"]["n"] == 4
    # the voided slots are still in the lists (index-stable windowing)
    assert len(tight.scheduler.queue_waits) > 4
    assert None in tight.scheduler.queue_waits


def test_prefill_budget_fair_share_does_not_starve_short_prompts():
    """Regression: lowest-index-first budget distribution let a long
    prompt in a lower row hold a short prompt hostage for its whole
    ingestion; the fair-share planner must let the short request finish
    while the long prompt is still being ingested."""
    cfg, model, params = _setup(6)
    eng = ServeEngine(model, params, max_batch=2, max_len=64, prefill_chunk=8,
                      prefill_token_budget=4)
    eng.submit([
        Request(uid="long", prompt=list(range(1, 41)), max_new_tokens=2),
        Request(uid="short", prompt=[91, 92, 93, 94], max_new_tokens=2),
    ])
    eng.run_to_completion()
    assert eng.finished[0].uid == "short", (
        "short request starved behind the long prompt's budget"
    )
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid["short"].first_token_tick < by_uid["long"].first_token_tick


def test_prefill_budget_refused_where_it_would_corrupt_or_noop():
    """A finite budget holds rows mid-prefill across decode ticks: on
    recurrent state the batch-wide dispatch would corrupt the held row's
    recurrence, and without the fused prefill path the knob is inert —
    both must be refused at construction, like grouped mode on SSM."""
    import pytest

    cfg = reduced(get_arch("mamba2-1.3b"))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent"):
        ServeEngine(model, params, max_batch=2, max_len=32, prefill_chunk=4,
                    prefill_token_budget=4)
    cfg2, model2, params2 = _setup()
    with pytest.raises(ValueError, match="fused prefill"):
        ServeEngine(model2, params2, max_batch=2, max_len=32,
                    dispatch_mode="grouped", prefill_token_budget=4)
    with pytest.raises(ValueError, match="positive"):
        ServeEngine(model2, params2, max_batch=2, max_len=32,
                    prefill_token_budget=0)


def test_trim_samples_bounds_lists_and_remaps_slot_indices():
    from repro.serving.scheduler import RequestScheduler
    from repro.serving.types import EngineStats

    sched = RequestScheduler(2, EngineStats())
    sched.queue_waits = list(range(10))
    sched.ttfts = list(range(5))
    sched.slots[0].wait_idx = 8   # survives the trim -> remapped
    sched.slots[1].wait_idx = 2   # falls off the front -> -1
    sched.slots[0].ttft_idx = 4
    sched.trim_samples(4)
    assert sched.queue_waits == [6, 7, 8, 9]
    assert sched.ttfts == [1, 2, 3, 4]
    assert sched.slots[0].wait_idx == 2 and sched.slots[1].wait_idx == -1
    assert sched.slots[0].ttft_idx == 3
    # the cumulative dropped offsets advance with the trim
    assert sched.waits_dropped == 6 and sched.ttfts_dropped == 1


def test_timing_marks_survive_trim():
    """Regression: a measurement window recorded before trim_samples must
    keep addressing the same samples afterwards.  sample_marks() returns
    absolute sample ids and timing() windows by them, so the per-loop
    trim in the streaming lease cannot silently slide the window."""
    from repro.serving.scheduler import RequestScheduler
    from repro.serving.types import EngineStats

    sched = RequestScheduler(2, EngineStats())
    sched.queue_waits = list(range(10))
    sched.ttfts = list(range(8))
    marks = sched.sample_marks()
    assert marks == {"waits_since": 10, "ttfts_since": 8}
    sched.queue_waits += [100, 200]
    sched.ttfts += [300]
    before = sched.timing(**marks)
    assert before["queue_wait_ticks"]["n"] == 2
    assert before["queue_wait_ticks"]["max"] == 200.0
    assert before["ttft_ticks"]["n"] == 1
    # trim away most of the history; the post-mark samples survive and
    # the window must be unchanged (the old length-relative semantics
    # would have summarized pre-mark samples here)
    sched.trim_samples(3)
    assert sched.timing(**marks) == before
    # marks recorded AFTER a trim keep working too
    marks2 = sched.sample_marks()
    sched.queue_waits.append(7)
    t = sched.timing(**marks2)
    assert t["queue_wait_ticks"]["n"] == 1 and t["queue_wait_ticks"]["max"] == 7.0
    # a window whose samples were entirely trimmed away degrades to the
    # retained suffix instead of crashing or going negative
    assert sched.timing(0, 0)["queue_wait_ticks"]["n"] == 4


class _CacheStub:
    """Minimal KVCacheManager stand-in for scheduler-only tests."""

    def __init__(self):
        self.released = []

    def can_admit(self):
        return True

    def reset_row(self, row):
        pass

    def stitch_prefix(self, row, slot):
        pass

    def release_slot(self, row):
        self.released.append(row)


def test_preempt_for_never_victimizes_the_requester():
    """Regression: pool-exhaustion escalation must never select the
    requesting row as victim — preempting the requester mid-allocation
    released the pages it was assembling and handed its own row back to
    the allocator.  The victim is the youngest slot strictly younger
    than the requester; when the requester is itself the youngest,
    preempt_for answers YIELD without touching anything (the cache
    manager requeues the row only after its allocation loop unwinds)."""
    from repro.serving.scheduler import RequestScheduler
    from repro.serving.types import EngineStats, Request

    sched = RequestScheduler(3, EngineStats())
    sched.cache = _CacheStub()
    sched.submit([Request(uid=f"r{i}", prompt=[1, 2]) for i in range(3)])
    sched.begin_tick()  # admits r0/r1/r2 into rows 0/1/2 (seq order)
    assert all(s.req is not None for s in sched.slots)
    # newest admission (row 2) triggers the escalation: preempt_for must
    # NOT preempt it (nor any older slot) — it answers YIELD and leaves
    # every slot untouched
    assert sched.preempt_for(2) == RequestScheduler.YIELD
    assert all(s.req is not None for s in sched.slots)
    assert sched.stats.preemptions == 0 and not sched.pending
    # escalation from the OLDEST slot preempts the youngest other
    assert sched.preempt_for(0) == 2
    assert sched.pending and sched.pending[0].uid == "r2"
    assert sched.preempt_for(0) == 1
    # nothing younger left active: requester row 0 must not preempt
    # itself; with no other slot active at all the answer is None (the
    # allocator raises — a lone request that cannot fit fails loudly)
    assert sched.preempt_for(0) is None
    assert sched.slots[0].req is not None and sched.stats.preemptions == 2


def test_pool_exhaustion_from_newest_admission_yields_cleanly():
    """End-to-end regression for the same bug: a late-arriving request
    whose prefill exhausts the pool while it is the youngest slot used
    to be preempted by preempt_for MID-allocation.  It must now yield at
    the clean seam instead — preempt_for never returns the requester,
    the requeue happens after the allocation loop unwinds — while the
    older slot keeps its pages (age priority, no inversion livelock);
    outputs stay byte-identical to the dense engine."""
    cfg, model, params = _setup(7)
    def drive(eng):
        # "old" runs alone for a few ticks (its 8 total tokens fit one
        # page), then "new" arrives with a 20-token prompt whose
        # single-tick chunked prefill wants 3 pages — exhausting the
        # 3-page pool while "new" is the youngest active slot
        eng.submit([Request(uid="old", prompt=[1, 2], max_new_tokens=6,
                            temperature=0.5)])
        for _ in range(2):
            eng.step()
        eng.submit([Request(uid="new", prompt=list(range(10, 30)),
                            max_new_tokens=4, temperature=0.5)])
        eng.run_to_completion(max_steps=200)
        return {r.uid: r.output for r in eng.finished}

    dense = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=8, rng_seed=5)
    want = drive(dense)
    tight = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=8, rng_seed=5,
                        cache_mode="paged", page_size=8, total_pages=3)
    preempted, escalations = [], []
    orig_preempt = tight.scheduler.preempt
    def preempt_spy(row):
        preempted.append(tight.slots[row].req.uid)
        orig_preempt(row)
    tight.scheduler.preempt = preempt_spy
    orig_for = tight.scheduler.preempt_for
    def for_spy(row):
        out = orig_for(row)
        escalations.append((row, out))
        return out
    tight.cache_mgr.preempt_for = for_spy
    got = drive(tight)
    assert escalations, "scenario never escalated to the scheduler"
    # preempt_for never selects the requesting row as victim
    assert all(victim != row for row, victim in escalations)
    # the newest slot yielded (requeued at the seam) at least once...
    from repro.serving.scheduler import RequestScheduler
    assert any(v == RequestScheduler.YIELD for _, v in escalations)
    assert "new" in preempted and tight.preemptions > 0
    # ...and never dragged the older slot down with it (age priority)
    assert "old" not in preempted, (
        "the newcomer inverted age priority by preempting the older slot"
    )
    assert got == want, "yield under exhaustion changed emitted tokens"
    assert len(got) == 2
    assert all(r >= 0 for r in tight._page_refs)


def test_prefix_store_refused_where_it_would_be_inert(tmp_path):
    """The cross-host store moves bytes only through the radix cache
    over paged pool pages; configurations where it could never act are
    refused, not silently accepted."""
    import pytest

    cfg, model, params = _setup()
    store = PrefixStore(ObjectStore(str(tmp_path / "s")), "ns")
    with pytest.raises(ValueError, match="prefix_store"):
        ServeEngine(model, params, max_batch=1, max_len=32,
                    prefix_store=store)  # dense cache
    with pytest.raises(ValueError, match="prefix_store"):
        ServeEngine(model, params, max_batch=1, max_len=32,
                    cache_mode="paged", page_size=8, total_pages=4,
                    prefix_cache=False, prefix_store=store)


def test_percentiles_nearest_rank_and_voided_samples():
    from repro.serving.types import percentiles

    p = percentiles([1, 2, 3, 4, 5, 6, 7, 8, 9, 500])
    assert p["n"] == 10
    assert p["p90"] == 9.0, "p90 of 10 samples is rank 9, not the max"
    assert p["p50"] == 5.0 and p["max"] == 500.0
    assert percentiles([None, 5, None])["n"] == 1
    assert percentiles([None])["n"] == 0


def test_scheduler_records_queue_wait_and_ttft():
    cfg, model, params = _setup(3)
    eng = ServeEngine(model, params, max_batch=1, max_len=32)
    eng.submit([Request(uid="a", prompt=[1, 2], max_new_tokens=2),
                Request(uid="b", prompt=[3, 4], max_new_tokens=2)])
    eng.run_to_completion()
    t = eng.scheduler.timing()
    assert t["queue_wait_ticks"]["n"] == 2 and t["ttft_ticks"]["n"] == 2
    # b waited behind a in the single slot
    assert t["queue_wait_ticks"]["max"] > t["queue_wait_ticks"]["p50"] or (
        eng.scheduler.queue_waits[1] > eng.scheduler.queue_waits[0]
    )
    for r in eng.finished:
        assert r.admit_tick >= 0 and r.first_token_tick >= r.admit_tick
        assert r.done_tick >= r.first_token_tick


# ------------------------------------------------- cross-host prefix store
PREFIX = [11, 12, 13, 14, 15, 16, 17, 18, 21, 22, 23, 24, 25, 26, 27, 28]


def test_prefix_store_publish_then_hydrate_across_engines(tmp_path):
    """Engine A (worker 1) publishes a completed prompt's pages to the
    object store; a COLD engine B (worker 2, empty radix cache) must
    hydrate them at admission, skip those prefill tokens, and still be
    byte-identical to a dense run."""
    cfg, model, params = _setup()
    store = ObjectStore(str(tmp_path / "store"))
    def mk(ns="ns"):
        return ServeEngine(model, params, max_batch=2, max_len=32,
                           prefill_chunk=4, rng_seed=7,
                           cache_mode="paged", page_size=8, total_pages=10,
                           prefix_store=PrefixStore(store, ns))
    a = mk()
    a.submit([Request(uid="warm", prompt=PREFIX + [50], max_new_tokens=2)])
    a.run_to_completion()
    assert a.prefix_store_pages_published == 2  # both full chunks
    assert a.prefix_store_pages_hydrated == 0  # nothing to pull: it was first

    dense = ServeEngine(model, params, max_batch=2, max_len=32,
                        prefill_chunk=4, rng_seed=7)
    dense.submit([Request(uid="cold", prompt=PREFIX + [60, 61], max_new_tokens=4)])
    want = dense.run_to_completion()[0].output

    b = mk()
    b.submit([Request(uid="cold", prompt=PREFIX + [60, 61], max_new_tokens=4)])
    got = b.run_to_completion()[0].output
    assert got == want
    assert b.prefix_store_pages_hydrated == 2
    assert b.prefix_store_tokens_hydrated == 16
    assert b.prompt_tokens_skipped == 16  # hydrated pages were stitched
    # republication is suppressed: the pages are already content-addressed
    assert b.prefix_store_pages_published == 0
    # local drain invariants hold with hydrated pages in the tree
    assert b.pages_in_use == len(b.prefix.pages())


def test_prefix_store_ttl_sweep(tmp_path):
    """sweep(ttl_s) deletes pages older than the TTL by object mtime and
    leaves fresh ones; ttl 0 clears the prefix.  Closes the 'store grows
    until an operator sweeps' caveat."""
    import os
    import time

    store = ObjectStore(str(tmp_path / "store"))
    ps = PrefixStore(store, "ns")
    page = {"k": np.zeros((2, 2), np.float32)}
    old_key, new_key = "aa" * 32, "bb" * 32
    ps.publish(old_key, page)
    ps.publish(new_key, page)
    # age one object by rewinding its filesystem mtime 1000 s
    old_path = os.path.join(store.root, ps._object_key(old_key))
    past = time.time() - 1000.0
    os.utime(old_path, (past, past))
    assert ps.sweep(500.0) == 1
    assert not ps.exists(old_key) and ps.exists(new_key)
    # explicit ``now`` pins the clock (deterministic TTL arithmetic)
    head = store.head(ps._object_key(new_key))
    assert ps.sweep(100.0, now=head.mtime + 50.0) == 0
    assert ps.sweep(100.0, now=head.mtime + 200.0) == 1
    assert list(store.list("kvprefix/")) == []
    # an empty prefix sweeps to zero, not an error
    assert ps.sweep(0.0) == 0


def test_async_publisher_flush_errors_and_restart(tmp_path):
    """The background publisher's contract: flush() blocks until every
    submitted write was attempted; a failing put is counted + dropped
    without killing the worker; close() is restartable (a later submit
    spins the worker back up)."""
    store = ObjectStore(str(tmp_path / "store"))
    ps = PrefixStore(store, "ns")
    pub = ps.publisher()
    page = {"k": np.arange(4, dtype=np.float32).reshape(2, 2)}

    pub.submit("aa" * 32, page)
    pub.submit("bb" * 32, page)
    pub.flush()
    for key in ("aa" * 32, "bb" * 32):
        got = ps.fetch(key, like=page)
        assert got is not None and np.array_equal(got["k"], page["k"])

    # a raising put is logged + dropped; the worker thread survives
    real_publish = ps.publish
    def boom(key, arrays):
        raise OSError("store down")
    ps.publish = boom
    pub.submit("cc" * 32, page)
    pub.flush()
    assert pub.errors == 1 and not ps.exists("cc" * 32)
    ps.publish = real_publish
    pub.submit("dd" * 32, page)  # same worker, next write succeeds
    pub.flush()
    assert ps.exists("dd" * 32)

    # close() drains and stops the worker but the publisher is reusable
    pub.close()
    assert pub._thread is None
    pub.submit("ee" * 32, page)
    pub.close()
    assert ps.exists("ee" * 32)


def test_prefix_store_namespace_isolation(tmp_path):
    """Different namespaces (different params identity) must never share
    pages: engine C under another namespace sees a cold store."""
    cfg, model, params = _setup()
    store = ObjectStore(str(tmp_path / "store"))
    a = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4,
                    cache_mode="paged", page_size=8, total_pages=8,
                    prefix_store=PrefixStore(store, "model-A"))
    a.submit([Request(uid="w", prompt=list(PREFIX), max_new_tokens=2)])
    a.run_to_completion()
    assert a.prefix_store_pages_published > 0
    c = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4,
                    cache_mode="paged", page_size=8, total_pages=8,
                    prefix_store=PrefixStore(store, "model-B"))
    c.submit([Request(uid="x", prompt=list(PREFIX), max_new_tokens=2)])
    c.run_to_completion()
    assert c.prefix_store_pages_hydrated == 0
    assert c.prefix_store_pages_published > 0  # published under its own keys


def test_prefix_store_rejects_incompatible_payload(tmp_path):
    """A blob that does not match the pool's leaf shapes (colliding
    namespace from another config) is a miss, not a crash/corruption."""
    cfg, model, params = _setup()
    store = ObjectStore(str(tmp_path / "store"))
    ps_store = PrefixStore(store, "shared-ns")
    # forge an incompatible page under the key engine B will look up
    key = ps_store.child_key(ps_store.root_key(), PREFIX[:8])
    store.put_bytes(f"kvprefix/{key[:2]}/{key}",
                    PrefixStore.pack({"k_pages": np.zeros((1, 2), np.float32)}))
    # and a truncated/garbage blob (e.g. a partially swept object) under
    # the SECOND chunk's key: hydration stops there, no crash
    key2 = ps_store.child_key(key, PREFIX[8:16])
    store.put_bytes(f"kvprefix/{key2[:2]}/{key2}", b"not an npz")
    # and a PK-magic-but-truncated npz (a partially written object whose
    # zip central directory is gone): np.load raises zipfile.BadZipFile,
    # which is neither ValueError nor OSError — must be a miss, not a
    # worker crash
    valid = PrefixStore.pack({"k": np.zeros((2, 2), np.float32)})
    assert valid[:2] == b"PK"
    key3 = ps_store.child_key(ps_store.root_key(), [77] * 8)
    store.put_bytes(f"kvprefix/{key3[:2]}/{key3}", valid[:20])
    assert ps_store.fetch(
        key3, {"k": np.zeros((2, 2), np.float32)}
    ) is None
    b = ServeEngine(model, params, max_batch=1, max_len=32, prefill_chunk=4,
                    cache_mode="paged", page_size=8, total_pages=8,
                    prefix_store=PrefixStore(store, "shared-ns"))
    b.submit([Request(uid="x", prompt=list(PREFIX), max_new_tokens=2),
              Request(uid="y", prompt=[77] * 8 + [1, 2], max_new_tokens=2)])
    b.run_to_completion()
    assert b.prefix_store_pages_hydrated == 0

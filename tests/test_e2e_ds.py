"""End-to-end: the DS control plane driving real JAX training/serving jobs.

These are the paper's workflow in miniature: submit step-span training
jobs, run a preemptible fleet, verify idempotent restart (CHECK_IF_DONE),
checkpoint-based resume after preemption, and the serve/eval Somethings.
"""

import jax
import numpy as np
import pytest

import repro.launch.serve  # noqa: F401  (registers distributed-serve)
import repro.launch.train  # noqa: F401  (registers distributed-train/eval)
from repro.core import (
    DSConfig,
    DSRuntime,
    FleetFile,
    JobFile,
    SimRunner,
    VirtualClock,
    step_span_job_file,
)
from repro.train.checkpoint import latest_step

ARCH_OVERRIDES = "reduced"
TRAIN_SHARED = {
    "arch": "ds-paper-100m",
    "arch_overrides": ARCH_OVERRIDES,
    "seq_len": 32,
    "global_batch": 2,
    "lr": 1e-3,
    "warmup_steps": 2,
}


def _runtime(tmp_path, clk, *, machines=2, payload="distributed-train", **cfg_kwargs):
    kwargs = dict(
        app_name="E2E",
        payload=payload,
        cluster_machines=machines,
        tasks_per_machine=1,
        machine_type=["sim.large"],
        machine_price=1.0,
        sqs_message_visibility=240.0,
        max_receive_count=8,
        check_if_done=True,
        expected_number_files=1,
        min_file_size_bytes=2,
    )
    kwargs.update(cfg_kwargs)
    cfg = DSConfig(**kwargs)
    rt = DSRuntime(cfg, store_root=str(tmp_path / "store"), clock=clk)
    rt.setup()
    return rt


def test_train_spans_to_completion_and_loss_falls(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    jf = step_span_job_file(arch="ds-paper-100m", total_steps=12, span=4, run="r1",
                            shared=dict(TRAIN_SHARED, total_steps=12))
    rt.submit_job(jf)
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=400)
    assert summary.jobs_done == 3, f"all spans must complete: {summary}"
    assert latest_step(rt.store, "r1") == 12
    # loss trajectory recorded in the span DONE markers must decrease
    first = rt.store.get_json("runs/r1/spans/000000-000004/DONE.json")
    last = rt.store.get_json("runs/r1/spans/000008-000012/DONE.json")
    assert last["final_loss"] < first["final_loss"], (first, last)


def test_resubmission_skips_completed_spans(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    jf = step_span_job_file(arch="ds-paper-100m", total_steps=8, span=4, run="r2",
                            shared=dict(TRAIN_SHARED, total_steps=8))
    rt.submit_job(jf)
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    s1 = SimRunner(rt, tick_seconds=30.0).run(max_ticks=400)
    assert s1.jobs_done == 2

    # paper semantics: resubmit the WHOLE job file; only missing work runs
    rt2 = _runtime(tmp_path, clk)
    rt2.submit_job(jf)
    rt2.start_cluster(FleetFile(startup_seconds=0.0))
    s2 = SimRunner(rt2, tick_seconds=30.0).run(max_ticks=400)
    assert s2.jobs_skipped == 2 and s2.jobs_done == 0, f"{s2}"


def test_training_survives_aggressive_preemption(tmp_path):
    """Node-failure drill: ~2 preemptions/instance/hour, virtual time.

    The queue's visibility timeout + checkpoint resume must still drive
    training to 100% completion with a correct final checkpoint.
    """
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, machines=3)
    jf = step_span_job_file(arch="ds-paper-100m", total_steps=12, span=4, run="r3",
                            shared=dict(TRAIN_SHARED, total_steps=12, ckpt_every=2))
    rt.submit_job(jf)
    rt.start_cluster(FleetFile(startup_seconds=0.0, preemption_rate_per_hour=2.0, market_seed=11))
    summary = SimRunner(rt, tick_seconds=120.0).run(max_ticks=600)
    assert latest_step(rt.store, "r3") == 12, f"training did not finish: {summary}"
    assert rt.queue.counts()["dead"] == 0


def test_out_of_order_span_waits_for_prerequisite(tmp_path):
    """A span whose prerequisite checkpoint is missing fails fast and is
    retried via visibility timeout until an earlier span produces it."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, machines=1, sqs_message_visibility=90.0)
    # submit ONLY the second span first, then the first span
    jf = step_span_job_file(arch="ds-paper-100m", total_steps=8, span=4, run="r4",
                            shared=dict(TRAIN_SHARED, total_steps=8))
    second, first = jf.groups[1], jf.groups[0]
    jf2 = JobFile(shared=jf.shared, groups=[second])
    rt.submit_job(jf2)
    rt.submit_job(JobFile(shared=jf.shared, groups=[first]))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=60.0).run(max_ticks=400)
    assert latest_step(rt.store, "r4") == 8
    assert summary.jobs_done == 2


def test_serve_payload_writes_completions(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, payload="distributed-serve", machines=1)
    rt.submit_job(
        JobFile(
            shared={
                "arch": "ds-paper-100m",
                "arch_overrides": ARCH_OVERRIDES,
                "max_new_tokens": 4,
                "max_len": 32,
            },
            groups=[
                {"prompts": [[1, 2, 3], [4, 5]], "output_prefix": "serve/g0"},
                {"prompts": [[7, 8, 9, 10]], "output_prefix": "serve/g1"},
            ],
        )
    )
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)
    assert summary.jobs_done == 2
    res = rt.store.get_json("serve/g0/RESULTS.json")
    assert len(res["requests"]) == 2
    for r in res["requests"].values():
        assert len(r["completion"]) == 4


def test_eval_payload_after_training(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    jf = step_span_job_file(arch="ds-paper-100m", total_steps=4, span=4, run="r5",
                            shared=dict(TRAIN_SHARED, total_steps=4))
    rt.submit_job(jf)
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)

    rt2 = _runtime(tmp_path, clk, payload="distributed-eval")
    rt2.submit_job(
        JobFile(
            shared=dict(TRAIN_SHARED, run="r5", n_batches=2),
            groups=[{"shard": 0, "output_prefix": "runs/r5/eval/shard0"},
                    {"shard": 1, "output_prefix": "runs/r5/eval/shard1"}],
        )
    )
    rt2.start_cluster(FleetFile(startup_seconds=0.0))
    s = SimRunner(rt2, tick_seconds=30.0).run(max_ticks=200)
    assert s.jobs_done == 2
    m = rt2.store.get_json("runs/r5/eval/shard0/METRICS.json")
    assert np.isfinite(m["loss"]) and m["ckpt_step"] == 4

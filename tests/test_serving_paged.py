"""Paged KV cache: token parity vs the dense engine, page reuse after
free, pool accounting, on-device stop tokens, and the paged MLA path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine


def _setup(arch="ds-paper-100m", seed=0, **rt_kwargs):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(**rt_kwargs))
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _ragged_requests(max_new=4, temperature=0.0, stop_token=None):
    """Mixed lengths + more requests than slots => mid-stream refills."""
    prompts = [[1, 2, 3, 4, 5, 6, 7], [9, 8], [42], [5, 4, 3, 2, 1], [17, 23, 31]]
    return [
        Request(uid=f"r{i}", prompt=list(p), max_new_tokens=max_new,
                temperature=temperature, stop_token=stop_token)
        for i, p in enumerate(prompts)
    ]


# ------------------------------------------------------------- token parity
def test_paged_matches_dense_token_for_token():
    """Tentpole parity: the paged engine (tight pool => mid-stream page
    reuse after free) must produce token-for-token identical output to
    the dense fused engine, greedy AND seeded temperature, on a ragged
    batch with mid-stream refills."""
    cfg, model, params = _setup()
    for temperature in (0.0, 0.7):
        dense = ServeEngine(model, params, max_batch=2, max_len=32,
                            prefill_chunk=4, rng_seed=7)
        dense.submit(_ragged_requests(temperature=temperature))
        dense.run_to_completion()
        paged = ServeEngine(model, params, max_batch=2, max_len=32,
                            prefill_chunk=4, rng_seed=7,
                            cache_mode="paged", page_size=8, total_pages=4)
        paged.submit(_ragged_requests(temperature=temperature))
        paged.run_to_completion()
        got_d = {r.uid: r.output for r in dense.finished}
        got_p = {r.uid: r.output for r in paged.finished}
        assert got_d == got_p, f"temperature={temperature}: {got_d} != {got_p}"
        # the pool (4 pages) is smaller than the lifetime page demand, so
        # parity above can only hold if freed pages were reused cleanly
        assert paged.page_allocs > paged.n_pages, "scenario never reused a page"
        assert paged.peak_pages <= paged.n_pages
        assert paged.peak_cache_bytes < paged.dense_cache_bytes
        # everything returned to the pool at drain
        assert paged.pages_in_use == 0
        assert sorted(paged._free_pages) == list(range(paged.n_pages))


def test_paged_matches_dense_decode_ingest_mla():
    """Paged MLA (deepseek: compressed latent pages, decode-path prompt
    ingestion since MoE has no fused prefill) matches the dense engine."""
    cfg, model, params = _setup("deepseek-v2-236b", seed=2)
    dense = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3)
    dense.submit(_ragged_requests(max_new=3))
    dense.run_to_completion()
    paged = ServeEngine(model, params, max_batch=2, max_len=32, rng_seed=3,
                        cache_mode="paged", page_size=8, total_pages=6)
    paged.submit(_ragged_requests(max_new=3))
    paged.run_to_completion()
    assert not paged._use_prefill  # moe => decode-path ingestion
    got_d = {r.uid: r.output for r in dense.finished}
    got_p = {r.uid: r.output for r in paged.finished}
    assert got_d == got_p
    assert "kv_pages" in paged.cache and paged.peak_pages > 0


def test_paged_isolated_rows_and_refill():
    """A request's output must be independent of co-scheduled requests
    and of which physical pages it lands on after refills."""
    cfg, model, params = _setup(seed=2)
    long_p = [3, 1, 4, 1, 5, 9, 2, 6]
    solo = ServeEngine(model, params, max_batch=1, max_len=32)
    solo.submit([Request(uid="solo", prompt=list(long_p), max_new_tokens=4)])
    want = solo.run_to_completion()[0].output

    mixed = ServeEngine(model, params, max_batch=2, max_len=32,
                        cache_mode="paged", page_size=8, total_pages=6)
    mixed.submit([
        Request(uid="long", prompt=list(long_p), max_new_tokens=4),
        Request(uid="short", prompt=[2, 7], max_new_tokens=6),
        Request(uid="short2", prompt=[7], max_new_tokens=6),
    ])
    got = {r.uid: r.output for r in mixed.run_to_completion()}
    assert got["long"] == want


def test_paged_pool_exhaustion_raises():
    """Exhaustion is now recoverable (prefix eviction, then youngest-slot
    preemption — tests/test_serving_prefix.py), but a request that cannot
    fit the whole pool must still fail loudly: here every prompt+budget
    needs 2 pages of a 1-page pool."""
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, max_batch=2, max_len=32,
                         prefill_chunk=4, cache_mode="paged",
                         page_size=8, total_pages=1)
    engine.submit(_ragged_requests())
    with pytest.raises(RuntimeError, match="pool exhausted"):
        engine.run_to_completion()


def test_paged_overlong_prompt_raises_clearly():
    """A prompt that cannot fit max_len must fail with a clear error at
    allocation, not an opaque page-table IndexError."""
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, max_batch=1, max_len=16,
                         prefill_chunk=8, cache_mode="paged", page_size=8)
    engine.submit([Request(uid="big", prompt=list(range(1, 25)),
                           max_new_tokens=2)])
    with pytest.raises(ValueError, match="max_len"):
        engine.run_to_completion()


def test_paged_rejected_for_unpageable_arch():
    cfg, model, params = _setup("mamba2-1.3b", seed=1)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, max_batch=2, max_len=32, cache_mode="paged")


# ------------------------------------------------------------- stop tokens
def test_stop_token_finishes_early_on_device():
    """Satellite: the fused dispatch's done mask must finish a request the
    moment it emits its stop token (kept in the output), in dense and
    paged modes and in the grouped baseline, identically."""
    cfg, model, params = _setup(seed=4)
    probe = ServeEngine(model, params, max_batch=1, max_len=32)
    probe.submit([Request(uid="p", prompt=[1, 2, 3], max_new_tokens=6)])
    free_run = probe.run_to_completion()[0].output
    stop = free_run[2]  # finish after the 3rd token

    outs = {}
    for name, kwargs in (
        ("dense", {}),
        ("paged", dict(cache_mode="paged", page_size=8, total_pages=4)),
        ("grouped", dict(dispatch_mode="grouped")),
    ):
        e = ServeEngine(model, params, max_batch=2, max_len=32, **kwargs)
        e.submit([Request(uid="s", prompt=[1, 2, 3], max_new_tokens=6,
                          stop_token=stop)])
        outs[name] = e.run_to_completion()[0].output
        if name == "paged":
            assert e.pages_in_use == 0  # freed the moment the mask fired
    assert outs["dense"] == free_run[:3], (outs["dense"], free_run)
    assert outs["dense"] == outs["paged"] == outs["grouped"]


def test_stop_token_host_sampling_fallback():
    """sample_on_device=False re-derives the stop condition on host."""
    cfg, model, params = _setup(seed=4)
    probe = ServeEngine(model, params, max_batch=1, max_len=32)
    probe.submit([Request(uid="p", prompt=[1, 2, 3], max_new_tokens=6)])
    free_run = probe.run_to_completion()[0].output
    e = ServeEngine(model, params, max_batch=1, max_len=32,
                    sample_on_device=False)
    e.submit([Request(uid="s", prompt=[1, 2, 3], max_new_tokens=6,
                      stop_token=free_run[1])])
    assert e.run_to_completion()[0].output == free_run[:2]


# ------------------------------------------------------ model-level kernel path
def test_paged_kernel_impl_matches_jnp_impl():
    """The Pallas flash-decode path (interpret mode on CPU) must agree
    with the jnp gather fallback through full decode steps."""
    cfg, model, params = _setup()
    B, max_len, ps = 2, 32, 8
    P = max_len // ps
    n_pages = B * P
    cache = model.init_cache(B, max_len, paged=True, page_size=ps, n_pages=n_pages)
    table = np.full((B, P), n_pages, np.int32)
    table[0, :2] = [3, 0]
    table[1, :2] = [2, 1]
    cache["page_table"] = jnp.asarray(table)
    m_jnp = Model(cfg, ModelRuntime(paged_attn_impl="jnp"))
    m_ker = Model(cfg, ModelRuntime(paged_attn_impl="kernel"))
    toks = jnp.asarray([[5], [9]], jnp.int32)
    cache_j, cache_k = cache, cache
    for pos in ([0, 0], [1, 1], [2, 2]):
        pv = jnp.asarray(pos, jnp.int32)
        lj, cache_j = m_jnp.decode_step(params, cache_j, toks, pv)
        lk, cache_k = m_ker.decode_step(params, cache_k, toks, pv)
        np.testing.assert_allclose(
            np.asarray(lj), np.asarray(lk), rtol=2e-4, atol=2e-4
        )


def test_paged_prefill_chunk_kernel_matches_jnp():
    """Chunk-extend through the kernel == jnp fallback (ragged lengths,
    padded rows)."""
    cfg, model, params = _setup()
    B, max_len, ps = 2, 32, 8
    n_pages = B * (max_len // ps)
    toks = np.zeros((B, 4), np.int32)
    toks[0, :4] = [1, 2, 3, 4]
    toks[1, :2] = [9, 8]
    offs = jnp.zeros((B,), jnp.int32)
    lens = jnp.asarray([4, 2], jnp.int32)
    outs = {}
    for impl in ("jnp", "kernel"):
        m = Model(cfg, ModelRuntime(paged_attn_impl=impl))
        cache = m.init_cache(B, max_len, paged=True, page_size=ps, n_pages=n_pages)
        table = np.full((B, max_len // ps), n_pages, np.int32)
        table[0, 0] = 1
        table[1, 0] = 3
        cache["page_table"] = jnp.asarray(table)
        lg, _ = m.prefill_chunk(params, cache, jnp.asarray(toks), offs, lens)
        outs[impl] = np.asarray(lg)
    np.testing.assert_allclose(outs["jnp"], outs["kernel"], rtol=2e-4, atol=2e-4)

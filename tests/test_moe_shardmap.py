"""shard_map expert-parallel MoE vs the dense oracle (fwd + grads).

Subprocess-isolated (needs 8 host devices)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, reduced
from repro.models.moe import apply_moe, moe_init
from repro.sharding.logical import axis_rules, train_rules
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(reduced(get_arch("mixtral-8x7b")),
                          d_model=32, moe_d_ff=64, n_experts=8, top_k=2,
                          capacity_factor=16.0)  # ample capacity: no drops
p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32, 0.1)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
ref = apply_moe(p, x, cfg, "dense")

def run(pp, xx):
    with axis_rules(mesh, train_rules(multi_pod=False)):
        return apply_moe(pp, xx, cfg, "shardmap")

wspecs = {"router": P("data", None), "wi": P("model", "data", None),
          "wg": P("model", "data", None), "wo": P("model", "data", None)}
p_sh = dict(p)
for k in wspecs:
    p_sh[k] = jax.device_put(p[k], NamedSharding(mesh, wspecs[k]))
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
with jax.set_mesh(mesh):
    out = jax.jit(run)(p_sh, x_sh)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("FWD-OK")

def loss_d(pp):
    return jnp.sum(apply_moe(pp, x, cfg, "dense") ** 2)
def loss_s(pp):
    with axis_rules(mesh, train_rules(multi_pod=False)):
        return jnp.sum(apply_moe(pp, x_sh, cfg, "shardmap") ** 2)
gd = jax.grad(loss_d)(p)
with jax.set_mesh(mesh):
    gs = jax.device_get(jax.jit(jax.grad(loss_s))(p_sh))
for k in ("router", "wi", "wg", "wo"):
    np.testing.assert_allclose(np.asarray(gs[k]), np.asarray(gd[k]), rtol=1e-4, atol=1e-4)
print("GRAD-OK")

# capacity drops: shardmap and gather paths drop by the same local rule
cfg2 = dataclasses.replace(cfg, capacity_factor=0.6)
def run2(pp, xx):
    with axis_rules(mesh, train_rules(multi_pod=False)):
        return apply_moe(pp, xx, cfg2, "shardmap")
with jax.set_mesh(mesh):
    out2 = jax.jit(run2)(p_sh, x_sh)
assert np.isfinite(np.asarray(out2)).all()
print("DROP-OK")
"""


def test_moe_shardmap_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=560,
    )
    for marker in ("FWD-OK", "GRAD-OK", "DROP-OK"):
        assert marker in res.stdout, f"missing {marker}\nstdout={res.stdout}\nstderr={res.stderr[-3000:]}"

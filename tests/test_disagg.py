"""Disaggregated prefill/decode serving, layer by layer: role-aware
scheduler admission contracts, engine role validation, the role-split
autoscaler policy, publisher-side dedup of pending page keys, pin-aware
TTL sweeps, and the sweep-races-a-handoff regression (byte-identical
fallback when the store lies)."""

import os
import threading
import time

os.environ.setdefault("DS_DEBUG_INVARIANTS", "1")

import jax
import numpy as np
import pytest

import repro.launch.serve  # noqa: F401  (registers distributed-serve)
import repro.launch.train  # noqa: F401
from repro.core import DSConfig, FleetFile, VirtualClock
from repro.core.autoscaler import Autoscaler, ProgressBoard
from repro.core.cluster import ECSCluster, Service, TaskDefinition
from repro.core.fleet import SpotFleet
from repro.core.queue import DurableQueue
from repro.core.storage import ObjectStore
from repro.launch.train import build_model
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_store import PrefixStore
from repro.serving.scheduler import RequestScheduler
from repro.serving.types import EngineStats

JOB = {"arch": "ds-paper-100m", "arch_overrides": "reduced"}
PAGE = 8


@pytest.fixture(scope="module")
def model_params():
    model = build_model(JOB)
    return model, model.init(jax.random.PRNGKey(0))


# ------------------------------------------------- scheduler role gates
def test_decode_scheduler_refuses_fresh_prefill_work():
    sched = RequestScheduler(2, EngineStats(), role="decode")
    with pytest.raises(RuntimeError, match="refuses fresh prefill work"):
        sched.submit([Request(uid="a", prompt=[1, 2])])
    assert sched.pending == []
    # sealed handoffs are the only admissible work, and they keep the
    # prefill worker's stream id (fresh assignment would collide)
    req = Request(uid="h", prompt=[1, 2, 3])
    req.sample_stream = 5
    sched.submit_handoff(req)
    assert sched.pending[-1] is req and req.handoff
    assert sched._n_submitted == 6


def test_prefill_scheduler_refuses_handoff_admissions():
    sched = RequestScheduler(2, EngineStats(), role="prefill")
    with pytest.raises(RuntimeError, match="refuses handoff"):
        sched.submit_handoff(Request(uid="h", prompt=[1, 2]))
    sched.submit([Request(uid="a", prompt=[1, 2])])  # fresh work is fine
    assert len(sched.pending) == 1


def test_scheduler_role_validation():
    with pytest.raises(ValueError, match="role"):
        RequestScheduler(2, EngineStats(), role="verifier")


# ------------------------------------------------- engine role validation
def test_engine_role_validation(tmp_path, model_params):
    model, params = model_params
    ps = PrefixStore(ObjectStore(str(tmp_path / "store")), "ns")
    paged = dict(cache_mode="paged", page_size=PAGE, prefix_store=ps)
    with pytest.raises(ValueError, match="worker_role"):
        ServeEngine(model, params, worker_role="draft", **paged)
    # a storage-mediated handoff without storage is refused up front
    for role in ("prefill", "decode"):
        with pytest.raises(ValueError, match="prefix_store"):
            ServeEngine(model, params, worker_role=role)
    # a prefill worker has no decode ticks: chunked prefill is mandatory
    # and speculative decoding can never run
    with pytest.raises(ValueError, match="chunked-prefill"):
        ServeEngine(model, params, worker_role="prefill",
                    prefill_chunk=0, **paged)
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(model, params, worker_role="prefill",
                    speculative="ngram", **paged)


# --------------------------------------------- role-split autoscaler
def _scaler(tmp_path, clk, **over):
    cfg = DSConfig(
        app_name="Split", cluster_machines=1,
        machine_type=["sim.large"], machine_price=1.0,
        autoscale="slo", min_workers=1, max_workers=10,
        autoscale_queue_per_worker=4, autoscale_target_p99_ttft=10.0,
        autoscale_up_cooldown_seconds=60.0,
        autoscale_down_cooldown_seconds=600.0,
        autoscale_max_step=2, monitor_poll_seconds=60.0, **over,
    )
    queue = DurableQueue(str(tmp_path / "jobs.sqlite"), clock=clk)
    fleet = SpotFleet(FleetFile(startup_seconds=0.0), clock=clk,
                      app_name="Split")
    fleet.request(target_capacity=1, bid=1.0, machine_types=["sim.large"])
    cluster = ECSCluster()
    cluster.register_service(Service(
        name="SplitService",
        task_definition=TaskDefinition.from_config(cfg),
        desired_count=1,
    ))
    board = ProgressBoard()
    return Autoscaler(cfg, queue, fleet, cluster, clock=clk,
                      board=board), board, fleet


def test_role_split_autoscaler_sizes_pools_independently(tmp_path):
    clk = VirtualClock()
    asc, board, fleet = _scaler(tmp_path, clk)

    # per-role demand: prefill off the request-queue backlog, decode off
    # the decode-queue backlog; the fleet target is the sum
    board.put("w_pre", {"kind": "serve", "role": "prefill", "backlog": 8},
              clk.now())
    board.put("w_dec", {"kind": "serve", "role": "decode", "backlog": 4,
                        "active": 0, "p99_ttft": 0.0}, clk.now())
    d = asc.tick()
    assert d.desired == 3 and d.applied
    assert "role-split prefill=2 decode=1" in d.reason
    assert fleet.target_capacity == 3

    # decode SLO breach steps the decode pool up past its queue-depth
    # answer; the prefill share rides on top
    clk.sleep(60.0)
    board.put("w_pre", {"kind": "serve", "role": "prefill", "backlog": 0},
              clk.now())
    board.put("w_dec", {"kind": "serve", "role": "decode", "backlog": 0,
                        "active": 0, "p99_ttft": 25.0}, clk.now())
    d = asc.tick()
    assert d.desired == 4 and d.applied
    assert "decode slo breach" in d.reason and "prefill=1" in d.reason

    # hysteresis: decode p99 inside (target/2, target] holds the fleet
    # instead of shrinking both pools into a breach
    clk.sleep(60.0)
    board.put("w_dec", {"kind": "serve", "role": "decode", "backlog": 0,
                        "active": 0, "p99_ttft": 7.0}, clk.now())
    d = asc.tick()
    assert d.desired == 4 and "decode slo hold" in d.reason

    # active-slot pressure sizes the decode pool even with an empty queue
    clk.sleep(60.0)
    board.put("w_dec", {"kind": "serve", "role": "decode", "backlog": 0,
                        "active": 12, "p99_ttft": 0.0}, clk.now())
    d = asc.tick()
    assert "role-split prefill=1 decode=3" in d.reason and d.desired == 4

    # a mixed fleet sizes its unified share exactly like the legacy policy
    clk.sleep(60.0)
    board.put("w_pre", {"kind": "serve", "role": "prefill", "backlog": 0},
              clk.now())
    board.put("w_dec", {"kind": "serve", "role": "decode", "backlog": 0,
                        "active": 0, "p99_ttft": 0.0}, clk.now())
    board.put("w_uni", {"kind": "serve", "backlog": 8}, clk.now())
    d = asc.tick()
    assert "unified=2" in d.reason and d.desired == 4

    # each live role keeps a floor of one worker: a pipeline with either
    # stage empty serves nothing
    clk.sleep(600.0)
    board.put("w_pre", {"kind": "serve", "role": "prefill", "backlog": 0},
              clk.now())
    board.put("w_dec", {"kind": "serve", "role": "decode", "backlog": 0,
                        "active": 0, "p99_ttft": 0.0}, clk.now())
    board.put("w_uni", {"kind": "serve", "backlog": 0}, clk.now())
    d = asc.tick()
    assert d.desired == 2 and "prefill=1 decode=1" in d.reason


def test_unified_role_tags_keep_the_legacy_policy(tmp_path):
    """serve leases now always tag their role; an all-unified fleet must
    still run the single-pool policy with its original reason strings."""
    clk = VirtualClock()
    asc, board, _ = _scaler(tmp_path, clk)
    board.put("w1", {"kind": "serve", "role": "unified", "backlog": 8,
                     "p99_ttft": 0.0}, clk.now())
    d = asc.tick()
    assert d.desired == 2 and "reported backlog=8" in d.reason


# ----------------------------------------------- publisher dedup
def test_async_publisher_dedups_pending_page_keys(tmp_path):
    store = ObjectStore(str(tmp_path / "store"))
    ps = PrefixStore(store, "ns")
    arrays = {"k": np.arange(8, dtype=np.float32)}
    gate = threading.Event()
    real_publish = ps.publish
    ps.publish = lambda key, arrs: (gate.wait(5.0), real_publish(key, arrs))
    pub = ps.publisher()
    try:
        key = "ab" * 32
        assert pub.submit(key, dict(arrays)) is True
        # the first write is gated in the worker thread, so the key is
        # deterministically still pending: the resubmit is dropped...
        assert pub.submit(key, dict(arrays)) is False
        assert pub.dedup_hits == 1
        # ...and a deduped CALLABLE submit never snapshots at all
        pulled = []
        assert pub.submit(key, lambda: pulled.append(1) or dict(arrays)) is False
        assert pub.dedup_hits == 2 and pulled == []
        # a different key is not deduped
        assert pub.submit("cd" * 32, dict(arrays)) is True
        gate.set()
        pub.flush()
        assert ps.exists(key)
        # once landed the key is pending no more: resubmit is accepted
        assert pub.submit(key, dict(arrays)) is True
        pub.flush()
        assert pub.dedup_hits == 2 and pub.errors == 0
    finally:
        gate.set()
        pub.close()


# ------------------------------------------- pin-aware TTL sweep
def _age(store: ObjectStore, key: str, seconds: float) -> None:
    old = time.time() - seconds
    os.utime(os.path.join(store.root, key), (old, old))


def test_sweep_honors_fresh_pins_and_collects_expired_markers(tmp_path):
    store = ObjectStore(str(tmp_path / "store"))
    ps = PrefixStore(store, "ns")
    arrays = {"k": np.arange(8, dtype=np.float32)}
    keep, drop = "aa" * 32, "bb" * 32
    ps.publish(keep, arrays)
    ps.publish(drop, arrays)
    ps.pin(keep)  # fresh marker
    ps.pin(drop)
    # both pages are past the TTL; only drop's marker is stale too
    for key in (keep, drop):
        _age(store, f"kvprefix/{key[:2]}/{key}", 7200.0)
    _age(store, f"kvprefix-pins/{drop[:2]}/{drop}", 7200.0)
    assert ps.sweep(3600.0) == 1  # pages only; markers are not counted
    assert ps.exists(keep), "fresh pin must exempt an expired page"
    assert not ps.exists(drop)
    # the expired marker was garbage-collected, the fresh one kept
    assert not store.exists(f"kvprefix-pins/{drop[:2]}/{drop}")
    assert store.exists(f"kvprefix-pins/{keep[:2]}/{keep}")
    # pins protect by TTL, not forever: once the marker expires the
    # page is reclaimed like any other
    _age(store, f"kvprefix-pins/{keep[:2]}/{keep}", 7200.0)
    assert ps.sweep(3600.0) == 1
    assert not ps.exists(keep)


# --------------------------- sweep races a handoff: fallback regression
def _paged_engine(model, params, store, role="unified"):
    return ServeEngine(
        model, params, max_batch=2, max_len=32, prefill_chunk=4,
        cache_mode="paged", page_size=PAGE,
        prefix_store=PrefixStore(store, "ns"), worker_role=role,
    )


def test_sweep_mid_handoff_pins_protect_then_fallback_is_byte_identical(
    tmp_path, model_params
):
    """The full storage-mediated handoff at engine level, with the TTL
    sweep fired in the window between handoff-enqueue and decode-side
    admission.  With fresh pins the chain survives and hydration is a
    guaranteed hit; with the chain destroyed (expired pins) the decode
    engine falls back down the replay ladder and the output is STILL
    byte-identical to a dense monolith."""
    model, params = model_params
    prompt = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]
    oracle = ServeEngine(model, params, max_batch=2, max_len=32,
                         prefill_chunk=4)
    oracle.submit([Request(uid="o", prompt=list(prompt), max_new_tokens=4)])
    want = oracle.run_to_completion()[0].output
    assert len(want) == 4

    store = ObjectStore(str(tmp_path / "store"))
    pre = _paged_engine(model, params, store, role="prefill")
    pre.submit([Request(uid="h", prompt=list(prompt), max_new_tokens=4)])
    fin = pre.run_to_completion()
    # prefill role: prompt ingested and published, zero tokens decoded
    assert fin[0].output == [] and pre.stats.tokens_emitted == 0
    assert pre.stats.decode_dispatches == 0
    chain = pre.cache_mgr.chain_keys_for(prompt)
    assert len(chain) == 2  # one full page + the sub-page tail
    ps = PrefixStore(store, "ns")
    for k in chain:
        assert ps.exists(k)
        ps.pin(k)  # what _publish_handoff does before enqueueing
    rec = {"uid": "h", "prompt": list(prompt), "output": [],
           "sample_stream": 0, "max_new_tokens": 4, "temperature": 0.0,
           "stop_token": None}

    # TTL sweep races the handoff: every PAGE is past the TTL, but the
    # handoff's fresh pins protect the whole chain
    for root, _, files in os.walk(os.path.join(store.root, "kvprefix")):
        for f in files:
            old = time.time() - 7200.0
            os.utime(os.path.join(root, f), (old, old))
    assert ps.sweep(3600.0) == 0
    for k in chain:
        assert ps.exists(k)

    dec = _paged_engine(model, params, store, role="decode")
    dec.submit_handoff(dict(rec))
    assert dec.run_to_completion()[0].output == want
    assert dec.stats.handoffs_admitted == 1
    assert dec.stats.handoff_fallbacks == 0
    assert dec.stats.prefix_store_pages_hydrated > 0
    assert dec.stats.hydration_fetch_ops > 0
    assert dec.stats.prefix_store_bytes_fetched > 0
    assert dec.snapshot()["hydration_ticks"]["n"] == 1

    # now the store lies: ttl 0 expires the pins and destroys the chain
    # mid-handoff.  Admission falls back down the replay ladder — and
    # the output is byte-identical anyway
    assert ps.sweep(0.0) > 0
    assert not ps.exists(chain[0])
    dec2 = _paged_engine(model, params, store, role="decode")
    dec2.submit_handoff(dict(rec))
    assert dec2.run_to_completion()[0].output == want
    assert dec2.stats.handoff_fallbacks == 1
    assert dec2.stats.prefix_store_pages_hydrated == 0

"""Minimal property-testing shim.

``hypothesis`` is not installable in this offline container; this module
provides a tiny compatible subset (``@given`` + strategies) backed by
seeded random case generation, and transparently defers to the real
hypothesis when it is available.  Tests written against this API run
unchanged in either environment.
"""

from __future__ import annotations

import functools
import itertools
import random

try:  # pragma: no cover - prefer the real thing when present
    from hypothesis import given, settings  # type: ignore # noqa: F401
    from hypothesis import strategies as st  # type: ignore

    HAVE_HYPOTHESIS = True
except Exception:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.sample(rng)))

        def filter(self, pred, _tries=100):
            def sample(rng):
                for _ in range(_tries):
                    v = self.sample(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too strict")

            return _Strategy(sample)

    class st:  # noqa: N801 - mimic hypothesis.strategies
        @staticmethod
        def integers(min_value=0, max_value=100):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elem.sample(rng) for _ in range(rng.randint(min_size, max_size))
                ]
            )

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.sample(rng) for e in elems))

    def given(*g_args, **g_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n_cases = int(wrapper._proptest_cases)
                for case in range(n_cases):
                    rng = random.Random((hash(fn.__qualname__) ^ case) & 0xFFFFFFFF)
                    vals = [s.sample(rng) for s in g_args]
                    kw = {k: s.sample(rng) for k, s in g_kwargs.items()}
                    try:
                        fn(*args, *vals, **kwargs, **kw)
                    except Exception:
                        print(f"proptest falsifying case #{case}: args={vals} kwargs={kw}")
                        raise

            wrapper._proptest_cases = 25
            return wrapper

        return deco

    def settings(max_examples=25, **_ignored):
        def deco(fn):
            fn._proptest_cases = max_examples
            return fn

        return deco

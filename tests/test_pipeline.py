"""Pipeline parallelism vs sequential reference (4-stage host-device mesh).

Runs in a subprocess so XLA_FLAGS (forced host device count) never leaks
into the main test process (smoke tests must see 1 device).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType
from repro.train.pipeline import pipeline_apply, bubble_fraction

mesh = jax.make_mesh((4,), ("pipe",), axis_types=(AxisType.Auto,))
S, M, MB, D = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (S, D, D)) * 0.3
b = jax.random.normal(jax.random.fold_in(key, 1), (S, D)) * 0.1
params = {"w": w, "b": b}
x = jax.random.normal(jax.random.fold_in(key, 2), (M, MB, D))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s] + b[s])

with jax.set_mesh(mesh):
    out = jax.jit(lambda pp, xx: pipeline_apply(mesh, pp, xx, stage_fn))(params, x)

np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPELINE-OK")
"""


@pytest.mark.parametrize("n", [1])
def test_pipeline_matches_sequential(n):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=300,
    )
    assert "PIPELINE-OK" in res.stdout, f"stdout={res.stdout}\nstderr={res.stderr[-2000:]}"

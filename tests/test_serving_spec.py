"""Speculative decoding on the paged KV cache: byte parity with the
non-speculative engine (greedy AND seeded temperature), the fused
verify step's position-wise equivalence to sequential decode, stream
accounting across preemption replays under different ``spec_k``, and
allocator invariants under randomized speculative interleaving (seeded
``random``, not hypothesis — the env lacks it)."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import make_verify_step


def _setup(seed=0):
    cfg = reduced(get_arch("ds-paper-100m"))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _mixed_requests():
    """Greedy + seeded-temperature rows, a stop-token row, and a row that
    runs into the max_len truncation point."""
    prompts = [[1, 2, 3, 4, 5, 6, 7], [9, 8], [42], [5, 4, 3, 2, 1]]
    reqs = [
        Request(uid=f"r{i}", prompt=list(p), max_new_tokens=10,
                temperature=0.0 if i % 2 == 0 else 0.8)
        for i, p in enumerate(prompts)
    ]
    reqs.append(Request(uid="stop", prompt=[3, 1, 4], max_new_tokens=10,
                        stop_token=7))
    reqs.append(Request(uid="long", prompt=[2, 7, 1, 8], max_new_tokens=64))
    return reqs


def _run(model, params, reqs, **kw):
    eng = ServeEngine(model, params, max_batch=3, max_len=32,
                      cache_mode="paged", page_size=8, **kw)
    eng.submit(reqs)
    eng.run_to_completion()
    return {r.uid: r.output for r in eng.finished}, eng


def test_spec_byte_identical_to_nonspec_both_proposers():
    """The tentpole's hard gate: greedy AND temperature outputs under
    speculation are byte-identical to the non-speculative engine, for
    the ngram proposer and for a draft model whose guesses are mostly
    wrong (separately-initialised weights) — acceptance only moves
    tokens-per-dispatch, never content."""
    cfg, model, params = _setup()
    base, _ = _run(model, params, _mixed_requests())

    got_n, eng_n = _run(model, params, _mixed_requests(),
                        speculative="ngram", spec_k=4)
    assert got_n == base
    assert eng_n.spec_dispatches > 0
    assert eng_n.stats.snapshot()["accepted_per_dispatch"] > 0

    draft = Model(cfg, ModelRuntime())
    dparams = draft.init(jax.random.PRNGKey(7))
    got_d, eng_d = _run(model, params, _mixed_requests(),
                        speculative="draft", spec_k=4,
                        draft_model=draft, draft_params=dparams)
    assert got_d == base
    assert eng_d.spec_dispatches > 0 and eng_d.draft_dispatches > 0
    # the pessimal draft exercises the rollback path constantly
    assert eng_d.draft_tokens_accepted < eng_d.draft_tokens_proposed


def test_spec_works_on_dense_cache_too():
    """Rewind is a frontier move, not a page operation, so speculation
    also runs (and stays byte-identical) on the dense cache."""
    cfg, model, params = _setup()
    base = ServeEngine(model, params, max_batch=3, max_len=32)
    base.submit(_mixed_requests())
    base.run_to_completion()
    spec = ServeEngine(model, params, max_batch=3, max_len=32,
                       speculative="ngram", spec_k=4)
    spec.submit(_mixed_requests())
    spec.run_to_completion()
    assert ({r.uid: r.output for r in spec.finished}
            == {r.uid: r.output for r in base.finished})
    assert spec.spec_dispatches > 0


def test_verify_step_positionwise_matches_sequential_decode():
    """Foundation of byte parity: one fused ``T = k + 1`` verify samples,
    position for position, exactly what ``k + 1`` sequential single-token
    decode dispatches would have — same logits conditioning (causal
    mask), same stream/step sampling keys.  Runs on the dense cache so
    the model is driven directly (a raw paged cache's page table belongs
    to the engine's allocator); the paged path is covered end-to-end by
    the byte-parity tests above."""
    cfg, model, params = _setup()
    B, L, k = 2, 32, 3
    prompt = [5, 9, 2, 7, 1]
    drafts = [3, 8, 4]  # arbitrary: verify scores them, then we compare
    rng_seed = 0

    # sequential oracle: feed [x0, d1..dk] one token at a time
    cache = model.init_cache(B, L)
    toks = jnp.asarray([prompt + [0] * k, prompt + [0] * k], jnp.int32)
    offs = jnp.asarray([0, 0], jnp.int32)
    lens = jnp.asarray([len(prompt) - 1] * 2, jnp.int32)
    _, cache = model.prefill_chunk(params, cache, toks[:, :len(prompt) - 1],
                                   offs, lens)
    seq_logits = []
    feed = [prompt[-1]] + drafts
    for t, tok in enumerate(feed):
        lg, cache = model.decode_step(
            params, cache,
            jnp.asarray([[tok]] * B, jnp.int32),
            jnp.asarray([len(prompt) - 1 + t] * B, jnp.int32),
        )
        seq_logits.append(np.asarray(lg[:, 0, :cfg.vocab_size]))

    # fused verify over the same positions
    verify = make_verify_step(model, rng_seed)
    cache = model.init_cache(B, L)
    _, cache = model.prefill_chunk(params, cache, toks[:, :len(prompt) - 1],
                                   offs, lens)
    tokens = jnp.asarray([feed] * B, jnp.int32)
    offsets = jnp.asarray([len(prompt) - 1] * B, jnp.int32)
    lengths = jnp.asarray([k + 1] * B, jnp.int32)
    temps = jnp.asarray([0.0, 0.9], jnp.float32)
    streams = jnp.asarray([0, 1], jnp.int32)
    steps = jnp.asarray([0, 0], jnp.int32)
    stops = jnp.full((B,), -1, jnp.int32)
    max_news = jnp.full((B,), 100, jnp.int32)
    tgt, n_emit, done, _ = verify(params, cache, tokens, offsets, lengths,
                                  temps, streams, steps, stops, max_news)
    tgt = np.asarray(tgt)

    # position-wise: the verify targets equal sampling the sequential
    # logits with the same (stream, step + t) keys — greedy row 0 via
    # argmax, temperature row 1 via the engine's device sampler
    from repro.serving.sampling import sample_tokens
    for t in range(k + 1):
        lg_t = jnp.asarray(seq_logits[t])
        want = np.asarray(sample_tokens(
            lg_t, temps, streams,
            jnp.asarray([t, t], jnp.int32), base_seed=rng_seed,
        ))
        assert tgt[0, t] == want[0], f"greedy row diverged at position {t}"
        assert tgt[1, t] == want[1], f"temp row diverged at position {t}"


def test_preempted_replay_identical_across_spec_k():
    """Deterministic-stream accounting: a request's sampling stream
    position depends only on tokens emitted — not on spec_k, not on how
    many drafts a dispatch carried, not on preemption replays.  A pool
    tight enough to force preemption mid-generation must yield identical
    outputs for the plain engine and speculative engines at different
    spec_k (temperature rows make stream misuse visible)."""
    cfg, model, params = _setup(3)

    def reqs():
        return [
            Request(uid=f"r{i}", prompt=[10 * i + j for j in range(1, 7)],
                    max_new_tokens=8, temperature=0.7)
            for i in range(5)
        ]

    outs = {}
    preempted = False
    for label, kw in (
        ("off", {}),
        ("k1", dict(speculative="ngram", spec_k=1)),
        ("k4", dict(speculative="ngram", spec_k=4)),
    ):
        # 5 pages for 3 slots of up to 2 pages each: growth pressure
        # forces preemption + replay partway through generation
        eng = ServeEngine(model, params, max_batch=3, max_len=16,
                          cache_mode="paged", page_size=8, total_pages=5,
                          **kw)
        eng.submit(reqs())
        eng.run_to_completion()
        outs[label] = {r.uid: r.output for r in eng.finished}
        preempted |= eng.preemptions > 0
    assert preempted, "pool never forced a preemption — weak test"
    assert outs["off"] == outs["k1"] == outs["k4"]


def test_spec_randomized_interleaving_invariants():
    """Satellite property test: drive the speculative paged engine
    through a seeded-random interleaving of submits and ticks on a pool
    tight enough to force preemption; after every tick the page
    refcounts must equal the holders (so CoW rollback never leaks or
    double-frees a page), no page may be aliased across slots in the
    generated region (rewind never exposes another slot's KV), and the
    final outputs must match the non-speculative dense engine byte for
    byte."""
    cfg, model, params = _setup()

    def _random_requests(rng, n):
        reqs = []
        for i in range(n):
            p = [rng.randrange(1, 99) for _ in range(rng.randrange(1, 10))]
            # long enough tails that three concurrent slots outgrow the
            # 5-page pool (up to ~26 tokens = 4 pages each)
            reqs.append(Request(uid=f"r{i}", prompt=p,
                                max_new_tokens=rng.randrange(6, 18),
                                temperature=0.5 if i % 2 else 0.0))
        return reqs

    def _check_invariants(eng):
        holders = {pid: [] for pid in range(eng.n_pages)}
        for row, pages in enumerate(eng._slot_pages):
            for j, pid in enumerate(pages):
                holders[pid].append((row, j))
        for pid in range(eng.n_pages):
            assert eng._page_refs[pid] == len(holders[pid]), (
                f"page {pid}: refcount {eng._page_refs[pid]} != "
                f"{len(holders[pid])} holders"
            )
        free = sorted(eng._free_pages
                      + [p for p in range(eng.n_pages) if eng._page_refs[p] > 0])
        assert free == list(range(eng.n_pages)), "free list / refs don't partition"
        for pid, maps in holders.items():
            assert len(maps) <= 1, (
                f"page {pid} aliased across slots {maps} with no prefix cache"
            )

    rejected_somewhere = preempted_somewhere = False
    for seed in (0, 1):
        rng = random.Random(seed)
        reqs = _random_requests(rng, 10)
        dense = ServeEngine(model, params, max_batch=3, max_len=32,
                            prefill_chunk=4, rng_seed=9)
        dense.submit([Request(uid=r.uid, prompt=list(r.prompt),
                              max_new_tokens=r.max_new_tokens,
                              temperature=r.temperature) for r in reqs])
        dense.run_to_completion()
        want = {r.uid: r.output for r in dense.finished}

        eng = ServeEngine(model, params, max_batch=3, max_len=32,
                          prefill_chunk=4, rng_seed=9,
                          cache_mode="paged", page_size=8, total_pages=5,
                          prefix_cache=False,  # so pages are never shared:
                          # any aliasing below is a rewind/refcount bug
                          speculative="ngram", spec_k=3)
        queue = list(reqs)
        steps = 0
        while (queue or eng.pending or eng.scheduler.has_active()) and steps < 500:
            if queue and rng.random() < 0.6:
                eng.submit([queue.pop(0) for _ in range(min(len(queue),
                                                            rng.randrange(1, 4)))])
            eng.step()
            steps += 1
            _check_invariants(eng)
        assert not queue and not eng.pending
        assert {r.uid: r.output for r in eng.finished} == want, (
            f"seed {seed}: speculative paged != one-shot dense"
        )
        assert eng.spec_dispatches > 0
        rejected_somewhere |= (eng.draft_tokens_accepted
                               < eng.draft_tokens_proposed)
        preempted_somewhere |= eng.preemptions > 0
    assert rejected_somewhere, "no draft was ever rejected — rollback untested"
    assert preempted_somewhere, "pool never came under pressure — weak test"


def test_spec_never_ooms_a_pool_the_plain_engine_fits():
    """Draft positions are best-effort: on a pool sized exactly for the
    non-speculative run (one slot, pages for prompt + max_new only), the
    speculative engine must shrink its drafts near the pool edge instead
    of raising pool exhaustion — and still emit identical bytes."""
    cfg, model, params = _setup()

    def reqs():
        # 4-token prompt + 28 new = exactly 4 pages at ps=8, but
        # max_len=40 leaves draft room: the optimistic pos+1+spec_k
        # reservation near the end wants a 5th page the pool lacks
        return [Request(uid="r", prompt=[5, 9, 2, 7], max_new_tokens=28)]

    outs = {}
    for label, kw in (("off", {}), ("spec", dict(speculative="ngram",
                                                 spec_k=8))):
        eng = ServeEngine(model, params, max_batch=1, max_len=40,
                          cache_mode="paged", page_size=8, total_pages=4,
                          **kw)
        eng.submit(reqs())
        eng.run_to_completion()
        outs[label] = {r.uid: r.output for r in eng.finished}
    assert outs["spec"] == outs["off"]
    assert len(outs["off"]["r"]) == 28


def test_spec_knob_validation():
    cfg, model, params = _setup()
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_batch=2, max_len=32,
                    speculative="both")
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_batch=2, max_len=32,
                    speculative="ngram", spec_k=0)
    # inert-knob policy: draft params with speculation off are refused
    with pytest.raises(ValueError):
        ServeEngine(model, params, max_batch=2, max_len=32,
                    draft_model=model, draft_params=params)
    with pytest.raises(ValueError):  # draft mode needs the draft model
        ServeEngine(model, params, max_batch=2, max_len=32,
                    speculative="draft")

"""Work-preserving recovery: generation checkpoints survive a crash at
*every* tick of a seeded workload byte-identically (crash-point sweep),
the checkpoint fallback ladder degrades to full replay on missing /
corrupt / mismatched records, the async publisher and the serve-side
retry helper ride out transient storage faults, the prefix store rejects
content-hash mismatches as counted misses, and the chaos monkey's
flaky_storage / flaky_queue windows inject exactly the transient
``ConnectionError`` discipline the serving tier retries against."""

import os

os.environ.setdefault("DS_DEBUG_INVARIANTS", "1")

import random
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import DurableQueue, FleetFile, VirtualClock
from repro.core.chaos import ChaosEvent, ChaosMonkey
from repro.core.fleet import SpotFleet
from repro.core.queue import install_fault_hook, remove_fault_hook
from repro.core.storage import ObjectStore
from repro.launch.serve import (
    _checkpoint_valid,
    _seal_checkpoint,
    _try_resume,
    _uid_safe,
    _with_retries,
)
from repro.models import Model, ModelRuntime
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_store import AsyncPublisher, PrefixStore


def _setup(seed=0):
    cfg = reduced(get_arch("ds-paper-100m"))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _workload(rng: random.Random, n: int):
    """Mixed sampled workload over a shared one-page prefix plus cold
    prompts — temperature > 0 so byte-identical resumption genuinely
    depends on the preserved sampling-stream position, not on greedy
    argmax hiding a stream reset."""
    prefix = [100 + j for j in range(8)]
    reqs = []
    for i in range(n):
        if rng.randrange(3) < 2:
            p = list(prefix) + [rng.randrange(1, 99)
                                for _ in range(rng.randrange(0, 4))]
        else:
            p = [rng.randrange(1, 99) for _ in range(rng.randrange(1, 11))]
        reqs.append(Request(uid=f"r{i}", prompt=p,
                            max_new_tokens=rng.randrange(2, 5),
                            temperature=0.5))
    return reqs


def _clones(reqs):
    return [Request(uid=r.uid, prompt=list(r.prompt),
                    max_new_tokens=r.max_new_tokens,
                    temperature=r.temperature) for r in reqs]


def _engine(model, params, ps):
    return ServeEngine(model, params, max_batch=2, max_len=32,
                       prefill_chunk=4, rng_seed=7,
                       cache_mode="paged", page_size=8, total_pages=10,
                       prefix_cache=True, prefix_store=ps)


# ------------------------------------------------------ crash-point sweep
def test_crash_point_sweep_every_tick_byte_identical(tmp_path):
    """Revoke the worker at EVERY tick index of a seeded workload:
    checkpoint whatever slots are checkpointable, preempt everything,
    hand the survivors to a fresh engine over the same object store
    (resumes via ``submit_resume``, the rest as full replays), and the
    combined outputs must be byte-identical to an uninterrupted run with
    zero lost requests — while ``DS_DEBUG_INVARIANTS=1`` asserts
    refcount == holders after every tick of both engines."""
    _, model, params = _setup()
    reqs = _workload(random.Random(3), 6)
    store = ObjectStore(str(tmp_path / "store"))

    # uninterrupted oracle on the SAME engine config (also measures the
    # total tick count the sweep walks)
    oracle = _engine(model, params, PrefixStore(store, "sweep"))
    oracle.submit(_clones(reqs))
    oracle.run_to_completion()
    want = {r.uid: list(r.output) for r in oracle.finished}
    n_ticks = oracle.scheduler.tick
    assert len(want) == len(reqs) and n_ticks > 3

    total_resumes = total_recovered = 0
    for t in range(1, n_ticks):
        ps_a = PrefixStore(store, "sweep")
        a = _engine(model, params, ps_a)
        a.submit(_clones(reqs))
        for _ in range(t):
            a.step()
        done_a = {r.uid: list(r.output) for r in a.finished}

        # the revocation drain: checkpoint every active slot that has
        # emitted anything, then preempt it back to pending
        ckpts = {}
        for row, slot in enumerate(a.scheduler.slots):
            if slot.req is None:
                continue
            ck = a.checkpoint_slot(row)
            if ck is not None:
                ckpts[ck["uid"]] = ck
            a.scheduler.preempt(row)
        a.cache_mgr.flush_store()  # published pages durable before handoff
        a.cache_mgr.check_invariants()
        survivors = list(a.scheduler.pending)
        assert len(done_a) + len(survivors) == len(reqs), "request lost at drain"

        b = _engine(model, params, PrefixStore(store, "sweep"))
        for r in survivors:
            if r.uid in ckpts:
                b.submit_resume(ckpts[r.uid])
            else:
                # a replayed request re-enters the fleet through the queue
                # with a fresh local stream; temperature > 0 here, so pin
                # the original stream the oracle drew (what the greedy
                # production path gets for free) to isolate KV correctness
                clone = _clones([r])[0]
                b.submit([clone])
                clone.sample_stream = r.sample_stream
        b.run_to_completion()
        b.cache_mgr.check_invariants()

        got = dict(done_a)
        got.update({r.uid: list(r.output) for r in b.finished})
        assert got == want, f"crash at tick {t} diverged"
        assert b.stats.checkpoint_resumes == len(ckpts)
        total_resumes += b.stats.checkpoint_resumes
        total_recovered += b.stats.tokens_recovered
        assert b.stats.tokens_recovered == sum(
            len(c["output"]) - 1 for c in ckpts.values())

    # the sweep must actually have exercised mid-decode resumption
    assert total_resumes > 0 and total_recovered > 0


# --------------------------------------------------- checkpoint fallback
def _ctx(tmp_path):
    return SimpleNamespace(store=ObjectStore(str(tmp_path / "ctx")),
                           clock=VirtualClock())


def _mid_decode_checkpoint(model, params, store, req):
    """Run a request partway, checkpoint it, and return (sealed record,
    the oracle's full output)."""
    oracle = _engine(model, params, PrefixStore(store, "ladder"))
    oracle.submit(_clones([req]))
    oracle.run_to_completion()
    want = list(oracle.finished[0].output)
    assert len(want) >= 3

    a = _engine(model, params, PrefixStore(store, "ladder"))
    a.submit(_clones([req]))
    while (a.scheduler.slots[0].req is None
           or len(a.scheduler.slots[0].req.output) < 2):
        a.step()  # admission happens at the first tick
    ck = a.checkpoint_slot(0)
    assert ck is not None and 2 <= len(ck["output"]) < len(want)
    a.cache_mgr.flush_store()
    return _seal_checkpoint(ck), want


def test_fallback_ladder_missing_corrupt_and_mismatched(tmp_path):
    """Rung one resumes byte-identically from a sealed checkpoint; a
    missing, bit-flipped, or request-mismatched record is a counted
    ``checkpoint_fallback`` and the full replay still lands on the
    oracle's exact tokens."""
    _, model, params = _setup()
    store = ObjectStore(str(tmp_path / "store"))
    ctx = _ctx(tmp_path)
    req = Request(uid="lad/0", prompt=[5, 6, 7, 8, 9], max_new_tokens=4,
                  temperature=0.5)
    sealed, want = _mid_decode_checkpoint(model, params, store, req)
    prefix = "serve/x/checkpoints/"
    key = f"{prefix}{_uid_safe(req.uid)}.json"
    assert "/" not in _uid_safe(req.uid)[4:]  # uid slash never splits the key

    # rung one: valid checkpoint -> mid-decode resume, byte-identical
    ctx.store.put_json(key, sealed)
    b = _engine(model, params, PrefixStore(store, "ladder"))
    assert _try_resume(b, ctx, prefix, _clones([req])[0]) is not None
    b.run_to_completion()
    assert list(b.finished[0].output) == want
    assert b.stats.checkpoint_resumes == 1 and b.stats.checkpoint_fallbacks == 0
    assert b.stats.tokens_recovered == len(sealed["output"]) - 1

    # sha seal: tampering any field (or resealing a record that no longer
    # matches the queue message) fails validation
    flipped = dict(sealed, output=[sealed["output"][0] + 1]
                   + sealed["output"][1:])
    assert not _checkpoint_valid(flipped, _clones([req])[0])
    wrong_req = dict(sealed)
    wrong_req.pop("sha")
    wrong_req = _seal_checkpoint(dict(wrong_req, max_new_tokens=99))
    assert not _checkpoint_valid(wrong_req, _clones([req])[0])

    for label, record in (("missing", None), ("corrupt", flipped),
                          ("mismatched", wrong_req)):
        if record is None:
            ctx.store.delete(key)
        else:
            ctx.store.put_json(key, record)
        c = _engine(model, params, PrefixStore(store, "ladder"))
        clone = _clones([req])[0]
        assert _try_resume(c, ctx, prefix, clone) is None, label
        assert c.stats.checkpoint_fallbacks == 1 and c.stats.checkpoint_resumes == 0
        c.submit([clone])  # rungs two/three: replay (store pages may stitch)
        c.run_to_completion()
        assert list(c.finished[0].output) == want, label


# ------------------------------------------------------- async publisher
def _page_arrays():
    return {"k": np.arange(8, dtype=np.float32).reshape(2, 4),
            "v": np.ones((2, 4), np.float32)}


def test_async_publisher_retries_transient_faults(tmp_path):
    ps = PrefixStore(ObjectStore(str(tmp_path / "s")), "pub")
    page = ps.child_key(ps.root_key(), [1, 2, 3])
    real_publish, fails = ps.publish, {"n": 2}

    def flaky(page_key, arrays):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise ConnectionError("transient put")
        real_publish(page_key, arrays)

    ps.publish = flaky
    pub = AsyncPublisher(ps, max_attempts=4, retry_base=0.0, retry_cap=0.0)
    pub.submit(page, _page_arrays())
    pub.flush()
    assert pub.retries == 2 and pub.errors == 0
    assert ps.exists(page)
    assert ps.fetch(page, _page_arrays()) is not None
    pub.close()


def test_async_publisher_gives_up_after_max_attempts(tmp_path):
    ps = PrefixStore(ObjectStore(str(tmp_path / "s")), "pub")
    page = ps.child_key(ps.root_key(), [4, 5, 6])

    def always_down(page_key, arrays):
        raise ConnectionError("store down")

    ps.publish = always_down
    pub = AsyncPublisher(ps, max_attempts=3, retry_base=0.0, retry_cap=0.0)
    pub.submit(page, _page_arrays())
    pub.flush()
    # every attempt but the last counts as a retry; the final failure is
    # a dropped page (cold for other workers), never an exception
    assert pub.retries == 2 and pub.errors == 1
    assert not ps.exists(page)
    pub.close()
    with pytest.raises(ValueError):
        AsyncPublisher(ps, max_attempts=0)


# ------------------------------------------------- content-hash verification
def test_fetch_rejects_hash_mismatch_as_counted_miss(tmp_path):
    ps = PrefixStore(ObjectStore(str(tmp_path / "s")), "hash")
    like = _page_arrays()
    page = ps.child_key(ps.root_key(), [7, 8, 9])
    other = ps.child_key(ps.root_key(), [10, 11, 12])
    ps.publish(page, like)
    assert ps.fetch(page, like) is not None and ps.hash_mismatches == 0

    # blob whose digest binds it to a DIFFERENT key (wrong-content
    # overwrite / blob copied under the wrong key)
    ps.store.put_bytes(ps._object_key(page),
                       PrefixStore.pack(like, page_key=other))
    assert ps.fetch(page, like) is None and ps.hash_mismatches == 1

    # legacy/digest-less blob: also rejected (no binding to verify)
    ps.store.put_bytes(ps._object_key(page), PrefixStore.pack(like))
    assert ps.fetch(page, like) is None and ps.hash_mismatches == 2

    # republishing heals the key
    ps.publish(page, like)
    got = ps.fetch(page, like)
    assert got is not None and np.array_equal(got["k"], like["k"])
    assert ps.hash_mismatches == 2


# ----------------------------------------------------- chaos flaky faults
def _fleet(clk, name):
    return SpotFleet(FleetFile(startup_seconds=0.0), clock=clk, app_name=name)


def test_flaky_storage_faults_first_attempt_per_key_within_scope(tmp_path):
    clk = VirtualClock()
    store = ObjectStore(str(tmp_path / "store"))
    chaos = ChaosMonkey(_fleet(clk, "Flaky"), clk, store=store, events=[
        ChaosEvent(kind="flaky_storage", at=0.0, duration=60.0,
                   scope="serve/"),
    ])
    assert [r.kind for r in chaos.tick()] == ["flaky_storage"]

    store.put_bytes("other/x", b"ok")  # outside scope: untouched
    with pytest.raises(ConnectionError):
        store.put_bytes("serve/a", b"1")
    store.put_bytes("serve/a", b"1")  # second attempt on the key succeeds
    with pytest.raises(ConnectionError):
        store.get_bytes("serve/a")  # get is a distinct (op, key) token
    assert store.get_bytes("serve/a") == b"1"
    assert chaos.counters["storage_faults"] == 2

    # the serve-side retry helper rides straight through the window on a
    # fresh key: one transient fault, retried, data lands
    _with_retries(lambda: store.put_bytes("serve/c", b"3"),
                  key="serve/c", clock=clk)
    assert chaos.counters["storage_faults"] == 3
    # window expiry: the wrapper stays installed but passes through
    clk.sleep(120.0)
    assert store.get_bytes("serve/c") == b"3"
    store.put_bytes("serve/b", b"2")
    assert chaos.counters["storage_faults"] == 3  # nothing new after expiry


def test_flaky_queue_hook_faults_consumer_ops_once_each(tmp_path):
    clk = VirtualClock()
    q = DurableQueue(str(tmp_path / "q.sqlite"), clock=clk)
    q.send_batch([{"i": i} for i in range(3)])
    chaos = ChaosMonkey(_fleet(clk, "FlakyQ"), clk, queue=q, events=[
        ChaosEvent(kind="flaky_queue", at=0.0, duration=30.0),
    ])
    assert [r.kind for r in chaos.tick()] == ["flaky_queue"]

    q.send({"i": 99})  # the producer side is never faulted
    with pytest.raises(ConnectionError):
        q.receive()
    m = q.receive()  # first retry succeeds: no message is ever lost
    assert m is not None
    with pytest.raises(ConnectionError):
        q.delete(m)
    assert q.delete(m)
    assert chaos.counters["queue_faults"] == 2

    # the hook reaches EVERY handle on the same sqlite file (workers open
    # their own), keyed by absolute path — and a second window re-arms
    other_handle = DurableQueue(q.path, clock=clk)
    chaos._arm_flaky_queue(ChaosEvent(kind="flaky_queue", at=0.0,
                                      duration=30.0), clk.now())
    with pytest.raises(ConnectionError):
        other_handle.receive()
    assert other_handle.receive() is not None
    remove_fault_hook(q.path)
    assert q.receive() is not None  # unhooked: clean


def test_queue_fault_hook_registry_is_per_path(tmp_path):
    clk = VirtualClock()
    q1 = DurableQueue(str(tmp_path / "a.sqlite"), clock=clk)
    q2 = DurableQueue(str(tmp_path / "b.sqlite"), clock=clk)
    q1.send({"x": 1})
    q2.send({"x": 2})
    calls = []
    install_fault_hook(q1.path, lambda op, path: calls.append((op, path)))
    try:
        assert q1.receive() is not None
        assert q2.receive() is not None  # other path: hook never consulted
    finally:
        remove_fault_hook(q1.path)
    assert [op for op, _ in calls] == ["receive"]


def test_with_retries_exhausts_then_raises_and_misses_propagate():
    clk = VirtualClock()
    calls = {"n": 0}

    def always_flaky():
        calls["n"] += 1
        raise ConnectionError("down")

    with pytest.raises(ConnectionError):
        _with_retries(always_flaky, key="k", clock=clk, attempts=3)
    assert calls["n"] == 3

    def miss():
        calls["n"] += 1
        raise FileNotFoundError("no such key")

    calls["n"] = 0
    with pytest.raises(FileNotFoundError):
        _with_retries(miss, key="k", clock=clk, attempts=3)
    assert calls["n"] == 1  # a miss is not transient: no retry burned

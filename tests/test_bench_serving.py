"""Tier-1 smoke run of the serving benchmark: a regression in the fused
engine's dispatch count, the paged-cache accounting, or the shared-prefix
radix cache (hit rate, prefill skipping, token parity) fails fast on CPU."""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

import bench_serving
import check_bench


def test_bench_serving_smoke_dispatch_reduction(tmp_path):
    out = os.path.join(tmp_path, "BENCH_serving.json")
    rc = bench_serving.main(["--smoke", "--out", out])
    assert rc == 0, "fused engine must dispatch strictly less than grouped"
    report = json.load(open(out))
    fused = report["engines"]["fused"]
    grouped = report["engines"]["grouped"]
    # acceptance: dispatches/token strictly lower than the seed-style engine
    assert fused["dispatches_per_token"] < grouped["dispatches_per_token"]
    assert fused["tokens_per_sec"] > 0 and grouped["tokens_per_sec"] > 0
    # prompt ingestion is chunked, not token-at-a-time
    assert fused["prompt_tokens_per_prefill_dispatch"] > 1.0
    assert grouped["prefill_dispatches"] == 0  # seed-style path has none
    # paged scenario: peak cache strictly below the dense reservation at
    # equal concurrency, same dispatch schedule as the fused engine
    paged = report["engines"]["paged"]
    assert paged["peak_cache_bytes"] < paged["dense_cache_bytes"]
    assert paged["pages_in_use_peak"] <= paged["total_pages"]
    assert paged["dispatches_per_token"] == fused["dispatches_per_token"]
    assert paged["tokens_emitted"] == fused["tokens_emitted"]
    assert report["paged_cache_reduction"] > 1.0
    # shared-prefix scenario: the radix cache must actually hit (rc=0
    # above already gates paged-vs-dense token divergence byte-for-byte),
    # skip >= 2x of the prompt prefill work, and store shared pages once
    # (lower peak than the per-slot paged engine)
    sp = report["shared_prefix"]
    prefix = sp["engines"]["paged_prefix"]
    assert prefix["prefix_hit_tokens"] > 0
    assert prefix["prompt_tokens_skipped"] > 0
    assert prefix["pages_shared_peak"] > 0
    assert sp["prefill_reduction"] >= 2.0
    assert prefix["peak_cache_bytes"] < sp["engines"]["paged"]["peak_cache_bytes"]
    assert prefix["tokens_emitted"] == sp["engines"]["fused"]["tokens_emitted"]
    # every scenario now records queue-wait / TTFT percentiles (ticks)
    assert fused["timing"]["ttft_ticks"]["n"] > 0
    assert prefix["timing"]["queue_wait_ticks"]["n"] > 0
    # mid-page-divergence scenario: sub-page (token-granularity) matching
    # must recover tokens inside the first divergent page (rc=0 above
    # already gates byte-identical outputs across dense/page/token) and
    # prefill strictly fewer prompt tokens than page-aligned matching
    mp = report["midpage_divergence"]
    tok = mp["engines"]["paged_prefix_token"]
    pg = mp["engines"]["paged_prefix_page"]
    assert tok["prefix_hit_tokens_partial"] > 0
    assert tok["cow_partial_stitches"] > 0
    assert pg["prefix_hit_tokens_partial"] == 0  # page-aligned baseline
    assert tok["prompt_tokens_ingested"] < pg["prompt_tokens_ingested"]
    assert mp["prefill_reduction_vs_page_aligned"] > 1.0
    assert tok["tokens_emitted"] == mp["engines"]["fused"]["tokens_emitted"]
    # decode-heavy speculative scenario: rc=0 above already gates
    # byte-identical outputs across off/ngram/draft — here assert both
    # proposers actually speculated and that the ngram proposer cut
    # target dispatches per token (counter-based, deterministic)
    spec = report["speculative"]
    off = spec["engines"]["off"]
    ngram = spec["engines"]["ngram"]
    draft = spec["engines"]["draft"]
    assert off["spec_dispatches"] == 0 and off["draft_tokens_proposed"] == 0
    for eng in (ngram, draft):
        assert eng["spec_dispatches"] > 0
        assert eng["draft_tokens_proposed"] > 0
        assert eng["tokens_emitted"] == off["tokens_emitted"]
    assert draft["draft_dispatches"] > 0  # the draft model actually ran
    assert ngram["draft_tokens_accepted"] > 0
    assert ngram["dispatches_per_token"] < off["dispatches_per_token"]
    assert ngram["accepted_per_dispatch"] >= 2.0
    assert max(spec["dispatch_reduction_vs_off"].values()) > 1.0
    # continuous-batching scenario: staggered arrivals must be admitted
    # mid-flight (rc=0 above already gates byte-identical outputs), with
    # strictly lower mean time-to-first-token than drain-then-refill
    cb = report["continuous_batching"]
    cont, drain = cb["engines"]["continuous"], cb["engines"]["drain"]
    assert cont["mean_ttft_ticks"] < drain["mean_ttft_ticks"]
    assert cb["ttft_reduction"] > 1.0
    assert cont["tokens_emitted"] == drain["tokens_emitted"]
    # elastic-churn drill: both fleets ride out >= 2 mid-spike spot
    # revocations losing nothing and diverging nowhere (rc=0 above gates
    # the hard failures); the autoscaled fleet must beat the static p99
    # and its survivors must hydrate the shared prefix from the store
    churn = report["elastic_churn"]["engines"]
    for fleet_name in ("static", "autoscaled"):
        eng = churn[fleet_name]
        assert eng["lost_requests"] == 0
        assert eng["byte_identical"] is True
        assert eng["revocations_injected"] >= 2
        assert eng["revocation_notices"] >= 1  # somebody drained gracefully
    assert churn["autoscaled"]["prefix_store_pages_hydrated"] > 0
    assert churn["autoscaled"]["workers_peak"] > churn["static"]["workers_peak"]
    assert (churn["autoscaled"]["p99_ttft_s"]
            < churn["static"]["p99_ttft_s"])
    assert report["elastic_churn"]["p99_ttft_reduction"] > 1.0
    # recovery_drill: all three fleets survive revocations + flaky
    # storage/queue windows losing nothing and diverging nowhere (rc=0
    # above gates the hard failures); the checkpointing fleet resumes
    # generations mid-decode instead of replaying them, and the
    # sabotaged fleet walks the fallback ladder to full replay
    rec = report["recovery_drill"]["engines"]
    for leg in ("replay", "checkpoint", "sabotage"):
        eng = rec[leg]
        assert eng["lost_requests"] == 0
        assert eng["byte_identical"] is True
        assert eng["revocations_injected"] >= 2
        assert eng["storage_faults"] > 0  # flaky windows actually fired
        assert eng["queue_faults"] > 0
    assert rec["replay"]["checkpoints_published"] == 0
    assert rec["replay"]["tokens_redecoded"] > 0
    assert rec["checkpoint"]["checkpoints_published"] > 0
    assert rec["checkpoint"]["checkpoint_resumes"] > 0
    assert rec["checkpoint"]["tokens_recovered"] > 0
    assert rec["sabotage"]["checkpoint_fallbacks"] > 0
    assert rec["sabotage"]["checkpoint_resumes"] == 0
    assert report["recovery_drill"]["redecode_reduction"] >= 3.0
    # disaggregation drill: at equal hardware both fleet topologies are
    # byte-identical and lossless (rc=0 above gates the hard failures);
    # every request travels the storage-mediated handoff path, the
    # prefill pool never decodes, the decode pool hydrates its KV from
    # the prefix store, and the decode side strictly beats the monolith
    # on p99 TTFT and tokens per engine tick — all counter-derived
    dg = report["disaggregation"]["engines"]
    dg_n = report["disaggregation"]["scenario"]["n_requests"]
    for leg in ("monolith", "split"):
        eng = dg[leg]
        assert eng["lost_requests"] == 0
        assert eng["dead_letters"] == 0
        assert eng["byte_identical"] is True
    split = dg["split"]
    assert split["handoffs_published"] == split["handoffs_admitted"] == dg_n
    assert split["handoff_fallbacks"] == 0
    assert split["handoff_seal_rejects"] == 0
    assert dg["monolith"]["handoffs_published"] == 0
    assert split["roles"]["prefill"]["tokens_emitted"] == 0
    assert split["roles"]["prefill"]["decode_dispatches"] == 0
    assert split["prefix_store_pages_hydrated"] > 0
    assert split["hydration_fetch_ops"] > 0
    assert split["prefix_store_bytes_fetched"] > 0
    assert split["ttft_ticks_p99"] < dg["monolith"]["ttft_ticks_p99"]
    assert split["tokens_per_tick"] > dg["monolith"]["tokens_per_tick"]
    assert report["disaggregation"]["decode_ttft_p99_reduction"] > 1.0
    # the freshly-generated report must satisfy the published schema,
    # and every scenario block must be gated by this test file
    assert check_bench.check_report(report) == []
    assert check_bench.check_test_coverage(open(__file__).read()) == []


def test_committed_bench_report_schema():
    """The checked-in full-run BENCH_serving.json must match the schema
    too — a bench refactor has to regenerate it, not strand it."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")
    report = json.load(open(path))
    assert check_bench.check_report(report) == []
    assert not report["smoke"], "committed report must come from a full run"

"""Distribution correctness on a host-device mesh (subprocess-isolated).

- sharded loss == single-device loss for a dense and an SSM arch
- rules engine produces legal, memory-reducing specs for every arch
- decode under the flash-decode rule set matches unsharded decode
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, list_archs, reduced
from repro.models import Model, ModelRuntime
from repro.sharding import ShardingPolicy, axis_rules, bytes_per_device, param_specs, train_rules
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))

# 1. specs legality + FSDP reduction for every arch
for arch in list_archs():
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime(moe_strategy="dense"))
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    b_tp = bytes_per_device(shapes, param_specs(shapes, mesh, ShardingPolicy())[0], mesh)
    b_fsdp = bytes_per_device(shapes, param_specs(shapes, mesh, ShardingPolicy(fsdp_axes=("data",)))[0], mesh)
    assert b_fsdp < b_tp, f"{arch}: FSDP must reduce per-device bytes ({b_fsdp} vs {b_tp})"
    # legality: building NamedShardings raises on duplicate axes etc.
    jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(shapes, mesh, ShardingPolicy(fsdp_axes=("data",)))[0],
                 is_leaf=lambda x: isinstance(x, P))
print("SPECS-OK")

# 2. train-loss parity, dense + ssm
for arch in ("ds-paper-100m", "mamba2-1.3b"):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    ref = float(model.loss(params, batch)[0])

    specs, _ = param_specs(jax.eval_shape(lambda: params), mesh, ShardingPolicy(fsdp_axes=("data",)))
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P))
    ps = jax.device_put(params, shardings)
    bs = jax.device_put(batch, NamedSharding(mesh, P("data", None)))

    def loss_fn(p, b):
        with axis_rules(mesh, train_rules(multi_pod=False)):
            return model.loss(p, b)[0]

    with jax.set_mesh(mesh):
        dist = float(jax.jit(loss_fn, in_shardings=(shardings, NamedSharding(mesh, P("data", None))))(ps, bs))
    assert abs(ref - dist) < 1e-4, f"{arch}: {ref} vs {dist}"
print("PARITY-OK")

# 3. grad parity (distributed backward == local backward), dense arch
cfg = reduced(get_arch("ds-paper-100m"))
model = Model(cfg, ModelRuntime())
params = model.init(jax.random.PRNGKey(2))
toks = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
g_ref = jax.grad(lambda p: model.loss(p, batch)[0])(params)
specs, _ = param_specs(jax.eval_shape(lambda: params), mesh, ShardingPolicy(fsdp_axes=("data",)))
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P))
def gfn(p, b):
    with axis_rules(mesh, train_rules(multi_pod=False)):
        return jax.grad(lambda pp: model.loss(pp, b)[0])(p)
with jax.set_mesh(mesh):
    g_dist = jax.jit(gfn, in_shardings=(shardings, NamedSharding(mesh, P("data", None))))(
        jax.device_put(params, shardings), jax.device_put(batch, NamedSharding(mesh, P("data", None))))
err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
          zip(jax.tree.leaves(g_ref), jax.tree.leaves(jax.device_get(g_dist))))
assert err < 1e-4, f"grad mismatch {err}"
print("GRAD-OK")
"""


def test_distribution_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=560,
    )
    for marker in ("SPECS-OK", "PARITY-OK", "GRAD-OK"):
        assert marker in res.stdout, f"missing {marker}\nstdout={res.stdout}\nstderr={res.stderr[-3000:]}"

"""dslint (repro.analysis) tests: per-rule fixtures, pragma/baseline
behavior, the full-tree tier-1 gate, and the acceptance drills from the
PR spec (re-introducing PR 8's unretried PrefixStore put, dropping a
counter from a registry)."""

import json
import os

import pytest

from repro.analysis import run_analysis
from repro.analysis.engine import changed_files, update_baseline
from repro.analysis.rules import ALL_RULES

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "dslint")


def fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name), encoding="utf-8") as f:
        return f.read()


def lint_tree(tmp_path, files, **kwargs):
    """Write ``files`` (relpath -> source) under a fresh root and lint it
    with an empty baseline."""
    for rel, source in files.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(source, encoding="utf-8")
    kwargs.setdefault("baseline_path", str(tmp_path / "baseline.json"))
    return run_analysis(str(tmp_path), **kwargs)


def rules_fired(report):
    return sorted({f.rule for f in report.findings})


# --------------------------------------------------------------- rule catalog
def test_rule_ids_are_unique_and_titled():
    ids = [r.rule_id for r in ALL_RULES]
    assert len(ids) == len(set(ids))
    assert all(r.title for r in ALL_RULES)
    assert "R0" not in ids  # reserved for engine hygiene findings


# ------------------------------------------------------------- R1 fixtures
def test_r1_trips_on_bare_lease_ops(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r1_bad.py")}
    )
    r1 = [f for f in report.findings if f.rule == "R1"]
    assert len(r1) == 2, report.render()
    assert any("store.put_json" in f.message for f in r1)
    assert any("rq.delete" in f.message for f in r1)


def test_r1_passes_retry_wrapped_ops(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r1_good.py")}
    )
    assert report.ok, report.render()


def test_r1_ignores_modules_without_lease_role(tmp_path):
    source = fixture("r1_bad.py").replace("# dslint-role: lease", "")
    report = lint_tree(tmp_path, {"src/repro/fix.py": source})
    assert report.ok, report.render()


# ------------------------------------------------------------- R2 fixtures
def test_r2_trips_on_ack_before_put(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r2_bad.py")}
    )
    assert rules_fired(report) == ["R2"], report.render()


def test_r2_passes_put_then_ack_and_cross_loop_order(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r2_good.py")}
    )
    assert report.ok, report.render()


# ------------------------------------------------------------- R3 fixtures
def test_r3_trips_on_clock_rng_and_set_iteration(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r3_bad.py")}
    )
    r3 = [f for f in report.findings if f.rule == "R3"]
    assert len(r3) == 3, report.render()
    blob = " ".join(f.message for f in r3)
    assert "time.time" in blob and "random.random" in blob and "seen" in blob


def test_r3_passes_seeded_and_sorted(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r3_good.py")}
    )
    assert report.ok, report.render()


# ------------------------------------------------------------- R5 fixtures
def test_r5_trips_on_unlocked_shared_writes(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r5_bad.py")}
    )
    r5 = [f for f in report.findings if f.rule == "R5"]
    assert len(r5) == 2, report.render()  # one per unguarded side
    assert all("pending" in f.message for f in r5)


def test_r5_passes_locked_and_single_writer(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r5_good.py")}
    )
    assert report.ok, report.render()


def test_r5_flags_module_globals_in_lease_modules(tmp_path):
    source = "# dslint-role: lease\nCACHE = {}\n"
    report = lint_tree(tmp_path, {"src/repro/fix.py": source})
    assert rules_fired(report) == ["R5"], report.render()
    suppressed = source.replace(
        "CACHE = {}", "CACHE = {}  # dslint: disable=R5(per-key ownership)"
    )
    report = lint_tree(tmp_path, {"src/repro/fix.py": suppressed})
    assert report.ok, report.render()


# --------------------------------------------------------- R4 (project rule)
TYPES_EXPLICIT = '''
from dataclasses import dataclass

@dataclass
class EngineStats:
    ticks: int = 0
    tokens_emitted: int = 0
    _scratch: int = 0

    def snapshot(self):
        return {"ticks": self.ticks}
'''

TYPES_DYNAMIC = '''
from dataclasses import dataclass, fields

@dataclass
class EngineStats:
    ticks: int = 0
    tokens_emitted: int = 0
    _scratch: int = 0

    def snapshot(self):
        return {f.name: getattr(self, f.name) for f in fields(self)
                if not f.name.startswith("_")}
'''

DOCS_BOTH = "counters: `ticks` and `tokens_emitted`\n"


def test_r4_trips_on_counter_dropped_from_snapshot(tmp_path):
    report = lint_tree(tmp_path, {
        "src/repro/serving/types.py": TYPES_EXPLICIT,
        "docs/serving.md": DOCS_BOTH,
    })
    r4 = [f for f in report.findings if f.rule == "R4"]
    assert len(r4) == 1 and "tokens_emitted" in r4[0].message, report.render()
    assert "snapshot" in r4[0].message


def test_r4_trips_on_undocumented_counter(tmp_path):
    report = lint_tree(tmp_path, {
        "src/repro/serving/types.py": TYPES_DYNAMIC,
        "docs/serving.md": "counters: `ticks`\n",
    })
    r4 = [f for f in report.findings if f.rule == "R4"]
    assert len(r4) == 1 and "tokens_emitted" in r4[0].message, report.render()
    assert "docs/serving.md" in r4[0].message


def test_r4_passes_agreeing_registries(tmp_path):
    report = lint_tree(tmp_path, {
        "src/repro/serving/types.py": TYPES_DYNAMIC,
        "docs/serving.md": DOCS_BOTH,
    })
    assert report.ok, report.render()


def test_r4_trips_on_phantom_bench_schema_key(tmp_path):
    check_bench = (
        "DERIVED_KEYS = frozenset()\n"
        'SCENARIOS = {"s": (("engines",), ("e",), ("phantom_counter",), ())}\n'
    )
    report = lint_tree(tmp_path, {
        "src/repro/serving/types.py": TYPES_DYNAMIC,
        "docs/serving.md": DOCS_BOTH,
        "benchmarks/check_bench.py": check_bench,
    })
    r4 = [f for f in report.findings if f.rule == "R4"]
    assert len(r4) == 1 and "phantom_counter" in r4[0].message, report.render()


def test_real_bench_schema_keys_all_classified():
    """Direct form of the R4 invariant against the real repo: every key
    check_bench requires is an EngineStats field, a snapshot()-derived
    key, or a declared DERIVED_KEYS member."""
    import dataclasses
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "cb_r4", os.path.join(REPO_ROOT, "benchmarks", "check_bench.py")
    )
    cb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cb)
    from repro.serving.types import EngineStats

    fields = {
        f.name for f in dataclasses.fields(EngineStats)
        if not f.name.startswith("_")
    }
    allowed = fields | {"accepted_per_dispatch", "hydration_ticks"} | set(
        cb.DERIVED_KEYS
    )
    for name, (_p, _e, engine_keys, derived) in cb.SCENARIOS.items():
        unclassified = (set(engine_keys) | set(derived)) - allowed
        assert not unclassified, f"scenario {name}: {sorted(unclassified)}"


# --------------------------------------------------------- R6 (project rule)
OPS_FIXTURE = "def myop(x):\n    return x\n\n\ndef _helper(x):\n    return x\n"


def test_r6_trips_on_missing_oracle_and_test(tmp_path):
    report = lint_tree(tmp_path, {
        "src/repro/kernels/ops.py": OPS_FIXTURE,
        "src/repro/kernels/ref.py": "def myop_reference(x):\n    return x\n",
        "tests/test_kernels.py": "def test_other():\n    pass\n",
    })
    r6 = [f for f in report.findings if f.rule == "R6"]
    msgs = " | ".join(f.message for f in r6)
    assert "no module-level ORACLES" in msgs, report.render()
    assert "no ORACLES entry" in msgs
    assert "never referenced" in msgs


def test_r6_passes_registered_and_tested_kernel(tmp_path):
    report = lint_tree(tmp_path, {
        "src/repro/kernels/ops.py": OPS_FIXTURE,
        "src/repro/kernels/ref.py": (
            "def myop_reference(x):\n    return x\n\n\n"
            'ORACLES = {"myop": myop_reference}\n'
        ),
        "tests/test_kernels.py": "def test_myop():\n    assert myop\n",
    })
    assert report.ok, report.render()


# --------------------------------------------------------- R7 (project rule)
CONFIG_FIXTURE = '''
from dataclasses import dataclass

INERT_PAPER_FIELDS = {
    "dead_knob": "paper parity: nothing to size in the simulation",
    "vanished": "covers a field that no longer exists",
}

@dataclass
class DSConfig:
    live_knob: int = 1
    dead_knob: int = 2
    ghost_knob: int = 3
'''


def test_r7_trips_on_inert_and_stale_entries(tmp_path):
    report = lint_tree(tmp_path, {
        "src/repro/core/config.py": CONFIG_FIXTURE,
        "src/repro/core/user.py": "def use(cfg):\n    return cfg.live_knob\n",
    })
    r7 = [f for f in report.findings if f.rule == "R7"]
    msgs = " | ".join(f.message for f in r7)
    assert "ghost_knob" in msgs, report.render()  # consumed nowhere
    assert "vanished" in msgs  # stale refusal entry
    assert "dead_knob" not in msgs  # refused with a reason: fine
    assert "live_knob" not in msgs  # consumed: fine


def test_r7_consumption_via_string_or_kwarg_counts(tmp_path):
    user = (
        'def use(d, **kw):\n'
        '    a = d["ghost_knob"]\n'
        '    return a\n'
    )
    report = lint_tree(tmp_path, {
        "src/repro/core/config.py": CONFIG_FIXTURE,
        "src/repro/core/user.py": (
            "def use(cfg):\n    return cfg.live_knob\n" + user
        ),
    })
    msgs = " | ".join(f.message for f in report.findings if f.rule == "R7")
    assert "ghost_knob" not in msgs, report.render()


# ------------------------------------------------------- pragmas & baseline
def test_pragma_suppresses_but_hygiene_fires(tmp_path):
    report = lint_tree(
        tmp_path, {"src/repro/fix.py": fixture("r0_bad.py")}
    )
    # the R1 finding is suppressed by the (malformed) pragma...
    assert not any(f.rule == "R1" for f in report.findings)
    assert len(report.suppressed) == 1
    # ...but the empty reason and the unknown rule id are R0 findings
    r0 = [f for f in report.findings if f.rule == "R0"]
    msgs = " | ".join(f.message for f in r0)
    assert "no reason" in msgs and "R99" in msgs, report.render()


def test_pragma_on_def_header_covers_the_body(tmp_path):
    source = (
        "# dslint-role: lease\n"
        "def probe(store, key):  # dslint: disable=R1(probe is best-effort)\n"
        "    return store.exists(key)\n"
    )
    report = lint_tree(tmp_path, {"src/repro/fix.py": source})
    assert report.ok, report.render()
    assert len(report.suppressed) == 1


def test_baseline_workflow_roundtrip(tmp_path):
    files = {"src/repro/fix.py": fixture("r1_bad.py")}
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src, encoding="utf-8")
    bl = tmp_path / "baseline.json"

    report = run_analysis(str(tmp_path), baseline_path=str(bl))
    assert len(report.findings) == 2

    with pytest.raises(ValueError):
        update_baseline(str(tmp_path), justification="  ",
                        baseline_path=str(bl))

    update_baseline(str(tmp_path), justification="known, tracked elsewhere",
                    baseline_path=str(bl))
    report = run_analysis(str(tmp_path), baseline_path=str(bl))
    assert report.ok and len(report.baselined) == 2, report.render()

    # fingerprints survive unrelated edits above the finding
    p = tmp_path / "src/repro/fix.py"
    p.write_text("# new leading comment\n" + p.read_text(), encoding="utf-8")
    report = run_analysis(str(tmp_path), baseline_path=str(bl))
    assert report.ok and len(report.baselined) == 2, report.render()

    # fixing the violations makes the entries stale (full runs only)
    p.write_text(fixture("r1_good.py"), encoding="utf-8")
    report = run_analysis(str(tmp_path), baseline_path=str(bl))
    assert not report.findings and len(report.stale_baseline) == 2
    update_baseline(str(tmp_path), justification="sweep stale",
                    baseline_path=str(bl))
    assert json.loads(bl.read_text()) == {}


def test_baseline_entry_without_justification_is_a_finding(tmp_path):
    files = {"src/repro/fix.py": fixture("r2_bad.py")}
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src, encoding="utf-8")
    bl = tmp_path / "baseline.json"
    report = run_analysis(str(tmp_path), baseline_path=str(bl))
    (fp,) = [f.fingerprint for f in report.findings]
    bl.write_text(json.dumps({fp: {"rule": "R2", "justification": ""}}))
    report = run_analysis(str(tmp_path), baseline_path=str(bl))
    assert rules_fired(report) == ["R0"], report.render()
    assert "no written" in report.findings[0].message


# ------------------------------------------------------ paths / changed mode
def test_paths_mode_limits_module_findings(tmp_path):
    files = {
        "src/repro/bad.py": fixture("r1_bad.py"),
        "src/repro/other.py": fixture("r3_bad.py"),
    }
    report = lint_tree(tmp_path, files, paths=["src/repro/other.py"])
    assert rules_fired(report) == ["R3"], report.render()
    # stale-baseline detection is deferred on partial runs
    assert report.stale_baseline == []


def test_changed_files_runs_on_the_repo():
    out = changed_files(REPO_ROOT)
    assert isinstance(out, list)
    assert all(p.startswith("src/repro/") for p in out)


def test_cli_list_rules_and_clean_run(capsys):
    from repro.analysis.__main__ import main

    assert main(["--list-rules"]) == 0
    listed = capsys.readouterr().out
    for rule in ALL_RULES:
        assert rule.rule_id in listed


# ------------------------------------------------------------ tier-1 gates
def test_repo_tree_is_clean():
    """THE tier-1 gate: the real tree has zero unbaselined findings and
    no stale baseline entries."""
    report = run_analysis(REPO_ROOT)
    assert report.ok, report.render()
    assert report.stale_baseline == [], report.render()


def test_acceptance_reintroduced_bare_prefix_store_put(tmp_path):
    """Stripping the publish pragma (= re-introducing PR 8's unretried
    put) must fail the lint."""
    src_path = os.path.join(
        REPO_ROOT, "src", "repro", "serving", "prefix_store.py"
    )
    with open(src_path, encoding="utf-8") as f:
        source = f.read()
    assert "# dslint: disable=R1" in source
    import re

    stripped = re.sub(r"\s*# dslint: disable=R1[^\n]*", "", source)
    report = lint_tree(
        tmp_path, {"src/repro/serving/prefix_store.py": stripped}
    )
    r1 = [f for f in report.findings if f.rule == "R1"]
    assert any("put_bytes" in f.message for f in r1), report.render()


def test_acceptance_counter_dropped_from_docs(tmp_path):
    """Un-documenting a real counter must fail the lint."""
    with open(
        os.path.join(REPO_ROOT, "src", "repro", "serving", "types.py"),
        encoding="utf-8",
    ) as f:
        types_src = f.read()
    with open(
        os.path.join(REPO_ROOT, "docs", "serving.md"), encoding="utf-8"
    ) as f:
        docs = f.read()
    report = lint_tree(tmp_path, {
        "src/repro/serving/types.py": types_src,
        "docs/serving.md": docs.replace("`ticks`", "ticks"),
    })
    r4 = [f for f in report.findings if f.rule == "R4"]
    assert any("'ticks'" in f.message for f in r4), report.render()

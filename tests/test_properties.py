"""Property-based tests for the system's invariants.

Uses hypothesis when available, else the seeded shim in ``proptest.py``.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from proptest import given, settings, st

from repro.core import DurableQueue, VirtualClock
from repro.core.jobs import JobFile
from repro.train import compression
from repro.train.optimizer import dequantize_blockwise, quantize_blockwise


# ------------------------------------------------------------------- queue
@settings(max_examples=20, deadline=None)
@given(
    n_msgs=st.integers(1, 30),
    visibility=st.floats(1.0, 50.0),
    max_rc=st.integers(1, 5),
    fail_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 10_000),
)
def test_queue_conservation(tmp_path_factory, n_msgs, visibility, max_rc, fail_frac, seed):
    """Invariant: every message is eventually either acknowledged exactly
    once or dead-lettered — none lost, none duplicated-on-ack."""
    import random

    rng = random.Random(seed)
    clk = VirtualClock()
    q = DurableQueue(
        os.path.join(tmp_path_factory.mktemp("q"), "q.sqlite"),
        default_visibility=visibility,
        max_receive_count=max_rc,
        clock=clk,
    )
    q.send_batch([{"i": i} for i in range(n_msgs)])
    acked = set()
    for _ in range(n_msgs * (max_rc + 2) * 3):
        m = q.receive()
        if m is None:
            clk.advance(visibility + 0.1)
            c = q.counts()
            if c["visible"] == 0 and c["in_flight"] == 0:
                break
            continue
        if rng.random() >= fail_frac or m.receive_count >= max_rc:
            assert m.body["i"] not in acked, "double ack of the same message"
            if q.delete(m):
                acked.add(m.body["i"])
    c = q.counts()
    assert c["visible"] == 0 and c["in_flight"] == 0
    dead = {m.body["i"] for m in q.dead_letters()}
    assert acked | dead == set(range(n_msgs)), "message lost"
    assert acked & dead == set(), "message both acked and dead-lettered"


@settings(max_examples=20, deadline=None)
@given(
    shared=st.integers(0, 5),
    groups=st.lists(st.integers(0, 100), min_size=0, max_size=20),
)
def test_jobfile_expansion_properties(shared, groups):
    jf = JobFile(
        shared={f"s{i}": i for i in range(shared)},
        groups=[{"g": g} for g in groups],
    )
    bodies = jf.expand()
    assert len(bodies) == len(groups)
    for i, b in enumerate(bodies):
        assert b["g"] == groups[i]
        for j in range(shared):
            assert b[f"s{j}"] == j  # shared keys present in every job
        assert b["group_index"] == i


def test_jobfile_group_overrides_shared():
    jf = JobFile(shared={"x": 1}, groups=[{"x": 2}, {}])
    bodies = jf.expand()
    assert bodies[0]["x"] == 2 and bodies[1]["x"] == 1


# ------------------------------------------------------------- quantization
@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.integers(1, 700),
    scale=st.floats(1e-6, 1e3),
    seed=st.integers(0, 1000),
)
def test_int8_moment_quantization_bounded_error(rows, cols, scale, seed):
    """|dequant(quant(x)) - x| <= blockmax/127 elementwise."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * scale
    qd = quantize_blockwise(x)
    y = dequantize_blockwise(qd, x.shape)
    err = np.asarray(jnp.abs(y - x))
    # bound: half a quantization step per 128-block (use global max as a cap)
    bound = float(jnp.max(jnp.abs(x))) / 127.0 + 1e-12
    assert err.max() <= bound * 1.0001


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-5, 10.0))
def test_stochastic_rounding_unbiased(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (16, 256)) * scale
    acc = jnp.zeros_like(g)
    n = 64
    for i in range(n):
        qd = compression.stochastic_round_int8(g, jax.random.PRNGKey(seed * 131 + i))
        acc = acc + compression.dequant_int8(qd, g.shape)
    mean = acc / n
    # bias shrinks as 1/sqrt(n); allow 5 sigma of the quantization noise
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(mean - g))) < 5 * step / np.sqrt(n) + 1e-9


# ------------------------------------------------------------------ data
@settings(max_examples=10, deadline=None)
@given(
    step=st.integers(0, 50),
    n_dp=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 100),
)
def test_data_pipeline_determinism_and_disjointness(step, n_dp, seed):
    from repro.configs import get_arch, reduced
    from repro.train.data import DataConfig, SyntheticLM

    cfg = reduced(get_arch("ds-paper-100m"))
    ds = SyntheticLM(cfg, DataConfig(seq_len=16, global_batch=4, seed=seed))
    a = ds.batch(step, dp_rank=0, n_dp=n_dp)
    b = ds.batch(step, dp_rank=0, n_dp=n_dp)
    assert (a["tokens"] == b["tokens"]).all(), "same (seed, step, rank) must repeat"
    c = ds.batch(step + 1, dp_rank=0, n_dp=n_dp)
    assert not (a["tokens"] == c["tokens"]).all(), "steps must differ"
    # labels are next-token shifted view of the same stream
    assert a["labels"].shape == a["tokens"].shape


# --------------------------------------------------------------- checkpoint
@settings(max_examples=10, deadline=None)
@given(
    shape=st.tuples(st.integers(1, 5), st.integers(1, 17)),
    dt=st.sampled_from(["float32", "bfloat16", "int32"]),
    seed=st.integers(0, 1000),
)
def test_checkpoint_roundtrip_property(tmp_path_factory, shape, dt, seed):
    from repro.core.storage import ObjectStore
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    store = ObjectStore(str(tmp_path_factory.mktemp("ckpt")))
    x = (jax.random.normal(jax.random.PRNGKey(seed), shape) * 100).astype(dt)
    tree = {"x": x, "nested": {"y": jnp.arange(3)}}
    save_checkpoint(store, "r", seed, tree)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    got, _ = restore_checkpoint(store, "r", seed, like)
    assert got["x"].dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got["x"], np.float32), np.asarray(x, np.float32))


# ------------------------------------------------------------------- moe
@settings(max_examples=8, deadline=None)
@given(
    toks=st.integers(2, 24),
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    seed=st.integers(0, 500),
)
def test_moe_gather_matches_dense_when_capacity_ample(toks, e, k, seed):
    import dataclasses

    from repro.configs import get_arch, reduced
    from repro.models.moe import apply_moe, moe_init

    cfg = dataclasses.replace(
        reduced(get_arch("mixtral-8x7b")),
        n_experts=e, top_k=min(k, e), capacity_factor=float(e) * 2,
    )
    p = moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32, 0.1)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, toks, cfg.d_model))
    yd = apply_moe(p, x, cfg, "dense")
    yg = apply_moe(p, x, cfg, "capacity")
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd), rtol=2e-5, atol=2e-5)

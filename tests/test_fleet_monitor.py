"""Spot fleet, ECS placement, and monitor behaviour (paper Steps 3-4)."""

import os

import pytest

from repro.core import (
    DSConfig,
    DSRuntime,
    DurableQueue,
    ECSCluster,
    FleetFile,
    InstanceState,
    JobFile,
    Monitor,
    Service,
    SimRunner,
    SpotFleet,
    TaskDefinition,
    VirtualClock,
    register_payload,
)


def mkfleet(clk, **ff_kwargs):
    ff = FleetFile(startup_seconds=5.0, **ff_kwargs)
    return SpotFleet(ff, clock=clk, app_name="T")


def test_fleet_fulfills_target_after_startup():
    clk = VirtualClock()
    fleet = mkfleet(clk)
    fleet.request(target_capacity=3, bid=1.0, machine_types=["sim.large"])
    assert len(fleet.pending()) == 3 and not fleet.running()
    clk.advance(5.0)
    fleet.tick()
    assert len(fleet.running()) == 3


def test_outbid_gets_no_capacity_then_recovers():
    clk = VirtualClock()
    fleet = mkfleet(clk)
    fleet.request(target_capacity=2, bid=0.0001, machine_types=["sim.large"])
    assert fleet.fulfilled_capacity() == 0  # priced out
    fleet.bid = 1.0  # market came back under our (new) bid
    fleet.tick()
    assert fleet.fulfilled_capacity() == 2


def test_preemption_and_backfill():
    clk = VirtualClock()
    fleet = mkfleet(clk, preemption_rate_per_hour=60.0, market_seed=7)  # ~1/min
    fleet.request(target_capacity=4, bid=1.0, machine_types=["sim.small"])
    clk.advance(5.0)
    fleet.tick()
    preempted = 0
    for _ in range(60):
        clk.advance(60.0)
        dead = fleet.tick()
        preempted += sum(1 for i in dead if i.terminate_reason == "spot-preemption")
        # back-fill restores the target on the same tick
        assert fleet.fulfilled_capacity() == 4
    assert preempted > 5, "preemption injection should have fired repeatedly"


def test_cheapest_mode_no_backfill():
    clk = VirtualClock()
    fleet = mkfleet(clk, preemption_rate_per_hour=120.0, market_seed=3)
    fleet.request(target_capacity=4, bid=1.0, machine_types=["sim.small"])
    clk.advance(5.0)
    fleet.tick()
    fleet.replace_on_terminate = False  # what cheapest mode sets
    fleet.modify_target(1)
    for _ in range(30):
        clk.advance(60.0)
        fleet.tick()
    assert fleet.fulfilled_capacity() <= 1


def test_placement_respects_capacity():
    clk = VirtualClock()
    fleet = mkfleet(clk)
    fleet.request(target_capacity=1, bid=1.0, machine_types=["sim.large"])  # 8 vcpu, 16GB
    clk.advance(5.0)
    fleet.tick()
    cluster = ECSCluster()
    # each task wants 4 vcpus -> exactly 2 fit on a sim.large
    td = TaskDefinition(family="t", payload="p", cpu_shares=4096, memory_mb=4096, docker_cores=1)
    cluster.register_service(Service(name="S", task_definition=td, desired_count=5))
    placed = cluster.place("S", fleet, clk.now())
    assert len(placed) == 2, "bin-packing must stop at instance capacity"
    # oversized task never places (the paper's documented failure mode)
    td_big = TaskDefinition(family="b", payload="p", cpu_shares=99999, memory_mb=4096, docker_cores=1)
    cluster.register_service(Service(name="B", task_definition=td_big, desired_count=1))
    assert cluster.place("B", fleet, clk.now()) == []


def test_oversized_instance_takes_extra_tasks():
    """'ECS will keep placing Dockers onto an instance until it is full.'"""
    clk = VirtualClock()
    fleet = mkfleet(clk)
    fleet.request(target_capacity=1, bid=2.0, machine_types=["sim.xlarge"])  # 16 vcpu
    clk.advance(5.0)
    fleet.tick()
    cluster = ECSCluster()
    td = TaskDefinition(family="t", payload="p", cpu_shares=2048, memory_mb=2048, docker_cores=1)
    cluster.register_service(Service(name="S", task_definition=td, desired_count=8))
    placed = cluster.place("S", fleet, clk.now())
    assert len(placed) == 8  # more than the 2-ish the user probably intended


@register_payload("noop-sleep")
def noop_sleep(job, ctx):
    for _ in range(int(job.get("beats", 1))):
        ctx.heartbeat()
    return {"ok": True}


@register_payload("always-fails")
def always_fails(job, ctx):
    raise ValueError("intentional failure")


def _runtime(tmp_path, clk, payload="noop-sleep", machines=2, **cfg_kwargs):
    kwargs = dict(
        app_name="T",
        payload=payload,
        cluster_machines=machines,
        tasks_per_machine=1,
        machine_type=["sim.large"],
        machine_price=1.0,
        sqs_message_visibility=180.0,
        check_if_done=False,
        monitor_poll_seconds=60.0,
    )
    kwargs.update(cfg_kwargs)
    cfg = DSConfig(**kwargs)
    rt = DSRuntime(cfg, store_root=str(tmp_path / "store"), clock=clk)
    rt.setup()
    return rt


def test_sim_runner_drains_queue_and_tears_down(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    rt.submit_job(JobFile(shared={"beats": 1}, groups=[{"g": i} for i in range(10)]))
    rt.start_cluster(FleetFile(startup_seconds=5.0))
    runner = SimRunner(rt, tick_seconds=60.0)
    summary = runner.run()
    assert summary.jobs_done == 10
    assert rt.queue.counts() == {"visible": 0, "in_flight": 0, "dead": 0}
    # teardown: fleet cancelled, logs exported to the store
    assert rt.fleet.fulfilled_capacity() == 0
    assert any(o.key.startswith("logs/T/") for o in rt.store.list("logs/"))


def test_poison_jobs_end_in_dlq_without_wedging(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, payload="always-fails", max_receive_count=2,
                  sqs_message_visibility=60.0)
    rt.submit_job(JobFile(groups=[{"g": 0}, {"g": 1}]))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    runner = SimRunner(rt, tick_seconds=60.0)
    summary = runner.run(max_ticks=50)
    assert summary.jobs_done == 0
    assert rt.monitor.finished, "cluster must tear down despite poison jobs"


def test_teardown_sweeps_expired_kvprefix_pages(tmp_path):
    """With ``kvprefix_ttl_seconds`` set, the monitor's teardown sweep
    deletes expired cross-host KV prefix pages from the object store
    (ttl 0 = clear the prefix); without the knob the store is left
    alone."""
    import numpy as np

    from repro.serving.prefix_store import PrefixStore

    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, kvprefix_ttl_seconds=0.0)
    ps = PrefixStore(rt.store, "ns")
    ps.publish("aa" * 32, {"k": np.zeros((2, 2), np.float32)})
    ps.publish("bb" * 32, {"k": np.ones((2, 2), np.float32)})
    rt.submit_job(JobFile(shared={"beats": 1}, groups=[{"g": 0}]))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    runner = SimRunner(rt, tick_seconds=60.0)
    runner.run()
    assert rt.monitor.finished
    assert list(rt.store.list("kvprefix/")) == []
    assert any("kvprefix" in e["message"] for e in rt.logs.events("monitor"))

    # default config (no TTL): pages persist across the run
    clk2 = VirtualClock()
    rt2 = _runtime(tmp_path / "2", clk2)
    PrefixStore(rt2.store, "ns").publish(
        "cc" * 32, {"k": np.zeros((2, 2), np.float32)}
    )
    rt2.submit_job(JobFile(shared={"beats": 1}, groups=[{"g": 0}]))
    rt2.start_cluster(FleetFile(startup_seconds=0.0))
    SimRunner(rt2, tick_seconds=60.0).run()
    assert rt2.monitor.finished
    assert len(list(rt2.store.list("kvprefix/"))) == 1


def test_idle_alarm_terminates_stalled_instance(tmp_path):
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, machines=1, idle_alarm_seconds=900.0)
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    rt.fleet.tick()
    monitor = rt.make_monitor()
    # queue is empty -> but make it look non-empty so teardown doesn't race
    rt.queue.send({"g": 0})
    rt.queue.receive(visibility_timeout=10_000.0)  # someone holds a job forever
    inst = rt.fleet.running()[0]
    inst.last_heartbeat = clk.now()
    for _ in range(16):
        clk.advance(60.0)
        report = monitor.tick()
    assert inst.state == InstanceState.TERMINATED
    assert inst.terminate_reason == "idle-alarm"

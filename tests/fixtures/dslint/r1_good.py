# dslint-role: lease
"""Passes R1: every op flows through a retry wrapper."""


def _with_retries(op, *, key, clock):
    for _attempt in range(4):
        try:
            return op()
        except ConnectionError:
            clock.sleep(0.01)


def persist(store, rq, key, payload, m, clock):
    _with_retries(lambda: store.put_json(key, payload), key=key, clock=clock)
    _with_retries(lambda: rq.delete(m), key=key, clock=clock)

# dslint-role: tick
"""Passes R3: injected clock, seeded RNG, sorted-set iteration;
set membership/len (no iteration) is fine."""
import numpy as np


def tick(batch, clock, seed):
    now = clock.now()  # injected virtual clock
    rng = np.random.default_rng(seed)  # explicitly seeded
    seen = {3, 1, 2}
    order = [x for x in sorted(seen)]
    return now, rng, order, len(seen), 1 in seen

"""Passes R5: shared writes are lock-guarded (Pump); a single-writer
attribute read from the other side is ownership, not contention
(Gauge)."""
import threading


class Pump:
    def __init__(self):
        self.pending = []
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def submit(self, item):
        with self._lock:
            self.pending.append(item)

    def _run(self):
        while True:
            with self._lock:
                if self.pending:
                    self.pending.pop()


class Gauge:
    def __init__(self):
        self.count = 0
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        self.count += 1  # worker is the only writer

    def read(self):
        return self.count  # reads are fine from anywhere

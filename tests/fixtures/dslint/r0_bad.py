# dslint-role: lease
"""Trips R0 twice: a pragma with an empty reason, and one naming an
unknown rule.  The R1 finding itself IS suppressed (hygiene and
suppression are independent)."""


def probe(store, key):
    return store.exists(key)  # dslint: disable=R1(), R99(not a rule)

# dslint-role: handler
"""Passes R2: put-then-delete; and acks/puts in *different* loops are
independent ordering regions (different message populations)."""


def process(store, rq, m, key, record):
    store.put_json(key, record)
    rq.delete(m)


def drain(store, rq, messages, records):
    for m in messages:  # acking already-recorded redeliveries
        rq.delete(m)
    for key, rec in records:  # unrelated record flush
        store.put_json(key, rec)

# dslint-role: lease
"""Trips R1: bare store/queue ops on the lease path."""


def persist(store, rq, key, payload, m):
    store.put_json(key, payload)  # bare durable put
    rq.delete(m)  # bare ack

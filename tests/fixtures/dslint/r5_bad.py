"""Trips R5: both the thread target and the caller side mutate
``self.pending`` with no lock."""
import threading


class Pump:
    def __init__(self):
        self.pending = []
        self._thread = threading.Thread(target=self._run, daemon=True)

    def submit(self, item):
        self.pending.append(item)  # caller side, unguarded

    def _run(self):
        while self.pending:
            self.pending.pop()  # worker side, unguarded

# dslint-role: tick
"""Trips R3: wall clock, unseeded RNG, unordered-set iteration."""
import random
import time


def tick(batch):
    t = time.time()  # wall clock on the tick path
    r = random.random()  # unseeded global RNG
    seen = {3, 1, 2}
    order = [x for x in seen]  # hash-order iteration
    return t, r, order

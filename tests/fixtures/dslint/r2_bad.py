# dslint-role: handler
"""Trips R2: the ack precedes the durable write it guards."""


def process(store, rq, m, key, record):
    rq.delete(m)  # crash after this line loses the request
    store.put_json(key, record)

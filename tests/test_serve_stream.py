"""Queue-fed serving through the distributed tier: the
``distributed-serve`` payload streaming requests from a DurableQueue on
``SimRunner`` (submit -> stream -> per-request ack -> teardown sweep),
and a two-worker cross-host prefix hit through the ObjectStore."""

import os

os.environ.setdefault("DS_DEBUG_INVARIANTS", "1")

import jax

import repro.launch.serve  # noqa: F401  (registers distributed-serve)
import repro.launch.train  # noqa: F401
from repro.core import (
    DSConfig,
    DSRuntime,
    FleetFile,
    JobFile,
    SimRunner,
    VirtualClock,
)
from repro.core.queue import DurableQueue
from repro.launch.train import build_model
from repro.serving.engine import Request, ServeEngine

SHARED = {
    "arch": "ds-paper-100m",
    "arch_overrides": "reduced",
    "max_new_tokens": 4,
    "max_len": 32,
    "max_batch": 2,
    "prefill_chunk": 4,
}


def _runtime(tmp_path, clk, *, machines=1, **cfg_kwargs):
    kwargs = dict(
        app_name="Stream",
        payload="distributed-serve",
        cluster_machines=machines,
        tasks_per_machine=1,
        machine_type=["sim.large"],
        machine_price=1.0,
        sqs_message_visibility=240.0,
        check_if_done=False,
    )
    kwargs.update(cfg_kwargs)
    cfg = DSConfig(**kwargs)
    rt = DSRuntime(cfg, store_root=str(tmp_path / "store"), clock=clk)
    rt.setup()
    return rt


def _reference_outputs(job, prompts, max_new):
    """One-shot static-batch oracle with the payload's own model path."""
    model = build_model(job)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params,
                      max_batch=job["max_batch"], max_len=job["max_len"],
                      prefill_chunk=job["prefill_chunk"])
    eng.submit([Request(uid=f"q{i}", prompt=list(p), max_new_tokens=max_new)
                for i, p in enumerate(prompts)])
    eng.run_to_completion()
    return {r.uid: r.output for r in eng.finished}


def test_stream_payload_serves_acks_and_drains(tmp_path):
    """Tier-1 smoke of the queue-fed serving tier: request messages are
    streamed into the scheduler, acked per completion, the request queue
    drains to zero, the monitor tears the fleet down, and every
    completion is byte-identical to the one-shot static batch."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    prompts = [[1, 2, 3], [4, 5], [7, 8, 9, 10], [11], [12, 13]]
    rq_path = str(tmp_path / "requests.sqlite")
    rq = DurableQueue(rq_path, clock=clk)
    rq.send_batch([
        {"uid": f"q{i}", "prompt": p, "max_new_tokens": 4}
        for i, p in enumerate(prompts)
    ])
    rt.submit_job(JobFile(
        shared=dict(SHARED),
        groups=[{
            "request_queue": rq_path,
            "expected_requests": len(prompts),
            "output_prefix": "serve/stream0",
        }],
    ))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)
    assert summary.jobs_done == 1, f"{summary}"
    # every request message individually acknowledged; nothing dead
    counts = rq.counts()
    assert counts == {"visible": 0, "in_flight": 0, "dead": 0}, counts
    res = rt.store.get_json("serve/stream0/RESULTS.json")
    assert len(res["requests"]) == len(prompts)
    # durable-before-ack: each completion was persisted individually
    # BEFORE its message was deleted, so a crash after an ack can never
    # lose a served request
    for i in range(len(prompts)):
        rec = rt.store.get_json(f"serve/stream0/requests/q{i}.json")
        assert rec == res["requests"][f"q{i}"]
    want = _reference_outputs(SHARED, prompts, 4)
    got = {uid: r["completion"] for uid, r in res["requests"].items()}
    assert got == want, "streamed completions diverged from the static batch"
    # the full scheduler/cache snapshot reaches RESULTS.json
    assert res["admissions"] >= len(prompts)
    assert res["ticks"] > 0 and res["dispatches"] > 0
    assert res["timing"]["ttft_ticks"]["n"] == len(prompts)
    assert res["timing"]["queue_wait_ticks"]["mean"] >= 0.0


def test_stream_payload_idle_exit_without_expected_count(tmp_path):
    """Without ``expected_requests`` the stream lease exits after N idle
    polls once the queue runs dry (workers shut themselves down)."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    rq_path = str(tmp_path / "requests.sqlite")
    rq = DurableQueue(rq_path, clock=clk)
    rq.send({"uid": "only", "prompt": [1, 2, 3]})
    rt.submit_job(JobFile(
        shared=dict(SHARED),
        groups=[{"request_queue": rq_path, "stream_idle_polls": 2,
                 "output_prefix": "serve/stream1"}],
    ))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)
    assert summary.jobs_done == 1
    res = rt.store.get_json("serve/stream1/RESULTS.json")
    assert set(res["requests"]) == {"only"}
    assert rq.counts()["visible"] == 0


def test_stream_uid_collision_serves_both_prompts(tmp_path):
    """Two DIFFERENT prompts under one client-supplied uid must both be
    served (the second under a disambiguated uid), never silently
    conflated into one completion with both messages acked."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    rq_path = str(tmp_path / "requests.sqlite")
    rq = DurableQueue(rq_path, clock=clk)
    rq.send_batch([
        {"uid": "dup", "prompt": [1, 2, 3]},
        {"uid": "dup", "prompt": [9, 9]},  # distinct prompt, same uid
    ])
    rt.submit_job(JobFile(
        shared=dict(SHARED),
        groups=[{"request_queue": rq_path, "expected_requests": 2,
                 "output_prefix": "serve/stream3"}],
    ))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)
    assert summary.jobs_done == 1, f"{summary}"
    res = rt.store.get_json("serve/stream3/RESULTS.json")
    assert len(res["requests"]) == 2
    prompts_served = sorted(r["prompt"] for r in res["requests"].values())
    assert prompts_served == [[1, 2, 3], [9, 9]]
    assert rq.counts() == {"visible": 0, "in_flight": 0, "dead": 0}


def test_stream_lease_resume_merges_previous_holders_completions(tmp_path):
    """A retried lease (previous holder crashed after acking some
    requests but before its summary) must fold the persisted per-request
    records into its own RESULTS.json and count them toward
    ``expected_requests`` — otherwise the summary under-reports and the
    lease can only exit through the idle path."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk)
    # the crashed holder served q0 and durably recorded it pre-ack
    pre = {"prompt": [9, 9], "completion": [1, 2, 3, 4]}
    rt.store.put_json("serve/stream2/requests/q0.json", pre)
    rq_path = str(tmp_path / "requests.sqlite")
    rq = DurableQueue(rq_path, clock=clk)
    rq.send({"uid": "q1", "prompt": [1, 2, 3]})  # the resurfaced remainder
    rt.submit_job(JobFile(
        shared=dict(SHARED),
        groups=[{"request_queue": rq_path, "expected_requests": 2,
                 "output_prefix": "serve/stream2"}],
    ))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)
    assert summary.jobs_done == 1, f"{summary}"
    res = rt.store.get_json("serve/stream2/RESULTS.json")
    assert set(res["requests"]) == {"q0", "q1"}
    assert res["requests"]["q0"] == pre  # pre-crash completion preserved
    assert rq.counts() == {"visible": 0, "in_flight": 0, "dead": 0}


def test_two_workers_share_prefix_pages_through_object_store(tmp_path):
    """Cross-host prefix cache: worker A serves a batch carrying a
    system prompt and publishes its KV pages to the ObjectStore; worker
    B (a different task on a different machine, cold radix cache) must
    hydrate those pages and skip the shared prefill — byte-identically."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, machines=2)
    sys_prompt = [11, 12, 13, 14, 15, 16, 17, 18,
                  21, 22, 23, 24, 25, 26, 27, 28]
    shared = dict(
        SHARED,
        cache_mode="paged",
        page_size=8,
        prefix_cache=True,
        prefix_store=True,
    )
    jobs = [
        {"prompts": [sys_prompt + [31], sys_prompt + [32]],
         "output_prefix": "serve/w0"},
        {"prompts": [sys_prompt + [41], sys_prompt + [42]],
         "output_prefix": "serve/w1"},
    ]
    rt.submit_job(JobFile(shared=shared, groups=jobs))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=200)
    assert summary.jobs_done == 2, f"{summary}"
    res = [rt.store.get_json(f"serve/w{i}/RESULTS.json") for i in range(2)]
    # SimRunner gives no ordering guarantee over which worker's prompt
    # becomes resident first, so assert the ROLES symmetrically: exactly
    # one worker published the two prefix pages from scratch, and the
    # other hydrated both from the store instead of prefilling
    pubs = [r["prefix_store_pages_published"] for r in res]
    hyds = [r["prefix_store_pages_hydrated"] for r in res]
    assert sorted(pubs) == [0, 2], (pubs, hyds)
    assert sorted(hyds) == [0, 2], (pubs, hyds)
    publisher = pubs.index(2)
    hydrator = 1 - publisher
    assert hyds[publisher] == 0 and pubs[hydrator] == 0
    # the hydrator skipped the whole system prompt without dispatching it
    assert res[hydrator]["prompt_tokens_skipped"] >= len(sys_prompt)
    assert (res[hydrator]["prompt_tokens_ingested"]
            < res[publisher]["prompt_tokens_ingested"])
    # hydrated pages must be byte-equivalent to local prefill: BOTH
    # workers' completions match a dense engine computing from scratch
    for w, r in enumerate(res):
        want = _reference_outputs(shared, jobs[w]["prompts"], 4)
        # payload uids are req<i>, oracle uids q<i>: compare by position
        for i in range(2):
            assert r["requests"][f"req{i}"]["completion"] == want[f"q{i}"], (
                f"worker {w} request {i} diverged"
            )


def _worker_counters(rt, out):
    """Per-worker counter records under one output prefix, final
    RESULTS- summaries superseding slice-cumulative leases/ records
    (same merge rule the serving benchmarks use)."""
    recs = {}
    for info in rt.store.list(f"{out}/leases/"):
        wid = info.key.rsplit("/", 1)[-1][: -len(".json")]
        recs[wid] = rt.store.get_json(info.key)
    for info in rt.store.list(f"{out}/"):
        name = info.key[len(out) + 1:]
        if name.startswith("RESULTS-") and name.endswith(".json"):
            wid = name[len("RESULTS-"): -len(".json")]
            recs[wid] = rt.store.get_json(info.key)
    return list(recs.values())


def test_disaggregated_prefill_decode_roles_split_the_pipeline(tmp_path):
    """Role-split serving end to end: a prefill-role permit leases the
    request queue, publishes each prompt's KV chain through the prefix
    store and enqueues sealed handoff records; a decode-role permit
    leases those records, demand-hydrates exactly the chained pages and
    decodes to completion — byte-identical to a dense monolith, with
    the prefill side never emitting a token."""
    clk = VirtualClock()
    rt = _runtime(tmp_path, clk, machines=2)
    prompts = [
        [1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
        [1, 2, 3, 4, 5, 6, 7, 8, 11, 12, 13],
        [21, 22, 23],
        [4, 5],
    ]
    n = len(prompts)
    rq_path = str(tmp_path / "requests.sqlite")
    dq_path = str(tmp_path / "decode.sqlite")
    rq = DurableQueue(rq_path, clock=clk)
    rq.send_batch([
        {"uid": f"d{i}", "prompt": p, "max_new_tokens": 4}
        for i, p in enumerate(prompts)
    ])
    shared = dict(
        SHARED,
        cache_mode="paged",
        page_size=8,
        prefix_cache=True,
        prefix_store=True,
        stream_slice_ticks=4,
        stream_idle_polls=200,
    )
    rt.submit_job(JobFile(shared=shared, groups=[
        {"worker_role": "prefill", "request_queue": rq_path,
         "decode_queue": dq_path, "expected_requests": n,
         "output_prefix": "serve/dpre"},
        {"worker_role": "decode", "request_queue": dq_path,
         "expected_requests": n, "output_prefix": "serve/ddec"},
    ]))
    rt.start_cluster(FleetFile(startup_seconds=0.0))
    summary = SimRunner(rt, tick_seconds=30.0).run(max_ticks=400)
    assert summary.jobs_done == 2, f"{summary}"
    # both queues fully drained: every request handed off and acked,
    # every handoff admitted and acked, nothing dead
    assert rq.counts() == {"visible": 0, "in_flight": 0, "dead": 0}
    dq = DurableQueue(dq_path, clock=clk)
    assert dq.counts() == {"visible": 0, "in_flight": 0, "dead": 0}
    # one sealed handoff marker per prompt on the prefill side
    from repro.launch.serve import _handoff_valid
    for i in range(n):
        marker = rt.store.get_json(f"serve/dpre/handoffs/d{i}.json")
        assert _handoff_valid(marker), marker
        assert marker["prompt"] == prompts[i] and marker["output"] == []
    # completions land on the decode side, byte-identical to a dense
    # monolithic engine computing everything from scratch
    want = _reference_outputs(SHARED, prompts, 4)
    for i in range(n):
        rec = rt.store.get_json(f"serve/ddec/requests/d{i}.json")
        assert rec["prompt"] == prompts[i]
        assert rec["completion"] == want[f"q{i}"], f"request d{i} diverged"
    pre = _worker_counters(rt, "serve/dpre")
    dec = _worker_counters(rt, "serve/ddec")
    # the split of labor: prefill published every handoff and decoded
    # nothing; decode admitted every handoff without a single fallback
    # and pulled real KV bytes out of the store to do it
    assert sum(r.get("handoffs_published", 0) for r in pre) == n
    assert sum(r.get("tokens_emitted", 0) for r in pre) == 0
    assert sum(r.get("handoffs_admitted", 0) for r in dec) == n
    assert sum(r.get("handoff_fallbacks", 0) for r in dec) == 0
    assert sum(r.get("prefix_store_pages_hydrated", 0) for r in dec) > 0
    assert sum(r.get("hydration_fetch_ops", 0) for r in dec) > 0
    assert sum(r.get("prefix_store_bytes_fetched", 0) for r in dec) > 0

"""SQS-semantics tests for the durable queue."""

import os

import pytest

from repro.core import DurableQueue, VirtualClock


@pytest.fixture()
def q(tmp_path):
    clk = VirtualClock()
    queue = DurableQueue(
        os.path.join(tmp_path, "q.sqlite"),
        default_visibility=30.0,
        max_receive_count=3,
        clock=clk,
    )
    queue.clk = clk
    return queue


def test_fifo_ish_delivery_and_ack(q):
    ids = q.send_batch([{"i": i} for i in range(5)])
    assert len(set(ids)) == 5
    seen = []
    while True:
        m = q.receive()
        if m is None:
            break
        seen.append(m.body["i"])
        assert q.delete(m)
    assert sorted(seen) == list(range(5))
    assert q.counts() == {"visible": 0, "in_flight": 0, "dead": 0}


def test_visibility_timeout_redelivers(q):
    q.send({"job": 1})
    m1 = q.receive(visibility_timeout=10.0)
    assert m1 is not None
    assert q.receive() is None  # hidden while in flight
    q.clk.advance(10.1)
    m2 = q.receive()
    assert m2 is not None and m2.id == m1.id and m2.receive_count == 2


def test_stale_receipt_cannot_ack(q):
    q.send({"job": 1})
    m1 = q.receive(visibility_timeout=5.0)
    q.clk.advance(6.0)
    m2 = q.receive()  # re-delivered; m1's receipt is now stale
    assert not q.delete(m1), "stale receipt must not delete"
    assert q.delete(m2)


def test_change_visibility_extends_lease(q):
    q.send({"job": 1})
    m = q.receive(visibility_timeout=10.0)
    q.clk.advance(8.0)
    assert q.change_visibility(m, 20.0)
    q.clk.advance(12.0)  # original lease would have expired
    assert q.receive() is None, "extended lease must still hide the message"
    q.clk.advance(9.0)
    assert q.receive() is not None


def test_dead_letter_after_max_receives(q):
    q.send({"poison": True})
    for attempt in range(3):  # max_receive_count = 3
        m = q.receive(visibility_timeout=1.0)
        assert m is not None and m.receive_count == attempt + 1
        q.clk.advance(1.1)  # lease expires without an ack (worker "failed")
    # 4th receive attempt moves it to the DLQ
    m = q.receive()
    assert m is None
    dl = q.dead_letters()
    assert len(dl) == 1 and dl[0].body == {"poison": True}

    # operator redrive brings it back
    assert q.redrive_dead_letters() == 1
    assert q.receive() is not None


def test_release_does_not_consume_retry_budget(q):
    q.send({"waiting": True})
    for _ in range(10):  # far beyond max_receive_count
        m = q.receive(visibility_timeout=30.0)
        assert m is not None, "released message must keep coming back"
        assert m.receive_count == 1, "release must refund the receive"
        assert q.release(m, delay=2.0)
        assert q.receive() is None  # hidden for the delay
        q.clk.advance(2.1)
    m = q.receive()
    assert m is not None
    assert q.delete(m)


def test_receive_batch_claims_n_in_one_call(q):
    ids = q.send_batch([{"i": i} for i in range(7)])
    assert len(ids) == 7
    msgs = q.receive_batch(5, visibility_timeout=10.0)
    assert len(msgs) == 5
    assert len({m.receipt for m in msgs}) == 5  # distinct receipts
    # claimed messages are hidden; the rest still visible
    assert q.counts() == {"visible": 2, "in_flight": 5, "dead": 0}
    rest = q.receive_batch(5)
    assert len(rest) == 2  # drains without blocking
    # FIFO-ish: every message delivered exactly once across the two claims
    assert sorted(m.body["i"] for m in msgs + rest) == list(range(7))
    assert q.delete_batch(msgs + rest) == 7
    assert q.counts() == {"visible": 0, "in_flight": 0, "dead": 0}


def test_receive_batch_skips_poison_to_dlq(q):
    q.send({"poison": True})
    q.clk.advance(0.1)  # later enqueued_at: deterministic claim order
    q.send({"ok": True})
    for _ in range(3):  # burn the poison message's retry budget
        m = q.receive_batch(1, visibility_timeout=1.0)[0]
        assert m.body == {"poison": True}
        q.clk.advance(1.1)
    msgs = q.receive_batch(10)
    assert [m.body for m in msgs] == [{"ok": True}], "poison must be DLQ'd in-claim"
    assert q.counts()["dead"] == 1


def test_delete_batch_ignores_stale_receipts(q):
    q.send_batch([{"i": i} for i in range(2)])
    msgs = q.receive_batch(2, visibility_timeout=5.0)
    q.clk.advance(6.0)  # leases expire; receipts go stale
    fresh = q.receive_batch(2)
    assert q.delete_batch(msgs) == 0, "stale receipts must not delete"
    assert q.delete_batch(fresh) == 2


def test_durability_across_reopen(tmp_path):
    path = os.path.join(tmp_path, "q.sqlite")
    clk = VirtualClock()
    q1 = DurableQueue(path, clock=clk)
    q1.send_batch([{"i": i} for i in range(3)])
    m = q1.receive(visibility_timeout=60.0)
    q1.close()
    # crash + restart: a new process attaches to the same file
    q2 = DurableQueue(path, clock=clk)
    c = q2.counts()
    assert c["visible"] == 2 and c["in_flight"] == 1
    clk.advance(61.0)
    assert q2.counts()["visible"] == 3, "in-flight message resurfaced after crash"

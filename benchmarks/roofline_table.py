"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/.

    PYTHONPATH=src python -m benchmarks.roofline_table [--out experiments]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCH_ORDER = [
    "nemotron-4-340b", "granite-34b", "qwen2-72b", "h2o-danube-3-4b",
    "whisper-tiny", "zamba2-1.2b", "mixtral-8x7b", "deepseek-v2-236b",
    "mamba2-1.3b", "internvl2-1b", "ds-paper-100m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _key(d):
    a = ARCH_ORDER.index(d["arch"]) if d["arch"] in ARCH_ORDER else 99
    s = SHAPE_ORDER.index(d["shape"]) if d["shape"] in SHAPE_ORDER else 99
    return (a, s, d.get("mesh", ""))


def load(outdir, sub):
    rows = []
    for f in glob.glob(os.path.join(outdir, sub, "*.json")):
        rows.append(json.load(open(f)))
    rows.sort(key=_key)
    return rows


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(rows) -> str:
    out = [
        "| arch | shape | mesh | compile | policy | mem/dev (CPU-emul) | projected TPU | fits 16GiB | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | skip | {r['reason']} |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED | — | — | — | — | {r.get('error','')[:60]} |"
            )
            continue
        m = r["memory"]
        colls = ", ".join(f"{k}:{v}" for k, v in sorted(r["collective_counts"].items()))
        pol = "+".join(r["policy"]["fsdp_axes"]) or "TP-only"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.0f}s "
            f"| fsdp={pol},mb={r['microbatches']} | {m['per_device_gib']:.2f} GiB "
            f"| {m['projected_tpu_gib']:.2f} GiB | {'Y' if m['fits_16gib_projected'] else 'N'} "
            f"| {colls} |"
        )
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = [
        "| arch | shape | compute | memory | collective | bound | MODEL_FLOPS/dev | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — | {r['reason'][:50]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAILED {r.get('error','')[:60]} ||||||||")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['model_flops_global'] / r['n_devices']:.2e} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments")
    args = ap.parse_args(argv)
    dr = load(args.out, "dryrun")
    rf = load(args.out, "roofline")
    print("## §Dry-run\n")
    print(dryrun_table(dr))
    print("\n## §Roofline (single-pod 16x16, per-device terms)\n")
    print(roofline_table(rf))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

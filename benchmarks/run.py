"""Benchmark harness — one benchmark per paper claim/figure.

The paper's artifact is a control plane, so the "tables" are operational:
the four-command lifecycle (Figure 1), queue-driven distribution,
elastic scaling, spot fault tolerance, cheapest mode, and the idempotent
restart path — plus the training/serving substrate benchmarks.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark).
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

ROWS = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def _ensure_noop_payload():
    from repro.core.worker import PAYLOAD_REGISTRY, register_payload

    if "bench-noop" not in PAYLOAD_REGISTRY:
        @register_payload("bench-noop")
        def _noop(job, ctx):
            ctx.heartbeat()
            return {}


# ------------------------------------------------------------------ queue
def bench_queue_throughput() -> None:
    from repro.core import DurableQueue

    with tempfile.TemporaryDirectory() as d:
        q = DurableQueue(os.path.join(d, "q.sqlite"), default_visibility=60)
        n = 2000
        t0 = time.perf_counter()
        q.send_batch([{"i": i} for i in range(n)])
        t1 = time.perf_counter()
        while True:
            m = q.receive()
            if m is None:
                break
            q.delete(m)
        t2 = time.perf_counter()
        emit("queue_send", (t1 - t0) / n * 1e6, f"{n / (t1 - t0):.0f} msgs/s")
        emit("queue_recv_ack", (t2 - t1) / n * 1e6, f"{n / (t2 - t1):.0f} msgs/s")

    # batched claim/ack: one transaction per 32 messages instead of per message
    with tempfile.TemporaryDirectory() as d:
        q = DurableQueue(os.path.join(d, "qb.sqlite"), default_visibility=60)
        n = 2000
        q.send_batch([{"i": i} for i in range(n)])
        t0 = time.perf_counter()
        while True:
            msgs = q.receive_batch(32)
            if not msgs:
                break
            q.delete_batch(msgs)
        t1 = time.perf_counter()
        emit("queue_recv_ack_batch32", (t1 - t0) / n * 1e6, f"{n / (t1 - t0):.0f} msgs/s")


def bench_lifecycle() -> None:
    """Figure 1: setup -> submitJob -> startCluster -> monitor, 64 noop jobs."""
    from repro.core import DSConfig, DSRuntime, FleetFile, JobFile, SimRunner, VirtualClock

    _ensure_noop_payload()
    with tempfile.TemporaryDirectory() as d:
        clk = VirtualClock()
        cfg = DSConfig(app_name="B", payload="bench-noop", cluster_machines=4,
                       machine_type=["sim.large"], machine_price=1.0, check_if_done=False)
        rt = DSRuntime(cfg, store_root=d, clock=clk)
        t0 = time.perf_counter()
        rt.setup()
        rt.submit_job(JobFile(groups=[{"g": i} for i in range(64)]))
        rt.start_cluster(FleetFile(startup_seconds=5.0))
        summary = SimRunner(rt, tick_seconds=60.0).run()
        t1 = time.perf_counter()
        emit(
            "lifecycle_64jobs",
            (t1 - t0) / 64 * 1e6,
            f"done={summary.jobs_done};virtual_s={summary.wall_time:.0f};ticks={summary.ticks}",
        )


def bench_scaling_efficiency() -> None:
    """Virtual completion time vs fleet size (fixed 64 jobs, 1 job/tick/worker)."""
    from repro.core import DSConfig, DSRuntime, FleetFile, JobFile, SimRunner, VirtualClock

    _ensure_noop_payload()
    base = None
    for machines in (1, 2, 4, 8, 16):
        with tempfile.TemporaryDirectory() as d:
            clk = VirtualClock()
            cfg = DSConfig(app_name="S", payload="bench-noop", cluster_machines=machines,
                           machine_type=["sim.large"], machine_price=1.0, check_if_done=False)
            rt = DSRuntime(cfg, store_root=d, clock=clk)
            rt.setup()
            rt.submit_job(JobFile(groups=[{"g": i} for i in range(64)]))
            rt.start_cluster(FleetFile(startup_seconds=0.0))
            t0 = time.perf_counter()
            s = SimRunner(rt, tick_seconds=60.0).run()
            dt = time.perf_counter() - t0
            if machines == 1:
                base = s.ticks
            eff = base / (s.ticks * machines)
            emit(f"scaling_m{machines}", dt / 64 * 1e6, f"ticks={s.ticks};efficiency={eff:.2f}")


def bench_fault_recovery() -> None:
    """Completion overhead under spot preemption (paper: visibility timeout
    + idempotent restart keep the run converging)."""
    from repro.core import DSConfig, DSRuntime, FleetFile, JobFile, SimRunner, VirtualClock

    _ensure_noop_payload()
    base_ticks = None
    for rate in (0.0, 2.0, 6.0):
        with tempfile.TemporaryDirectory() as d:
            clk = VirtualClock()
            cfg = DSConfig(app_name="F", payload="bench-noop", cluster_machines=4,
                           machine_type=["sim.small"], machine_price=1.0,
                           cpu_shares=1024, memory_mb=1024,  # fits sim.small
                           sqs_message_visibility=120.0, max_receive_count=10,
                           check_if_done=False)
            rt = DSRuntime(cfg, store_root=d, clock=clk)
            rt.setup()
            rt.submit_job(JobFile(groups=[{"g": i} for i in range(64)]))
            rt.start_cluster(FleetFile(startup_seconds=0.0,
                                       preemption_rate_per_hour=rate, market_seed=5))
            t0 = time.perf_counter()
            s = SimRunner(rt, tick_seconds=60.0).run(max_ticks=600)
            dt = time.perf_counter() - t0
            if rate == 0.0:
                base_ticks = s.ticks
            emit(
                f"fault_recovery_rate{rate:g}",
                dt * 1e6 / 64,
                f"ticks={s.ticks};overhead={s.ticks / base_ticks:.2f}x;preempted={s.preemptions};done={s.jobs_done}",
            )


def bench_cheapest_mode() -> None:
    """Machine-hours consumed: normal vs cheapest (paper Step 4)."""
    from repro.core import DSConfig, DSRuntime, FleetFile, JobFile, SimRunner, VirtualClock

    _ensure_noop_payload()
    for cheapest in (False, True):
        with tempfile.TemporaryDirectory() as d:
            clk = VirtualClock()
            cfg = DSConfig(app_name="C", payload="bench-noop", cluster_machines=8,
                           machine_type=["sim.large"], machine_price=1.0,
                           check_if_done=False)
            rt = DSRuntime(cfg, store_root=d, clock=clk)
            rt.setup()
            rt.submit_job(JobFile(groups=[{"g": i} for i in range(240)]))
            rt.start_cluster(FleetFile(startup_seconds=0.0))
            t0 = time.perf_counter()
            s = SimRunner(rt, tick_seconds=600.0, cheapest=cheapest).run(max_ticks=600)
            dt = time.perf_counter() - t0
            hours = 0.0
            for inst in rt.fleet.instances.values():
                end = inst.terminate_time if inst.terminate_time else clk.now()
                hours += max(0.0, end - inst.launch_time) / 3600.0
            emit(
                f"cheapest_{'on' if cheapest else 'off'}",
                dt * 1e6 / 240,
                f"machine_hours={hours:.2f};virtual_s={s.wall_time:.0f};done={s.jobs_done}",
            )


# -------------------------------------------------------------- substrate
def bench_checkpoint_io() -> None:
    from repro.core.storage import ObjectStore
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    with tempfile.TemporaryDirectory() as d:
        store = ObjectStore(d)
        tree = {f"w{i}": jnp.ones((512, 1024), jnp.float32) * i for i in range(10)}
        nbytes = sum(x.nbytes for x in tree.values())
        t0 = time.perf_counter()
        save_checkpoint(store, "bench", 0, tree)
        t1 = time.perf_counter()
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        restore_checkpoint(store, "bench", 0, like)
        t2 = time.perf_counter()
        emit("checkpoint_save", (t1 - t0) * 1e6, f"{nbytes / (t1 - t0) / 1e6:.0f} MB/s")
        emit("checkpoint_restore", (t2 - t1) * 1e6, f"{nbytes / (t2 - t1) / 1e6:.0f} MB/s")


def bench_train_step() -> None:
    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelRuntime
    from repro.train.data import DataConfig, SyntheticLM
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.steps import TrainStepConfig, make_train_step

    cfg = reduced(get_arch("ds-paper-100m"), n_layers=4, d_model=128, d_ff=512,
                  vocab_size=2048)
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig()
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, TrainStepConfig(opt=opt_cfg)),
                   donate_argnums=(0, 1))
    ds = SyntheticLM(cfg, DataConfig(seq_len=128, global_batch=8))
    rng = jax.random.PRNGKey(0)
    params, opt, _ = step(params, opt, ds.batch(0), rng)  # compile
    jax.block_until_ready(params)
    n = 10
    t0 = time.perf_counter()
    for i in range(n):
        params, opt, m = step(params, opt, ds.batch(i + 1), rng)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / n
    toks = 8 * 128
    emit("train_step_tiny", dt * 1e6, f"{toks / dt:.0f} tokens/s")


def bench_decode_throughput() -> None:
    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelRuntime
    from repro.serving.engine import Request, ServeEngine

    cfg = reduced(get_arch("ds-paper-100m"), n_layers=4, d_model=128, d_ff=512,
                  vocab_size=2048)
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=4, max_len=64)
    from repro.serving.engine import Request as Req

    engine.submit([Req(uid=f"r{i}", prompt=[1, 2, 3], max_new_tokens=16)
                   for i in range(8)])
    t0 = time.perf_counter()
    finished = engine.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in finished)
    emit("decode_engine", dt / max(toks, 1) * 1e6, f"{toks / dt:.0f} tokens/s")


def bench_moe_dispatch() -> None:
    """Gather vs scatter vs dense dispatch (the §Perf iteration, on CPU)."""
    import dataclasses

    from repro.configs import get_arch, reduced
    from repro.models.moe import apply_moe, moe_init

    cfg = dataclasses.replace(reduced(get_arch("mixtral-8x7b")),
                              d_model=256, moe_d_ff=512, n_experts=8, top_k=2)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32, 0.1)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256, cfg.d_model))
    for strat in ("dense", "capacity", "capacity_scatter"):
        fn = jax.jit(lambda xx, s=strat: apply_moe(p, xx, cfg, s))
        fn(x).block_until_ready()
        n = 5
        t0 = time.perf_counter()
        for _ in range(n):
            y = fn(x)
        y.block_until_ready()
        dt = (time.perf_counter() - t0) / n
        emit(f"moe_dispatch_{strat}", dt * 1e6, f"{8 * 256 / dt:.0f} tokens/s")


def main() -> None:
    print("name,us_per_call,derived")
    bench_queue_throughput()
    bench_lifecycle()
    bench_scaling_efficiency()
    bench_fault_recovery()
    bench_cheapest_mode()
    bench_checkpoint_io()
    bench_train_step()
    bench_decode_throughput()
    bench_moe_dispatch()


if __name__ == "__main__":
    main()

"""Serving-engine benchmark: fused single-dispatch engine vs the seed's
per-position-group engine on a ragged continuous-batching scenario.

The scenario is deliberately hostile to per-group dispatching: mixed
prompt lengths and more requests than slots, so mid-stream refills keep
the batch ragged and the seed engine degenerates toward one jitted call
per occupied slot per token.  The fused engine issues exactly one decode
dispatch per tick and ingests prompts in ``prefill_chunk``-token slices.

Reports tokens/sec and dispatches/token per engine to
``BENCH_serving.json``::

    PYTHONPATH=src python benchmarks/bench_serving.py            # full
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke    # tier-1 CI

Smoke mode shrinks the workload to seconds on CPU but keeps the ragged
structure, so a regression in dispatch count (the metric the tentpole
optimizes) fails fast without waiting on wall-clock noise.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax


def ragged_requests(n_requests: int, max_new: int, seed: int = 0):
    """Mixed-length prompts: long/short interleaved to force position skew."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    lengths = [int(rng.integers(1, 24)) if i % 2 else int(rng.integers(24, 64))
               for i in range(n_requests)]
    return [
        Request(
            uid=f"r{i}",
            prompt=[int(t) for t in rng.integers(1, 200, size=n)],
            max_new_tokens=max_new,
        )
        for i, n in enumerate(lengths)
    ]


_COUNTERS = (
    "decode_dispatches", "prefill_dispatches", "dispatches",
    "tokens_emitted", "prompt_tokens_ingested",
)


def run_engine(model, params, reqs, *, mode: str, max_batch: int, max_len: int,
               prefill_chunk: int) -> dict:
    from repro.serving.engine import Request, ServeEngine

    engine = ServeEngine(
        model, params,
        max_batch=max_batch, max_len=max_len,
        prefill_chunk=prefill_chunk, dispatch_mode=mode,
    )
    # compile both dispatch paths on a throwaway request OUTSIDE the timed
    # region, then measure the real workload from its very first step —
    # otherwise the fused engine's warm-up would silently perform the whole
    # initial prefill phase off the clock and inflate tokens/sec
    engine.submit([Request(uid="__warmup__",
                           prompt=[1] * max(2 * max(prefill_chunk, 1), 2),
                           max_new_tokens=2)])
    engine.run_to_completion()
    base = {k: getattr(engine, k) for k in _COUNTERS}

    engine.submit(reqs)
    t0 = time.perf_counter()
    engine.run_to_completion()
    wall = time.perf_counter() - t0
    c = {k: getattr(engine, k) - base[k] for k in _COUNTERS}
    total_tokens = c["tokens_emitted"] + c["prompt_tokens_ingested"]
    return {
        "dispatch_mode": mode,
        "wall_s": round(wall, 3),
        **c,
        "tokens_per_sec": round(c["tokens_emitted"] / max(wall, 1e-9), 1),
        "dispatches_per_token": round(c["dispatches"] / max(total_tokens, 1), 4),
        "prompt_tokens_per_prefill_dispatch": round(
            c["prompt_tokens_ingested"] / max(c["prefill_dispatches"], 1), 2
        ),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config / short run for tier-1 CI on CPU")
    ap.add_argument("--arch", default="ds-paper-100m")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelRuntime

    n_requests = args.requests or (6 if args.smoke else 24)
    max_new = args.max_new or (4 if args.smoke else 32)
    max_batch = 4 if args.smoke else 8
    max_len = 128
    prefill_chunk = 8 if args.smoke else 32

    cfg = reduced(get_arch(args.arch))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(0))

    results = {}
    for mode in ("grouped", "fused"):
        reqs = ragged_requests(n_requests, max_new)
        results[mode] = run_engine(
            model, params, reqs, mode=mode,
            max_batch=max_batch, max_len=max_len, prefill_chunk=prefill_chunk,
        )
        r = results[mode]
        print(
            f"[bench_serving] {mode:8s} tokens/s={r['tokens_per_sec']:8.1f} "
            f"dispatches/token={r['dispatches_per_token']:.4f} "
            f"(decode={r['decode_dispatches']} prefill={r['prefill_dispatches']})"
        )

    report = {
        "arch": args.arch,
        "smoke": args.smoke,
        "scenario": {
            "n_requests": n_requests, "max_new_tokens": max_new,
            "max_batch": max_batch, "max_len": max_len,
            "prefill_chunk": prefill_chunk,
        },
        "engines": results,
        "dispatch_reduction": round(
            results["grouped"]["dispatches_per_token"]
            / max(results["fused"]["dispatches_per_token"], 1e-9),
            2,
        ),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[bench_serving] wrote {args.out} "
          f"(dispatch reduction {report['dispatch_reduction']}x)")

    # the whole point of the fused engine: strictly fewer dispatches/token
    if results["fused"]["dispatches_per_token"] >= results["grouped"]["dispatches_per_token"]:
        print("[bench_serving] REGRESSION: fused engine not below grouped dispatch rate")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving-engine benchmark: fused single-dispatch engine vs the seed's
per-position-group engine, plus the paged-KV-cache engine, on a ragged
continuous-batching scenario — and the shared-prefix radix cache on a
shared-system-prompt scenario.

The ragged scenario is deliberately hostile to per-group dispatching:
mixed prompt lengths and more requests than slots, so mid-stream refills
keep the batch ragged and the seed engine degenerates toward one jitted
call per occupied slot per token.  The fused engine issues exactly one
decode dispatch per tick and ingests prompts in ``prefill_chunk``-token
slices.

The ``paged`` engine is the fused engine with ``cache_mode="paged"`` and
a page pool sized to the workload's *actual* demand instead of the dense
``max_batch x max_len`` worst case; it reports ``peak_cache_bytes`` /
``pages_in_use_peak`` next to dispatches/token, and the run fails if the
paged peak is not strictly below the dense reservation (tokens/sec must
also stay within 10% of the dense fused engine in full runs — wall-clock
is too noisy to gate in ``--smoke``).  The ragged paged run keeps
``prefix_cache=False``: it is the PR 2 per-slot baseline.

The shared-prefix scenario sends many requests carrying one system
prompt with short distinct tails, after a priming request has populated
the radix cache (steady-state serving).  It compares the dense fused
engine, the per-slot paged engine, and the prefix-cache paged engine:
emitted tokens must be byte-identical across all three, the prefix
engine must prefill >= 2x fewer prompt tokens than the per-slot paged
baseline (``prompt_tokens_skipped``), and its ``peak_cache_bytes`` must
come in below the per-slot paged peak (shared pages are stored once,
not per slot).

The mid-page-divergence scenario sends prompts sharing a prefix that
ends *inside* a page (not on a page boundary), after a priming request.
It compares the dense fused engine, the page-aligned prefix engine
(``prefix_match="page"``, the PR 3 behaviour) and the sub-page prefix
engine (``prefix_match="token"``, the default): outputs must be
byte-identical across all three, and the sub-page engine must prefill
strictly fewer prompt tokens than the page-aligned engine — the tokens
it recovers by copy-on-writing the partially-matched page and resuming
prefill from the mid-page offset (``prefix_hit_tokens_partial`` /
``cow_partial_stitches``).

The decode-heavy (speculative) scenario sends short prompts with long
generations at low batch — the latency-bound shape where nearly every
dispatch is a decode tick and speculation pays — and compares
``speculative="off"`` against the ``ngram`` prompt-lookup proposer and
the ``draft`` small-model proposer on the paged engine.
Outputs must be byte-identical across all three (the tentpole's hard
gate: speculation may change only how many tokens land per dispatch,
never which tokens), every speculative engine must actually verify
(``spec_dispatches > 0``), and at least one proposer must land >= 2.0
tokens per verify dispatch (``accepted_per_dispatch``) while strictly
cutting dispatches/token — all counter-based and gated in smoke.  The
>= 1.5x tokens/sec gate runs full-mode only.

The staggered-arrival scenario demonstrates continuous batching: one
long generation plus short requests arriving one per tick, run under
``refill_policy="continuous"`` (freed rows admit mid-flight) and the
``"drain"`` baseline (refill only an empty batch).  Outputs must be
byte-identical — submit-order sampling streams make scheduling policy
invisible to content — while continuous batching must show strictly
lower mean time-to-first-token.  Every scenario additionally records
queue-wait and TTFT percentiles in engine ticks (deterministic on any
host, unlike wall-clock).

The disaggregation scenario runs the same mixed long-prompt /
long-generation workload through two simulated fleets at equal total
hardware (two machines each): a monolithic fleet of unified workers and
a role-split fleet of one prefill worker (chunk-prefills, publishes KV
chains to the prefix store, enqueues sealed handoff records) plus one
decode worker (hydrates chains on demand, decodes every tick).  Both
legs must be byte-identical to a direct-engine oracle with zero lost
requests; every request must travel the handoff path (published ==
admitted == n, zero fallbacks, zero seal rejects); the prefill pool
must never decode; and the decode pool's p99 TTFT (engine ticks from
admission to first token) and tokens-per-engine-tick must strictly beat
the monolith — all counter-derived and gated in smoke.  The >= 1.3x
TTFT-reduction margin runs full-mode only.

Reports tokens/sec and dispatches/token per engine to
``BENCH_serving.json``::

    PYTHONPATH=src python benchmarks/bench_serving.py            # full
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke    # tier-1 CI

Smoke mode shrinks the workload to seconds on CPU but keeps both
structures, so a regression in dispatch count, paged-cache accounting,
prefix hit rate, or paged-vs-dense token parity fails fast without
waiting on wall-clock noise.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax


def ragged_requests(n_requests: int, max_new: int, seed: int = 0):
    """Mixed-length prompts: long/short interleaved to force position skew."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    lengths = [int(rng.integers(1, 24)) if i % 2 else int(rng.integers(24, 64))
               for i in range(n_requests)]
    return [
        Request(
            uid=f"r{i}",
            prompt=[int(t) for t in rng.integers(1, 200, size=n)],
            max_new_tokens=max_new,
        )
        for i, n in enumerate(lengths)
    ]


def shared_prefix_requests(n_requests: int, max_new: int, *, prefix_len: int,
                           tail_len: int, seed: int = 1):
    """One shared system prompt + short distinct per-request tails."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, 200, size=prefix_len)]
    return [
        Request(
            uid=f"s{i}",
            prompt=prefix + [int(t) for t in rng.integers(1, 200, size=tail_len)],
            max_new_tokens=max_new,
        )
        for i in range(n_requests)
    ], prefix


def midpage_requests(n_requests: int, max_new: int, *, prefix_len: int,
                     tail_len: int, page_size: int, seed: int = 4):
    """Prompts sharing a prefix that ends MID-page: page-aligned matching
    strands the partial page's tokens; sub-page matching recovers them.
    Returns (requests, priming prompt).  The priming prompt pads the
    shared prefix out to a whole page, so the partially-shared chunk is
    indexed as a FULL page later requests can partially match (only full
    chunks are ever published)."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, 200, size=prefix_len)]
    pad = -prefix_len % page_size
    prime = prefix + [int(t) for t in rng.integers(1, 200, size=pad)]
    reqs = [
        Request(
            uid=f"m{i}",
            prompt=prefix + [int(t) for t in rng.integers(1, 200, size=tail_len)],
            max_new_tokens=max_new,
        )
        for i in range(n_requests)
    ]
    return reqs, prime


def decode_heavy_requests(n_requests: int, max_new: int, seed: int = 11):
    """Short prompts, long generations: the shape where speculative
    decoding matters.  Almost every dispatch is a decode tick, so
    accepted draft tokens translate ~1:1 into saved target dispatches."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            uid=f"d{i}",
            prompt=[int(t) for t in rng.integers(1, 200,
                                                 size=int(rng.integers(4, 12)))],
            max_new_tokens=max_new,
        )
        for i in range(n_requests)
    ]


def staggered_requests(n_requests: int, max_new: int, seed: int = 7):
    """One long-running generation plus short requests trickling in: the
    head-of-line-blocking shape where continuous batching matters.  A
    drain-then-refill scheduler strands every later arrival behind the
    long request; continuous batching cycles them through the freed
    rows.  Returns (requests, arrival ticks)."""
    import numpy as np

    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    reqs = [Request(uid="long",
                    prompt=[int(t) for t in rng.integers(1, 200, size=8)],
                    max_new_tokens=3 * n_requests)]
    for i in range(n_requests - 1):
        n = int(rng.integers(4, 13))
        reqs.append(Request(uid=f"s{i}",
                            prompt=[int(t) for t in rng.integers(1, 200, size=n)],
                            max_new_tokens=max_new))
    arrivals = [0] + [1 + i for i in range(n_requests - 1)]
    return reqs, arrivals


def churn_request_bodies(n_requests: int, max_new: int, *, prefix_len: int,
                         tail_len: int, seed: int = 21):
    """Queue message bodies for the elastic-churn drill: one shared
    page-sized system prefix (so survivors can hydrate it from the
    cross-host store after a revocation) plus short distinct tails."""
    import numpy as np

    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, 200, size=prefix_len)]
    return [
        {"uid": f"c{i}",
         "prompt": prefix + [int(t) for t in rng.integers(1, 200,
                                                          size=tail_len)],
         "max_new_tokens": max_new}
        for i in range(n_requests)
    ]


def disagg_request_bodies(n_requests: int, *, prefix_len: int, long_tail: int,
                          short_tail: int, long_new: int, short_new: int,
                          seed: int = 31):
    """Queue message bodies for the disaggregation drill: a shared
    page-sized system prefix, then an alternating mix of long-prompt /
    short-generation and short-prompt / long-generation requests — the
    workload shape where interleaved chunked prefill steals the most
    decode ticks from a monolithic worker."""
    import numpy as np

    rng = np.random.default_rng(seed)
    prefix = [int(t) for t in rng.integers(1, 200, size=prefix_len)]
    bodies = []
    for i in range(n_requests):
        tail_len, max_new = ((long_tail, short_new) if i % 2 == 0
                             else (short_tail, long_new))
        bodies.append({
            "uid": f"g{i}",
            "prompt": prefix + [int(t) for t in rng.integers(1, 200,
                                                             size=tail_len)],
            "max_new_tokens": max_new,
        })
    return bodies


# lease robustness counters aggregated over every segment summary a churn
# run leaves behind (per-worker RESULTS-*.json + drained leases/*.json)
_CHURN_COUNTERS = (
    "revocation_notices", "drain_requeued_requests", "requests_resumed",
    "lease_slices", "lease_resumes",
    "prefix_store_pages_hydrated", "prefix_store_pages_published",
    # work-preserving recovery: generation checkpoints written at drain,
    # resumes that restored emitted tokens from them, and the fallback /
    # flaky-storage books that prove the degradation ladder was walked
    "checkpoints_published", "checkpoint_resumes", "tokens_recovered",
    "checkpoint_fallbacks", "decode_tokens_discarded",
    "publish_retries", "prefix_store_hash_mismatches",
)


def run_churn_fleet(*, label: str, autoscale: str, max_fleet: int, bodies,
                    serve_job: dict, arrivals: dict, chaos_seed: int,
                    workdir: str, tick_seconds: float = 30.0,
                    max_ticks: int = 600, flaky_duration: float = 0.0,
                    flaky_scope: str = "",
                    sabotage_checkpoints: bool = False) -> dict:
    """One simulated serving fleet under an arrival spike and a seeded
    spot-revocation drill: elastic serving leases stream requests from a
    shared DurableQueue, the chaos monkey revokes instances mid-spike
    (the victims drain gracefully and requeue their in-flight work), and
    survivors/replacements resume it — hydrating the shared prefix's KV
    page from the object store instead of re-prefilling.  All latency is
    virtual-clock, so the numbers are deterministic on any host.

    ``flaky_duration`` > 0 opens a transient storage+queue fault window
    alongside every revocation (``ChaosMonkey.recovery_drill``), so the
    drain/resume paths must survive first-attempt put/get/receive
    failures via retry.  ``sabotage_checkpoints`` makes every generation
    checkpoint unreadable (reads under ``checkpoints/`` raise), forcing
    resumes down the fallback ladder to prefix-hit full replay."""
    from repro.core import (
        DSConfig, DSRuntime, FleetFile, JobFile, SimRunner, VirtualClock,
    )
    from repro.core.chaos import ChaosMonkey
    from repro.core.queue import DurableQueue
    from repro.launch.serve import reset_serve_state
    from repro.serving.types import percentiles
    import repro.launch.serve  # noqa: F401  (registers distributed-serve)
    import repro.launch.train  # noqa: F401

    # worker ids repeat across independent simulated runs: stale warm
    # engines must not let lease state "survive" a simulated fleet swap
    reset_serve_state()
    clk = VirtualClock()
    cfg = DSConfig(
        app_name=f"Churn{label.capitalize()}",
        payload="distributed-serve",
        cluster_machines=1,
        tasks_per_machine=1,
        machine_type=["sim.large"],
        machine_price=1.0,
        # one task fills a sim.large (8 vcpus): ECS bin-packs by
        # resources, so a half-size task would double up on the first
        # machine and leave scaled-up instances idle (and revocations
        # would hit workerless machines)
        cpu_shares=8192,
        memory_mb=16384,
        sqs_message_visibility=240.0,
        check_if_done=False,
        idle_alarm_seconds=100_000.0,  # chaos drives churn, not idle alarms
        monitor_poll_seconds=tick_seconds,
        autoscale=autoscale,
        min_workers=1,
        max_workers=max_fleet,
        autoscale_queue_per_worker=3,
        autoscale_target_p99_ttft=6.0,
        autoscale_up_cooldown_seconds=tick_seconds,
        autoscale_down_cooldown_seconds=600.0,
        autoscale_max_step=2,
    )
    rt = DSRuntime(cfg, store_root=os.path.join(workdir, f"store_{label}"),
                   clock=clk)
    rt.setup()
    if sabotage_checkpoints:
        # total checkpoint loss: puts still land (the durable-before-ack
        # ordering is still exercised) but every read raises, so every
        # resume must fall back to prefix-hit full replay.  The chaos
        # monkey's flaky wrapper stacks on top of this one, so reads are
        # ALSO transiently faulted first — the full ladder in one leg.
        _orig_get = rt.store.get_bytes

        def _sabotaged_get(key, *a, **kw):
            if "/checkpoints/" in key:
                raise FileNotFoundError(f"chaos: checkpoint sabotaged {key!r}")
            return _orig_get(key, *a, **kw)

        rt.store.get_bytes = _sabotaged_get
    rq_path = os.path.join(workdir, f"requests_{label}.sqlite")
    rq = DurableQueue(
        rq_path,
        default_visibility=float(serve_job.get("request_visibility", 240.0)),
        max_receive_count=int(serve_job.get("request_max_receive_count", 6)),
        clock=clk,
    )
    job = dict(serve_job, request_queue=rq_path,
               expected_requests=len(bodies), output_prefix="serve/churn")
    # interchangeable lease permits, one per potential worker: any permit
    # a worker claims resumes that worker's own warm engine
    rt.submit_job(JobFile(shared=job, groups=[{} for _ in range(max_fleet)]))
    rt.start_cluster(FleetFile(startup_seconds=tick_seconds, market_seed=7))
    # first notice lands mid-spike (arrivals peak at tick 4); an event
    # whose victim pool is empty (everything already revoked) stays
    # pending and fires once a replacement is running, so the static
    # single-machine fleet eats both revocations back to back
    if flaky_duration > 0:
        chaos = ChaosMonkey.recovery_drill(
            rt.fleet, clk, seed=chaos_seed, n_revocations=2,
            start=3 * tick_seconds, spacing=3 * tick_seconds,
            notice_seconds=2 * tick_seconds,
            flaky_duration=flaky_duration, flaky_scope=flaky_scope,
            store=rt.store, logs=rt.logs, queue=rq,
        )
    else:
        chaos = ChaosMonkey.revocation_drill(
            rt.fleet, clk, seed=chaos_seed, n_revocations=2,
            start=3 * tick_seconds, spacing=3 * tick_seconds,
            notice_seconds=2 * tick_seconds, store=rt.store, logs=rt.logs,
        )
    submitted_at = {}

    def on_tick(t):
        for body in arrivals.get(t, ()):
            submitted_at[body["uid"]] = clk.now()
            rq.send(dict(body, submitted_at=clk.now()))

    runner = SimRunner(rt, tick_seconds=tick_seconds, chaos=chaos,
                       on_tick=on_tick)
    summary = runner.run(max_ticks=max_ticks)
    req_prefix = "serve/churn/requests/"
    records = {
        info.key[len(req_prefix):-len(".json")]: rt.store.get_json(info.key)
        for info in rt.store.list(req_prefix)
        if info.key.endswith(".json")
    }
    # one cumulative record per worker: the final RESULTS- summary where
    # the worker wrote one, else its last slice/drain record under
    # leases/ (summing both would double-count — each is cumulative)
    finals, slices = {}, {}
    for seg_prefix in ("serve/churn/RESULTS-", "serve/churn/leases/"):
        for info in rt.store.list(seg_prefix):
            if not info.key.endswith(".json"):
                continue
            base = info.key.rsplit("/", 1)[-1][:-len(".json")]
            if "/leases/" in info.key:
                slices[base] = rt.store.get_json(info.key)
            else:
                finals[base.split("RESULTS-", 1)[-1]] = (
                    rt.store.get_json(info.key))
    counters = {k: 0 for k in _CHURN_COUNTERS}
    for seg in {**slices, **finals}.values():
        for k in counters:
            # noop permit summaries carry no counter block
            counters[k] += int(seg.get(k, 0))
    # client-observed latency: submit (queue send) -> completion record,
    # in virtual seconds.  p99 over the request population is the
    # fleet-level SLO the autoscaler is being graded on.
    turnarounds = [rec["done_at"] - submitted_at[uid]
                   for uid, rec in records.items() if uid in submitted_at]
    sim_s = summary.wall_time
    tokens = sum(len(r["completion"]) for r in records.values())
    result = {
        "sim_seconds": round(sim_s, 1),
        "tokens_per_sim_s": round(tokens / max(sim_s, 1e-9), 4),
        "p99_ttft_s": percentiles(turnarounds)["p99"],
        "lost_requests": len(bodies) - len(records),
        "revocations_injected": chaos.counters["revocations"],
        "requests_requeued": counters["drain_requeued_requests"],
        "requests_resumed": counters["requests_resumed"],
        "revocation_notices": counters["revocation_notices"],
        "lease_slices": counters["lease_slices"],
        "lease_resumes": counters["lease_resumes"],
        "prefix_store_pages_hydrated": counters["prefix_store_pages_hydrated"],
        "prefix_store_pages_published": counters["prefix_store_pages_published"],
        "checkpoints_published": counters["checkpoints_published"],
        "checkpoint_resumes": counters["checkpoint_resumes"],
        "tokens_recovered": counters["tokens_recovered"],
        "checkpoint_fallbacks": counters["checkpoint_fallbacks"],
        "decode_tokens_discarded": counters["decode_tokens_discarded"],
        "publish_retries": counters["publish_retries"],
        "prefix_store_hash_mismatches": counters["prefix_store_hash_mismatches"],
        # tokens decode had to redo: everything rolled back at preemption
        # minus everything a checkpoint resume restored (the held-back
        # re-dispatch token stays, by design)
        "tokens_redecoded": (counters["decode_tokens_discarded"]
                             - counters["tokens_recovered"]),
        "storage_faults": chaos.counters.get("storage_faults", 0),
        "queue_faults": chaos.counters.get("queue_faults", 0),
        "workers_peak": max(
            (r.running_instances for r in runner.monitor.history), default=0),
        "ticks": summary.ticks,
        "outputs": {uid: r["completion"] for uid, r in records.items()},
    }
    rq.close()
    reset_serve_state()
    return result


# per-role counters aggregated over a disaggregated fleet's segment
# summaries, keyed by each role pool's output prefix
_DISAGG_COUNTERS = (
    "ticks", "tokens_emitted", "prompt_tokens_ingested",
    "prompt_tokens_skipped", "decode_dispatches", "prefill_dispatches",
    "handoffs_published", "handoffs_admitted",
    "handoff_fallbacks", "handoff_seal_rejects",
    "prefix_store_pages_hydrated", "prefix_store_pages_published",
    "hydration_fetch_ops", "prefix_store_bytes_fetched",
    "publish_dedup_hits",
)


def run_disagg_fleet(*, label: str, split: bool, bodies, serve_job: dict,
                     arrivals: dict, workdir: str,
                     tick_seconds: float = 30.0,
                     max_ticks: int = 600) -> dict:
    """One simulated serving fleet over the disaggregation workload, at
    fixed hardware (two machines, autoscaling off).  ``split=False``
    runs the monolithic baseline: two unified permits draining one
    request queue.  ``split=True`` runs the same two machines role-split
    — one prefill permit that chunk-prefills prompts, publishes their KV
    chains to the prefix store and enqueues sealed handoff records, and
    one decode permit that hydrates those chains on demand and decodes.
    All latency is virtual-clock, and the serving-side metrics (TTFT in
    engine ticks, tokens per engine tick) are counter-derived, so every
    number is deterministic on any host."""
    from repro.core import (
        DSConfig, DSRuntime, FleetFile, JobFile, SimRunner, VirtualClock,
    )
    from repro.core.queue import DurableQueue
    from repro.launch.serve import reset_serve_state
    from repro.serving.types import percentiles
    import repro.launch.serve  # noqa: F401  (registers distributed-serve)
    import repro.launch.train  # noqa: F401

    reset_serve_state()
    clk = VirtualClock()
    cfg = DSConfig(
        app_name=f"Disagg{label.capitalize()}",
        payload="distributed-serve",
        cluster_machines=2,
        tasks_per_machine=1,
        machine_type=["sim.large"],
        machine_price=1.0,
        # one task fills a sim.large: both legs get exactly two workers
        # on two machines, so the comparison is at equal total hardware
        cpu_shares=8192,
        memory_mb=16384,
        sqs_message_visibility=240.0,
        check_if_done=False,
        idle_alarm_seconds=100_000.0,
        monitor_poll_seconds=tick_seconds,
        autoscale="off",
        min_workers=2,
        max_workers=2,
    )
    rt = DSRuntime(cfg, store_root=os.path.join(workdir, f"store_{label}"),
                   clock=clk)
    rt.setup()
    visibility = float(serve_job.get("request_visibility", 240.0))
    max_rc = int(serve_job.get("request_max_receive_count", 6))
    rq_path = os.path.join(workdir, f"requests_{label}.sqlite")
    rq = DurableQueue(rq_path, default_visibility=visibility,
                      max_receive_count=max_rc, clock=clk)
    n = len(bodies)
    if split:
        dq_path = os.path.join(workdir, f"decode_{label}.sqlite")
        dq = DurableQueue(dq_path, default_visibility=visibility,
                          max_receive_count=max_rc, clock=clk)
        # distinct per-role output prefixes keep each pool's RESULTS-*
        # and leases/* segments separately aggregatable
        groups = [
            {"worker_role": "prefill", "request_queue": rq_path,
             "decode_queue": dq_path, "expected_requests": n,
             "output_prefix": "serve/dpre"},
            {"worker_role": "decode", "request_queue": dq_path,
             "expected_requests": n, "output_prefix": "serve/ddec"},
        ]
        outs = {"prefill": "serve/dpre", "decode": "serve/ddec"}
        serving_role = "decode"
        req_prefix = "serve/ddec/requests/"
    else:
        dq = None
        groups = [
            {"request_queue": rq_path, "expected_requests": n,
             "output_prefix": "serve/mono"}
            for _ in range(2)
        ]
        outs = {"unified": "serve/mono"}
        serving_role = "unified"
        req_prefix = "serve/mono/requests/"
    rt.submit_job(JobFile(shared=dict(serve_job), groups=groups))
    rt.start_cluster(FleetFile(startup_seconds=tick_seconds, market_seed=7))
    submitted_at = {}

    def on_tick(t):
        for body in arrivals.get(t, ()):
            submitted_at[body["uid"]] = clk.now()
            rq.send(dict(body, submitted_at=clk.now()))

    runner = SimRunner(rt, tick_seconds=tick_seconds, on_tick=on_tick)
    summary = runner.run(max_ticks=max_ticks)
    records = {
        info.key[len(req_prefix):-len(".json")]: rt.store.get_json(info.key)
        for info in rt.store.list(req_prefix)
        if info.key.endswith(".json")
    }
    # one cumulative record per worker per role pool (finals supersede
    # that worker's last lease slice, same as the churn aggregation)
    roles = {}
    for role, out in outs.items():
        finals, slices = {}, {}
        for seg_prefix in (f"{out}/RESULTS-", f"{out}/leases/"):
            for info in rt.store.list(seg_prefix):
                if not info.key.endswith(".json"):
                    continue
                base = info.key.rsplit("/", 1)[-1][:-len(".json")]
                if "/leases/" in info.key:
                    slices[base] = rt.store.get_json(info.key)
                else:
                    finals[base.split("RESULTS-", 1)[-1]] = (
                        rt.store.get_json(info.key))
        agg = {k: 0 for k in _DISAGG_COUNTERS}
        ttft = 0.0
        for seg in {**slices, **finals}.values():
            for k in _DISAGG_COUNTERS:
                agg[k] += int(seg.get(k, 0))
            t = seg.get("timing", {}).get("ttft_ticks", {})
            ttft = max(ttft, float(t.get("p99", 0.0)))
        # fleet-level serving latency: the worst worker's p99 TTFT, in
        # engine ticks from admission to first emitted token
        agg["ttft_ticks_p99"] = ttft
        agg["tokens_per_tick"] = round(
            agg["tokens_emitted"] / max(agg["ticks"], 1), 4)
        roles[role] = agg
    serving = roles[serving_role]
    turnarounds = [rec["done_at"] - submitted_at[uid]
                   for uid, rec in records.items() if uid in submitted_at]
    sim_s = summary.wall_time
    tokens = sum(len(r["completion"]) for r in records.values())
    dead = rq.counts()["dead"] + (dq.counts()["dead"] if dq else 0)
    result = {
        "sim_seconds": round(sim_s, 1),
        "tokens_per_sim_s": round(tokens / max(sim_s, 1e-9), 4),
        "p99_turnaround_s": percentiles(turnarounds)["p99"],
        "lost_requests": n - len(records),
        "dead_letters": dead,
        "workers_peak": max(
            (r.running_instances for r in runner.monitor.history), default=0),
        "ticks": summary.ticks,
        # serving-side (decode pool on the split leg, the whole fleet on
        # the monolith): what the role split is supposed to improve
        "ttft_ticks_p99": serving["ttft_ticks_p99"],
        "tokens_per_tick": serving["tokens_per_tick"],
        "prompt_tokens_ingested_serving_side": serving["prompt_tokens_ingested"],
        "prefix_store_pages_hydrated": serving["prefix_store_pages_hydrated"],
        "hydration_fetch_ops": serving["hydration_fetch_ops"],
        "prefix_store_bytes_fetched": serving["prefix_store_bytes_fetched"],
        "handoffs_admitted": serving["handoffs_admitted"],
        "handoff_fallbacks": serving["handoff_fallbacks"],
        "handoff_seal_rejects": serving["handoff_seal_rejects"],
        # handoffs are published by the prefill pool, dedup hits by
        # whichever pool published — sum across roles
        "handoffs_published": sum(r["handoffs_published"]
                                  for r in roles.values()),
        "publish_dedup_hits": sum(r["publish_dedup_hits"]
                                  for r in roles.values()),
        "roles": roles,
        "outputs": {uid: r["completion"] for uid, r in records.items()},
    }
    rq.close()
    if dq is not None:
        dq.close()
    reset_serve_state()
    return result


_COUNTERS = (
    "decode_dispatches", "prefill_dispatches", "dispatches",
    "tokens_emitted", "prompt_tokens_ingested",
    "prompt_tokens_skipped", "prefix_hit_tokens",
    "prefix_hit_tokens_partial", "cow_partial_stitches",
    "spec_dispatches", "draft_dispatches",
    "draft_tokens_proposed", "draft_tokens_accepted", "spec_tokens_emitted",
)


def run_engine(model, params, reqs, *, mode: str, max_batch: int, max_len: int,
               prefill_chunk: int, page_size: int = 0, total_pages: int = 0,
               prefix_cache: bool = False, prefix_match: str = "token",
               speculative: str = "off", spec_k: int = 4,
               draft_model=None, draft_params=None,
               prime=None) -> dict:
    from repro.serving.engine import Request, ServeEngine

    paged = mode.startswith("paged")
    engine = ServeEngine(
        model, params,
        max_batch=max_batch, max_len=max_len,
        prefill_chunk=prefill_chunk,
        dispatch_mode="fused" if paged else mode,
        cache_mode="paged" if paged else "dense",
        **(dict(page_size=page_size, total_pages=total_pages,
                prefix_cache=prefix_cache, prefix_match=prefix_match)
           if paged else {}),
        **(dict(speculative=speculative, spec_k=spec_k,
                draft_model=draft_model, draft_params=draft_params)
           if speculative != "off" else {}),
    )
    # compile both dispatch paths on a throwaway request OUTSIDE the timed
    # region, then measure the real workload from its very first step —
    # otherwise the fused engine's warm-up would silently perform the whole
    # initial prefill phase off the clock and inflate tokens/sec
    engine.submit([Request(uid="__warmup__",
                           prompt=[1] * max(2 * max(prefill_chunk, 1), 2),
                           max_new_tokens=2)])
    engine.run_to_completion()
    if prime is not None:
        # steady-state shared-prefix serving: a priming request populates
        # the radix cache (one prefill of the system prompt) before the
        # measured window — run on every engine so wall-clocks compare
        engine.submit([Request(uid="__prime__", prompt=list(prime),
                               max_new_tokens=2)])
        engine.run_to_completion()
    base = {k: getattr(engine, k, 0) for k in _COUNTERS}
    if paged:
        # re-baseline the page stats too: the warmup request's private
        # pages are freed by now (cached prefix pages stay resident), so
        # the measured window starts from live usage
        alloc_base = engine.page_allocs
        engine.peak_pages = engine.pages_in_use
    # scope the latency samples to the measured window (warmup/prime
    # requests recorded their own)
    waits0 = len(engine.scheduler.queue_waits)
    ttfts0 = len(engine.scheduler.ttfts)

    engine.submit(reqs)
    t0 = time.perf_counter()
    engine.run_to_completion()
    wall = time.perf_counter() - t0
    c = {k: getattr(engine, k, 0) - base[k] for k in _COUNTERS}
    total_tokens = c["tokens_emitted"] + c["prompt_tokens_ingested"]
    out = {
        "dispatch_mode": engine.dispatch_mode,  # paged runs the fused path
        "wall_s": round(wall, 3),
        **c,
        "tokens_per_sec": round(c["tokens_emitted"] / max(wall, 1e-9), 1),
        "dispatches_per_token": round(c["dispatches"] / max(total_tokens, 1), 4),
        # tokens landed per fused verify dispatch (>1 means speculation
        # is paying for itself; exactly the engine's accepted run + 1
        # bonus token per live row)
        "accepted_per_dispatch": round(
            c["spec_tokens_emitted"] / c["spec_dispatches"], 4
        ) if c["spec_dispatches"] else 0.0,
        "prompt_tokens_per_prefill_dispatch": round(
            c["prompt_tokens_ingested"] / max(c["prefill_dispatches"], 1), 2
        ),
        # queue-wait / time-to-first-token percentiles in engine ticks
        # (deterministic, unlike wall-clock) for the measured window
        "timing": engine.scheduler.timing(waits0, ttfts0),
        # emitted tokens per request, for the byte-identity gates
        "outputs": {r.uid: list(r.output) for r in engine.finished
                    if not r.uid.startswith("__")},
    }
    if paged:
        out.update(
            cache_mode="paged",
            prefix_cache=prefix_cache,
            prefix_match=engine.cache_mgr.prefix_match,
            page_size=engine.page_size,
            total_pages=engine.n_pages,
            pages_in_use_peak=engine.peak_pages,
            page_allocs=engine.page_allocs - alloc_base,
            peak_cache_bytes=engine.peak_cache_bytes,
            dense_cache_bytes=engine.dense_cache_bytes,
            pages_shared_peak=engine.pages_shared_peak,
            cow_copies=engine.cow_copies,
            prefix_evictions=engine.prefix_evictions,
            preemptions=engine.preemptions,
        )
    else:
        out.update(cache_mode="dense", peak_cache_bytes=engine.peak_cache_bytes)
    return out


def run_staggered(model, params, reqs, arrivals, *, refill_policy: str,
                  max_batch: int, max_len: int, prefill_chunk: int) -> dict:
    """Staggered-arrival scenario: requests are submitted at the tick
    ``arrivals[i]`` says, while the engine is already generating.  The
    ``continuous`` refill policy admits them into rows the moment one
    frees; the ``drain`` baseline only refills an empty batch, so late
    arrivals stack behind the whole in-flight batch.  TTFT/queue-wait
    are measured in engine ticks (deterministic on any host)."""
    from repro.serving.engine import Request, ServeEngine

    engine = ServeEngine(
        model, params, max_batch=max_batch, max_len=max_len,
        prefill_chunk=prefill_chunk, refill_policy=refill_policy,
    )
    engine.submit([Request(uid="__warmup__",
                           prompt=[1] * max(2 * max(prefill_chunk, 1), 2),
                           max_new_tokens=2)])
    engine.run_to_completion()
    waits0 = len(engine.scheduler.queue_waits)
    ttfts0 = len(engine.scheduler.ttfts)
    base_dispatches = engine.dispatches

    schedule = sorted(zip(arrivals, range(len(reqs))))
    t0 = time.perf_counter()
    i = 0
    tick = 0
    while i < len(schedule) or engine.pending or engine.scheduler.has_active():
        while i < len(schedule) and schedule[i][0] <= tick:
            engine.submit([reqs[schedule[i][1]]])
            i += 1
        engine.step()
        tick += 1
    wall = time.perf_counter() - t0
    timing = engine.scheduler.timing(waits0, ttfts0)
    return {
        "refill_policy": refill_policy,
        "wall_s": round(wall, 3),
        "ticks": tick,
        "dispatches": engine.dispatches - base_dispatches,
        "tokens_emitted": sum(
            len(r.output) for r in engine.finished if not r.uid.startswith("__")
        ),
        "timing": timing,
        "mean_ttft_ticks": timing["ttft_ticks"]["mean"],
        "outputs": {r.uid: list(r.output) for r in engine.finished
                    if not r.uid.startswith("__")},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config / short run for tier-1 CI on CPU")
    ap.add_argument("--arch", default="ds-paper-100m")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelRuntime

    n_requests = args.requests or (6 if args.smoke else 24)
    max_new = args.max_new or (4 if args.smoke else 32)
    max_batch = 4 if args.smoke else 8
    max_len = 128
    prefill_chunk = 8 if args.smoke else 32

    cfg = reduced(get_arch(args.arch))
    model = Model(cfg, ModelRuntime())
    params = model.init(jax.random.PRNGKey(0))

    # page pool sized to the workload's actual demand: longest request
    # (prompt + generated tokens) rounded up to whole pages, per slot —
    # strictly below the dense max_len reservation
    page_size = 16
    longest = max(len(r.prompt) + r.max_new_tokens
                  for r in ragged_requests(n_requests, max_new))
    pages_per_req = -(-longest // page_size)
    total_pages = max_batch * pages_per_req

    modes = ["grouped", "fused"]
    if model.supports_paged_cache:
        modes.append("paged")
    else:
        print(f"[bench_serving] paged     skipped: arch {args.arch!r} has no "
              "pageable KV cache (rolling window / recurrent state / enc-dec)")

    results = {}
    for mode in modes:
        reqs = ragged_requests(n_requests, max_new)
        # the ragged paged run keeps prefix_cache=False: random prompts
        # share nothing, and this keeps it the PR 2 per-slot baseline the
        # schedule-equality gate below compares against
        results[mode] = run_engine(
            model, params, reqs, mode=mode,
            max_batch=max_batch, max_len=max_len, prefill_chunk=prefill_chunk,
            page_size=page_size, total_pages=total_pages,
        )
        r = results[mode]
        extra = ""
        if mode == "paged":
            extra = (f" peak_cache={r['peak_cache_bytes'] / 1024:.0f}KiB"
                     f"/{r['dense_cache_bytes'] / 1024:.0f}KiB dense"
                     f" pages={r['pages_in_use_peak']}/{r['total_pages']}")
        print(
            f"[bench_serving] {mode:8s} tokens/s={r['tokens_per_sec']:8.1f} "
            f"dispatches/token={r['dispatches_per_token']:.4f} "
            f"(decode={r['decode_dispatches']} prefill={r['prefill_dispatches']})"
            + extra
        )

    # ---------------------------------------------- shared-prefix scenario
    shared_results = {}
    shared_scenario = {}
    if model.supports_paged_cache:
        sp_requests = 6 if args.smoke else n_requests
        sp_batch = 2 if args.smoke else max_batch
        sp_prefix = 32 if args.smoke else 64
        sp_tail = 4 if args.smoke else 8
        _, sp_sys = shared_prefix_requests(
            sp_requests, max_new, prefix_len=sp_prefix, tail_len=sp_tail
        )
        sp_pages_per_req = -(-(sp_prefix + sp_tail + max_new) // page_size)
        sp_total_pages = sp_batch * sp_pages_per_req
        shared_scenario = {
            "n_requests": sp_requests, "max_new_tokens": max_new,
            "max_batch": sp_batch, "max_len": max_len,
            "prefill_chunk": prefill_chunk, "page_size": page_size,
            "total_pages": sp_total_pages,
            "prefix_len": sp_prefix, "tail_len": sp_tail, "primed": True,
        }
        for name, kwargs in (
            ("fused", {}),
            ("paged", dict(page_size=page_size, total_pages=sp_total_pages)),
            ("paged_prefix", dict(page_size=page_size, total_pages=sp_total_pages,
                                  prefix_cache=True)),
        ):
            # fresh Request objects per engine (outputs accumulate in place)
            reqs, _ = shared_prefix_requests(
                sp_requests, max_new, prefix_len=sp_prefix, tail_len=sp_tail
            )
            shared_results[name] = run_engine(
                model, params, reqs,
                mode="paged" if name.startswith("paged") else name,
                max_batch=sp_batch, max_len=max_len,
                prefill_chunk=prefill_chunk, prime=sp_sys, **kwargs,
            )
            r = shared_results[name]
            print(
                f"[bench_serving] shared/{name:12s} tokens/s="
                f"{r['tokens_per_sec']:8.1f} "
                f"prompt_tokens={r['prompt_tokens_ingested']} "
                f"skipped={r.get('prompt_tokens_skipped', 0)} "
                f"peak_cache={r['peak_cache_bytes'] / 1024:.0f}KiB"
                + (f" shared_pages_peak={r['pages_shared_peak']}"
                   if name == "paged_prefix" else "")
            )

    # --------------------------------------- mid-page-divergence scenario
    midpage_results = {}
    midpage_scenario = {}
    if model.supports_paged_cache:
        mp_requests = 6 if args.smoke else n_requests
        mp_batch = 2 if args.smoke else max_batch
        # shared prefix ends MID-page: page-aligned matching reuses only
        # the whole pages below it, sub-page matching recovers the rest
        mp_prefix = (2 * page_size + page_size // 2) if args.smoke \
            else (4 * page_size + page_size // 2)
        mp_tail = 4 if args.smoke else 8
        _, mp_prime = midpage_requests(
            mp_requests, max_new, prefix_len=mp_prefix, tail_len=mp_tail,
            page_size=page_size,
        )
        mp_pages_per_req = -(-(mp_prefix + mp_tail + max_new) // page_size)
        mp_total_pages = (mp_batch + 1) * mp_pages_per_req
        midpage_scenario = {
            "n_requests": mp_requests, "max_new_tokens": max_new,
            "max_batch": mp_batch, "max_len": max_len,
            "prefill_chunk": prefill_chunk, "page_size": page_size,
            "total_pages": mp_total_pages,
            "prefix_len": mp_prefix, "tail_len": mp_tail, "primed": True,
        }
        for name, kwargs in (
            ("fused", {}),
            ("paged_prefix_page", dict(page_size=page_size,
                                       total_pages=mp_total_pages,
                                       prefix_cache=True,
                                       prefix_match="page")),
            ("paged_prefix_token", dict(page_size=page_size,
                                        total_pages=mp_total_pages,
                                        prefix_cache=True,
                                        prefix_match="token")),
        ):
            reqs, _ = midpage_requests(
                mp_requests, max_new, prefix_len=mp_prefix, tail_len=mp_tail,
                page_size=page_size,
            )
            midpage_results[name] = run_engine(
                model, params, reqs,
                mode="paged" if name.startswith("paged") else name,
                max_batch=mp_batch, max_len=max_len,
                prefill_chunk=prefill_chunk, prime=mp_prime, **kwargs,
            )
            r = midpage_results[name]
            print(
                f"[bench_serving] midpage/{name:18s} tokens/s="
                f"{r['tokens_per_sec']:8.1f} "
                f"prompt_tokens={r['prompt_tokens_ingested']} "
                f"skipped={r.get('prompt_tokens_skipped', 0)} "
                f"partial_hits={r.get('prefix_hit_tokens_partial', 0)} "
                f"cow_partial={r.get('cow_partial_stitches', 0)}"
            )

    # ------------------------------------------ decode-heavy (speculative)
    # short prompts, long generations, low batch: the latency-bound
    # regime speculative decoding targets — almost every dispatch is a
    # decode tick and there is no batching to hide per-dispatch cost, so
    # accepted drafts translate directly into fewer target dispatches
    # and lower wall-clock per token.  "off" is the fused paged
    # baseline; "ngram" drafts by prompt-lookup over each request's own
    # history; "draft" runs a separately-initialised draft model (same
    # reduced arch here — a deliberately pessimal draft whose guesses
    # rarely land, demonstrating that byte parity and rollback hold even
    # when every draft is rejected; a real deployment pairs a small
    # draft arch with a large target)
    spec_results = {}
    spec_scenario = {}
    if model.supports_paged_cache:
        sd_requests = 4 if args.smoke else 8
        # speculation pays off where prompt-lookup finds structure, and
        # this model's greedy continuations only settle into repetitive
        # patterns a few dozen tokens in — so the full run generates
        # long and single-stream (the smoke run still gates parity +
        # dispatch reduction; it keeps two rows so the spec tick's
        # mixed live/parked row handling stays covered)
        sd_new = 24 if args.smoke else 640
        sd_batch = 2 if args.smoke else 1
        sd_max_len = max_len if args.smoke else 672
        sd_k = 8
        sd_longest = max(len(r.prompt) + r.max_new_tokens
                         for r in decode_heavy_requests(sd_requests, sd_new))
        sd_total_pages = sd_batch * (-(-sd_longest // page_size))
        spec_scenario = {
            "n_requests": sd_requests, "max_new_tokens": sd_new,
            "max_batch": sd_batch, "max_len": sd_max_len,
            "prefill_chunk": prefill_chunk, "page_size": page_size,
            "total_pages": sd_total_pages, "spec_k": sd_k,
            "draft_arch": args.arch, "draft_init_seed": 7,
        }
        draft_model = Model(cfg, ModelRuntime())
        draft_params = draft_model.init(jax.random.PRNGKey(7))
        for name, kwargs in (
            ("off", {}),
            ("ngram", dict(speculative="ngram", spec_k=sd_k)),
            ("draft", dict(speculative="draft", spec_k=sd_k,
                           draft_model=draft_model,
                           draft_params=draft_params)),
        ):
            reqs = decode_heavy_requests(sd_requests, sd_new)
            spec_results[name] = run_engine(
                model, params, reqs, mode="paged",
                max_batch=sd_batch, max_len=sd_max_len,
                prefill_chunk=prefill_chunk,
                page_size=page_size, total_pages=sd_total_pages, **kwargs,
            )
            r = spec_results[name]
            print(
                f"[bench_serving] spec/{name:6s} tokens/s="
                f"{r['tokens_per_sec']:8.1f} "
                f"dispatches/token={r['dispatches_per_token']:.4f} "
                f"accepted/dispatch={r['accepted_per_dispatch']:.2f} "
                f"(proposed={r['draft_tokens_proposed']} "
                f"accepted={r['draft_tokens_accepted']} "
                f"draft_dispatches={r['draft_dispatches']})"
            )

    # ------------------------------------------- staggered-arrival scenario
    # continuous batching vs the drain-then-refill baseline: one long
    # generation plus short requests arriving one per tick
    st_requests = 8 if args.smoke else 16
    st_batch = 2 if args.smoke else 4
    _, st_arrivals = staggered_requests(st_requests, max_new)
    staggered_results = {}
    staggered_scenario = {
        "n_requests": st_requests, "max_new_tokens": max_new,
        "long_max_new_tokens": 3 * st_requests,
        "max_batch": st_batch, "max_len": max_len,
        "prefill_chunk": prefill_chunk, "arrivals": st_arrivals,
    }
    for policy in ("continuous", "drain"):
        reqs, _ = staggered_requests(st_requests, max_new)
        staggered_results[policy] = run_staggered(
            model, params, reqs, st_arrivals, refill_policy=policy,
            max_batch=st_batch, max_len=max_len, prefill_chunk=prefill_chunk,
        )
        r = staggered_results[policy]
        print(
            f"[bench_serving] staggered/{policy:10s} "
            f"mean_ttft={r['mean_ttft_ticks']:6.2f} ticks "
            f"p90={r['timing']['ttft_ticks']['p90']:.0f} "
            f"queue_wait_p90={r['timing']['queue_wait_ticks']['p90']:.0f} "
            f"({r['ticks']} ticks total)"
        )

    # ------------------------------------------------ elastic churn drill
    # static fleet vs autoscaled fleet, both under the same arrival spike
    # and the same seeded revocation drill: robustness (zero lost
    # requests, byte-identical output) is the hard gate, the autoscaler's
    # p99 win and the survivors' prefix-store hydration are the payoff
    churn_results = {}
    churn_scenario = {}
    recovery_results = {}
    recovery_scenario = {}
    if model.supports_paged_cache:
        import tempfile

        # the decode tail is what keeps requests in flight while the
        # drill fires: one request costs ~(1 + max_new_tokens) engine
        # steps and a lease runs stream_slice_ticks steps per simulator
        # tick, so short completions would drain the spike before the
        # second revocation has a victim with anything to lose
        ch_requests = 10 if args.smoke else 20
        ch_new = 12 if args.smoke else 16
        ch_seed = 1234
        ch_bodies = churn_request_bodies(ch_requests, ch_new,
                                         prefix_len=page_size, tail_len=3)
        ch_job = {
            "arch": args.arch, "arch_overrides": "reduced",
            "max_new_tokens": ch_new, "max_len": 64, "max_batch": 2,
            "prefill_chunk": 8, "cache_mode": "paged",
            "page_size": page_size, "prefix_cache": True,
            "prefix_store": True,
            "stream_slice_ticks": 4, "stream_idle_polls": 60,
            "request_visibility": 240.0, "request_max_receive_count": 6,
        }
        # a trickle, then most of the load at once mid-run (ticks are
        # SimRunner ticks, 30 virtual seconds each)
        ch_arrivals = {2: ch_bodies[:3], 4: ch_bodies[3:]}
        churn_scenario = {
            "n_requests": ch_requests, "max_new_tokens": ch_new,
            "max_batch": 2, "prefill_chunk": 8, "page_size": page_size,
            "prefix_len": page_size, "stream_slice_ticks": 4,
            "chaos_seed": ch_seed, "n_revocations": 2,
            "notice_seconds": 60.0, "tick_seconds": 30.0,
            "min_workers": 1, "max_workers": 3,
            "arrivals_by_tick": {str(k): len(v)
                                 for k, v in ch_arrivals.items()},
        }
        # undisturbed oracle: the same requests through one direct engine
        # (greedy sampling streams are submit-order keyed, so output is
        # scheduling- and fleet-invariant)
        from repro.serving.engine import Request, ServeEngine

        oracle_eng = ServeEngine(model, params, max_batch=2, max_len=64,
                                 prefill_chunk=8)
        oracle_eng.submit([
            Request(uid=b["uid"], prompt=list(b["prompt"]),
                    max_new_tokens=ch_new)
            for b in ch_bodies
        ])
        oracle_eng.run_to_completion()
        oracle = {r.uid: list(r.output) for r in oracle_eng.finished}
        with tempfile.TemporaryDirectory() as ch_dir:
            for name, auto, fleet_cap in (("static", "off", 1),
                                          ("autoscaled", "slo", 3)):
                r = run_churn_fleet(
                    label=name, autoscale=auto, max_fleet=fleet_cap,
                    bodies=ch_bodies, serve_job=ch_job,
                    arrivals=ch_arrivals, chaos_seed=ch_seed,
                    workdir=ch_dir,
                )
                r["byte_identical"] = r["outputs"] == oracle
                churn_results[name] = r
                print(
                    f"[bench_serving] churn/{name:10s} "
                    f"p99_turnaround={r['p99_ttft_s']:6.0f}s "
                    f"lost={r['lost_requests']} "
                    f"revocations={r['revocations_injected']} "
                    f"requeued={r['requests_requeued']} "
                    f"resumed={r['requests_resumed']} "
                    f"hydrated={r['prefix_store_pages_hydrated']} "
                    f"workers_peak={r['workers_peak']} "
                    f"identical={r['byte_identical']}"
                )

        # ------------------------------------------- recovery drill
        # the same spike and seeded revocations, now with transient
        # storage/queue fault windows riding along every notice.  Three
        # fleets, identical chaos: generation checkpoints OFF (every
        # drained request replays its decode from token zero),
        # checkpoints ON (drained requests resume mid-generation and
        # continue pure decode), and checkpoints ON but sabotaged (every
        # record unreadable, so resumes walk the fallback ladder down to
        # prefix-hit full replay).  All three must be byte-identical to
        # the undisturbed oracle and lose nothing; the checkpoint fleet
        # must re-decode a small fraction of the replay fleet's tokens.
        rc_requests = 8 if args.smoke else 16
        rc_new = 14 if args.smoke else 16
        rc_seed = 4321
        rc_flaky = 120.0  # covers notice -> drain -> early resume
        rc_bodies = churn_request_bodies(rc_requests, rc_new,
                                         prefix_len=page_size, tail_len=3,
                                         seed=33)
        rc_job = dict(ch_job, max_new_tokens=rc_new)
        rc_arrivals = {2: rc_bodies[:3], 4: rc_bodies[3:]}
        recovery_scenario = {
            "n_requests": rc_requests, "max_new_tokens": rc_new,
            "max_batch": 2, "prefill_chunk": 8, "page_size": page_size,
            "prefix_len": page_size, "stream_slice_ticks": 4,
            "chaos_seed": rc_seed, "n_revocations": 2,
            "notice_seconds": 60.0, "tick_seconds": 30.0,
            "flaky_duration": rc_flaky,
            "min_workers": 1, "max_workers": 3,
            "arrivals_by_tick": {str(k): len(v)
                                 for k, v in rc_arrivals.items()},
        }
        rc_oracle_eng = ServeEngine(model, params, max_batch=2, max_len=64,
                                    prefill_chunk=8)
        rc_oracle_eng.submit([
            Request(uid=b["uid"], prompt=list(b["prompt"]),
                    max_new_tokens=rc_new)
            for b in rc_bodies
        ])
        rc_oracle_eng.run_to_completion()
        rc_oracle = {r.uid: list(r.output) for r in rc_oracle_eng.finished}
        with tempfile.TemporaryDirectory() as rc_dir:
            for name, job_over, sab in (
                    ("replay", {"generation_checkpoints": False}, False),
                    ("checkpoint", {}, False),
                    ("sabotage", {}, True)):
                r = run_churn_fleet(
                    label=name, autoscale="slo", max_fleet=3,
                    bodies=rc_bodies, serve_job=dict(rc_job, **job_over),
                    arrivals=rc_arrivals, chaos_seed=rc_seed,
                    workdir=rc_dir, flaky_duration=rc_flaky,
                    flaky_scope="serve/churn/,kvprefix/",
                    sabotage_checkpoints=sab,
                )
                r["byte_identical"] = r["outputs"] == rc_oracle
                recovery_results[name] = r
                print(
                    f"[bench_serving] recovery/{name:10s} "
                    f"lost={r['lost_requests']} "
                    f"ckpts={r['checkpoints_published']} "
                    f"resumes={r['checkpoint_resumes']} "
                    f"recovered={r['tokens_recovered']} "
                    f"redecoded={r['tokens_redecoded']} "
                    f"fallbacks={r['checkpoint_fallbacks']} "
                    f"storage_faults={r['storage_faults']} "
                    f"queue_faults={r['queue_faults']} "
                    f"identical={r['byte_identical']}"
                )

    # ------------------------------------ disaggregated prefill/decode
    # monolithic vs role-split serving at equal total hardware (two
    # machines each): prefill workers chunk-prefill and publish KV
    # chains + sealed handoff records, decode workers hydrate on demand
    # and spend every engine tick decoding.  Byte identity against the
    # undisturbed single-engine oracle is the hard gate; the payoff is
    # decode-side TTFT and tokens-per-tick beating the monolith, whose
    # interleaved chunked prefill steals decode ticks.
    disagg_results = {}
    disagg_scenario = {}
    if model.supports_paged_cache:
        import tempfile

        from repro.serving.engine import Request, ServeEngine

        dg_requests = 6 if args.smoke else 12
        dg_long_new, dg_short_new = 16, 6
        dg_long_tail, dg_short_tail = 24, 4
        dg_bodies = disagg_request_bodies(
            dg_requests, prefix_len=page_size,
            long_tail=dg_long_tail, short_tail=dg_short_tail,
            long_new=dg_long_new, short_new=dg_short_new,
        )
        dg_job = {
            "arch": args.arch, "arch_overrides": "reduced",
            "max_len": 64, "max_batch": 2,
            "prefill_chunk": 8, "cache_mode": "paged",
            "page_size": page_size, "prefix_cache": True,
            "prefix_store": True,
            # one chunk per engine tick: without the per-tick ingest cap
            # a whole prompt lands in a single step and prefill never
            # contends with decode, which is exactly the interference
            # the role split exists to remove (a decode worker's
            # hydrated admissions ingest only the one-token frontier)
            "prefill_token_budget": 8,
            "stream_slice_ticks": 4, "stream_idle_polls": 60,
            "request_visibility": 240.0, "request_max_receive_count": 6,
        }
        # paced arrivals (one per tick): the admission backlog stays
        # shallow, so TTFT measures prefill latency — the thing the role
        # split changes — instead of burst queueing, which is identical
        # for both legs
        dg_arrivals = {2 + i: [b] for i, b in enumerate(dg_bodies)}
        disagg_scenario = {
            "n_requests": dg_requests,
            "long_max_new_tokens": dg_long_new,
            "short_max_new_tokens": dg_short_new,
            "long_tail": dg_long_tail, "short_tail": dg_short_tail,
            "max_batch": 2, "prefill_chunk": 8, "page_size": page_size,
            "prefill_token_budget": 8,
            "prefix_len": page_size, "stream_slice_ticks": 4,
            "tick_seconds": 30.0, "machines_per_leg": 2,
            "arrivals_by_tick": {str(k): len(v)
                                 for k, v in dg_arrivals.items()},
        }
        # undisturbed oracle: one direct unified engine (greedy bodies,
        # so output is scheduling- and fleet-topology-invariant)
        dg_oracle_eng = ServeEngine(model, params, max_batch=2, max_len=64,
                                    prefill_chunk=8)
        dg_oracle_eng.submit([
            Request(uid=b["uid"], prompt=list(b["prompt"]),
                    max_new_tokens=b["max_new_tokens"])
            for b in dg_bodies
        ])
        dg_oracle_eng.run_to_completion()
        dg_oracle = {r.uid: list(r.output) for r in dg_oracle_eng.finished}
        with tempfile.TemporaryDirectory() as dg_dir:
            for name, split_flag in (("monolith", False), ("split", True)):
                r = run_disagg_fleet(
                    label=name, split=split_flag, bodies=dg_bodies,
                    serve_job=dg_job, arrivals=dg_arrivals, workdir=dg_dir,
                )
                r["byte_identical"] = r["outputs"] == dg_oracle
                disagg_results[name] = r
                print(
                    f"[bench_serving] disagg/{name:8s} "
                    f"ttft_p99={r['ttft_ticks_p99']:5.1f} ticks "
                    f"tokens/tick={r['tokens_per_tick']:.3f} "
                    f"lost={r['lost_requests']} "
                    f"handoffs={r['handoffs_published']}/"
                    f"{r['handoffs_admitted']} "
                    f"hydrated={r['prefix_store_pages_hydrated']} "
                    f"fallbacks={r['handoff_fallbacks']} "
                    f"identical={r['byte_identical']}"
                )

    report = {
        "arch": args.arch,
        "smoke": args.smoke,
        "scenario": {
            "n_requests": n_requests, "max_new_tokens": max_new,
            "max_batch": max_batch, "max_len": max_len,
            "prefill_chunk": prefill_chunk,
            "page_size": page_size, "total_pages": total_pages,
        },
        "engines": results,
        "dispatch_reduction": round(
            results["grouped"]["dispatches_per_token"]
            / max(results["fused"]["dispatches_per_token"], 1e-9),
            2,
        ),
    }
    paged_speed = 1.0
    if "paged" in results:
        paged_speed = (results["paged"]["tokens_per_sec"]
                       / max(results["fused"]["tokens_per_sec"], 1e-9))
        report["paged_cache_reduction"] = round(
            results["paged"]["dense_cache_bytes"]
            / max(results["paged"]["peak_cache_bytes"], 1), 2
        )
        report["paged_tokens_per_sec_vs_fused"] = round(paged_speed, 3)
    if staggered_results:
        report["continuous_batching"] = {
            "scenario": staggered_scenario,
            "engines": staggered_results,
            "ttft_reduction": round(
                staggered_results["drain"]["mean_ttft_ticks"]
                / max(staggered_results["continuous"]["mean_ttft_ticks"], 1e-9),
                2,
            ),
        }
    if shared_results:
        sp, spp = shared_results["paged"], shared_results["paged_prefix"]
        report["shared_prefix"] = {
            "scenario": shared_scenario,
            "engines": shared_results,
            "prefill_reduction": round(
                sp["prompt_tokens_ingested"]
                / max(spp["prompt_tokens_ingested"], 1), 2
            ),
            "peak_reduction_vs_paged": round(
                sp["peak_cache_bytes"] / max(spp["peak_cache_bytes"], 1), 2
            ),
        }
    if spec_results:
        off = spec_results["off"]
        report["speculative"] = {
            "scenario": spec_scenario,
            "engines": spec_results,
            "best_proposer": max(
                ("ngram", "draft"),
                key=lambda n: spec_results[n]["tokens_per_sec"],
            ),
            "tokens_per_sec_vs_off": {
                n: round(spec_results[n]["tokens_per_sec"]
                         / max(off["tokens_per_sec"], 1e-9), 3)
                for n in ("ngram", "draft")
            },
            "dispatch_reduction_vs_off": {
                n: round(off["dispatches_per_token"]
                         / max(spec_results[n]["dispatches_per_token"], 1e-9), 2)
                for n in ("ngram", "draft")
            },
        }
    if churn_results:
        report["elastic_churn"] = {
            "scenario": churn_scenario,
            "engines": churn_results,
            "p99_ttft_reduction": round(
                churn_results["static"]["p99_ttft_s"]
                / max(churn_results["autoscaled"]["p99_ttft_s"], 1e-9), 2
            ),
        }
    if recovery_results:
        report["recovery_drill"] = {
            "scenario": recovery_scenario,
            "engines": recovery_results,
            # how many fewer tokens the checkpointing fleet had to decode
            # twice, vs replaying every drained generation from scratch
            "redecode_reduction": round(
                recovery_results["replay"]["tokens_redecoded"]
                / max(recovery_results["checkpoint"]["tokens_redecoded"], 1),
                2,
            ),
        }
    if disagg_results:
        dg_mono = disagg_results["monolith"]
        dg_split = disagg_results["split"]
        report["disaggregation"] = {
            "scenario": disagg_scenario,
            "engines": disagg_results,
            # decode-side admission-to-first-token, vs the monolith whose
            # chunked prefill interleaves into the same engine ticks
            "decode_ttft_p99_reduction": round(
                dg_mono["ttft_ticks_p99"]
                / max(dg_split["ttft_ticks_p99"], 1e-9), 2
            ),
            "decode_tokens_per_tick_vs_monolith": round(
                dg_split["tokens_per_tick"]
                / max(dg_mono["tokens_per_tick"], 1e-9), 3
            ),
        }
    if midpage_results:
        mp_page = midpage_results["paged_prefix_page"]
        mp_tok = midpage_results["paged_prefix_token"]
        report["midpage_divergence"] = {
            "scenario": midpage_scenario,
            "engines": midpage_results,
            # prompt tokens the sub-page stitch recovers beyond whole pages
            "prefill_reduction_vs_page_aligned": round(
                mp_page["prompt_tokens_ingested"]
                / max(mp_tok["prompt_tokens_ingested"], 1), 2
            ),
        }

    # the byte-identity gates compare full output dicts; keep them out of
    # the written report (per-request token lists, not metrics)
    outputs = {}
    for prefix, group in (("", results), ("shared/", shared_results),
                          ("midpage/", midpage_results),
                          ("spec/", spec_results),
                          ("staggered/", staggered_results),
                          ("churn/", churn_results),
                          ("recovery/", recovery_results),
                          ("disagg/", disagg_results)):
        for name, r in group.items():
            outputs[prefix + name] = r.pop("outputs")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[bench_serving] wrote {args.out} "
          f"(dispatch reduction {report['dispatch_reduction']}x"
          + (f", paged cache reduction {report['paged_cache_reduction']}x, "
             f"paged speed {paged_speed:.2f}x fused" if "paged" in results else "")
          + (f", shared-prefix prefill reduction "
             f"{report['shared_prefix']['prefill_reduction']}x"
             if shared_results else "")
          + (f", mid-page prefill reduction "
             f"{report['midpage_divergence']['prefill_reduction_vs_page_aligned']}x"
             f" vs page-aligned"
             if midpage_results else "")
          + (f", continuous-batching TTFT reduction "
             f"{report['continuous_batching']['ttft_reduction']}x"
             if staggered_results else "")
          + (f", speculative dispatch reduction "
             f"{max(report['speculative']['dispatch_reduction_vs_off'].values())}x"
             if spec_results else "")
          + (f", churn p99 reduction "
             f"{report['elastic_churn']['p99_ttft_reduction']}x"
             if churn_results else "")
          + (f", recovery re-decode reduction "
             f"{report['recovery_drill']['redecode_reduction']}x"
             if recovery_results else "")
          + (f", disagg decode TTFT reduction "
             f"{report['disaggregation']['decode_ttft_p99_reduction']}x"
             if disagg_results else "")
          + ")")

    # the whole point of the fused engine: strictly fewer dispatches/token
    if results["fused"]["dispatches_per_token"] >= results["grouped"]["dispatches_per_token"]:
        print("[bench_serving] REGRESSION: fused engine not below grouped dispatch rate")
        return 1
    if "paged" in results:
        # and of the paged cache: peak bytes strictly below the dense reservation
        if results["paged"]["peak_cache_bytes"] >= results["paged"]["dense_cache_bytes"]:
            print("[bench_serving] REGRESSION: paged peak not below dense reservation")
            return 1
        # parity in output quality: paged must emit byte-identical tokens
        # on the same dispatch schedule
        if (results["paged"]["dispatches_per_token"] != results["fused"]["dispatches_per_token"]
                or results["paged"]["dispatches"] != results["fused"]["dispatches"]
                or outputs["paged"] != outputs["fused"]):
            print("[bench_serving] REGRESSION: paged schedule/output diverged from fused")
            return 1
        # wall-clock gate only outside smoke (CI boxes are too noisy)
        if not args.smoke and paged_speed < 0.9:
            print(f"[bench_serving] REGRESSION: paged tokens/sec {paged_speed:.2f}x "
                  "fused (< 0.9)")
            return 1
    if shared_results:
        sp = report["shared_prefix"]
        # prefix sharing must never change emitted tokens...
        if not (outputs["shared/fused"] == outputs["shared/paged"]
                == outputs["shared/paged_prefix"]):
            print("[bench_serving] REGRESSION: shared-prefix outputs diverged "
                  "from the dense fused engine")
            return 1
        # ...must actually hit (and skip) the shared system prompt...
        if (shared_results["paged_prefix"]["prompt_tokens_skipped"] <= 0
                or shared_results["paged_prefix"]["prefix_hit_tokens"] <= 0):
            print("[bench_serving] REGRESSION: shared-prefix scenario had a "
                  "0% prefix hit rate")
            return 1
        # ...>= 2x fewer prompt tokens prefilled than the per-slot paged
        # engine, at a strictly lower cache peak (pages stored once)
        if sp["prefill_reduction"] < 2.0:
            print(f"[bench_serving] REGRESSION: prefill reduction "
                  f"{sp['prefill_reduction']}x < 2x")
            return 1
        if (shared_results["paged_prefix"]["peak_cache_bytes"]
                >= shared_results["paged"]["peak_cache_bytes"]):
            print("[bench_serving] REGRESSION: prefix-cache peak not below "
                  "the per-slot paged peak")
            return 1
    if midpage_results:
        mp = report["midpage_divergence"]
        mp_page = midpage_results["paged_prefix_page"]
        mp_tok = midpage_results["paged_prefix_token"]
        # sub-page reuse must never change emitted tokens...
        if not (outputs["midpage/fused"] == outputs["midpage/paged_prefix_page"]
                == outputs["midpage/paged_prefix_token"]):
            print("[bench_serving] REGRESSION: mid-page-divergence outputs "
                  "diverged from the dense fused engine")
            return 1
        # ...must actually reuse tokens INSIDE the first divergent page...
        if (mp_tok["prefix_hit_tokens_partial"] <= 0
                or mp_tok["cow_partial_stitches"] <= 0):
            print("[bench_serving] REGRESSION: mid-page scenario never "
                  "stitched a partial page")
            return 1
        # ...and prefill strictly fewer prompt tokens than page-aligned
        # matching at the same page size
        if mp_tok["prompt_tokens_ingested"] >= mp_page["prompt_tokens_ingested"]:
            print("[bench_serving] REGRESSION: sub-page matching did not "
                  "reduce prompt tokens prefilled vs page-aligned")
            return 1
        if mp_page["prefix_hit_tokens_partial"] != 0:
            print("[bench_serving] REGRESSION: page-aligned engine reported "
                  "partial hits")
            return 1
    if spec_results:
        # the tentpole's hard gate: speculation must never change emitted
        # tokens — both proposers byte-identical to the plain fused engine
        if not (outputs["spec/off"] == outputs["spec/ngram"]
                == outputs["spec/draft"]):
            print("[bench_serving] REGRESSION: speculative outputs diverged "
                  "from the non-speculative engine")
            return 1
        for n in ("ngram", "draft"):
            if spec_results[n]["spec_dispatches"] <= 0:
                print(f"[bench_serving] REGRESSION: spec/{n} never ran a "
                      "verify dispatch")
                return 1
        # at least one proposer must land >= 2 tokens per verify dispatch
        # and strictly cut target dispatches per token (both counter-based
        # and deterministic, so gated in smoke too)
        best_acc = max(spec_results[n]["accepted_per_dispatch"]
                       for n in ("ngram", "draft"))
        if best_acc < 2.0:
            print(f"[bench_serving] REGRESSION: best accepted/dispatch "
                  f"{best_acc:.2f} < 2.0")
            return 1
        off_dpt = spec_results["off"]["dispatches_per_token"]
        if min(spec_results[n]["dispatches_per_token"]
               for n in ("ngram", "draft")) >= off_dpt:
            print("[bench_serving] REGRESSION: no proposer reduced "
                  "dispatches/token below the non-speculative engine")
            return 1
        # wall-clock gate only outside smoke (CI boxes are too noisy)
        best_speed = max(
            report["speculative"]["tokens_per_sec_vs_off"].values())
        if not args.smoke and best_speed < 1.5:
            print(f"[bench_serving] REGRESSION: best speculative tokens/sec "
                  f"{best_speed:.2f}x off (< 1.5)")
            return 1
    if staggered_results:
        # scheduling must never change tokens: both policies draw from the
        # same submit-order sampling streams
        if outputs["staggered/continuous"] != outputs["staggered/drain"]:
            print("[bench_serving] REGRESSION: refill policy changed emitted "
                  "tokens")
            return 1
        # the point of continuous batching: staggered arrivals reach their
        # first token strictly sooner than under drain-then-refill
        if (staggered_results["continuous"]["mean_ttft_ticks"]
                >= staggered_results["drain"]["mean_ttft_ticks"]):
            print("[bench_serving] REGRESSION: continuous batching did not "
                  "beat drain-then-refill mean TTFT")
            return 1
    if churn_results:
        for name in ("static", "autoscaled"):
            r = churn_results[name]
            # the robustness tentpole's hard gates: a revocation drill
            # must lose NOTHING and change NOTHING
            if r["lost_requests"] != 0 or not r["byte_identical"]:
                print(f"[bench_serving] REGRESSION: churn/{name} lost "
                      f"{r['lost_requests']} request(s) or diverged from "
                      "the undisturbed run")
                return 1
            if r["revocations_injected"] < 2:
                print(f"[bench_serving] REGRESSION: churn/{name} injected "
                      f"only {r['revocations_injected']} revocation(s)")
                return 1
        # survivors/replacements must warm up from the cross-host prefix
        # store, not re-prefill (that is what makes churn cheap)
        if churn_results["autoscaled"]["prefix_store_pages_hydrated"] <= 0:
            print("[bench_serving] REGRESSION: no prefix-store hydration "
                  "on post-revocation reruns")
            return 1
        # and the autoscaler's reason to exist: the spike's p99
        # turnaround must beat the static fleet's
        if (churn_results["autoscaled"]["p99_ttft_s"]
                >= churn_results["static"]["p99_ttft_s"]):
            print("[bench_serving] REGRESSION: autoscaled fleet did not "
                  "beat the static fleet's p99 turnaround under churn")
            return 1
    if recovery_results:
        for name in ("replay", "checkpoint", "sabotage"):
            r = recovery_results[name]
            # same hard gates as churn: revocations + flaky storage must
            # lose NOTHING and change NOTHING, whichever rung of the
            # fallback ladder the fleet lands on
            if r["lost_requests"] != 0 or not r["byte_identical"]:
                print(f"[bench_serving] REGRESSION: recovery/{name} lost "
                      f"{r['lost_requests']} request(s) or diverged from "
                      "the undisturbed run")
                return 1
            if r["revocations_injected"] < 2:
                print(f"[bench_serving] REGRESSION: recovery/{name} injected "
                      f"only {r['revocations_injected']} revocation(s)")
                return 1
            # the flaky windows must actually have injected faults the
            # retry/backoff discipline then survived
            if r["storage_faults"] <= 0 or r["queue_faults"] <= 0:
                print(f"[bench_serving] REGRESSION: recovery/{name} saw no "
                      f"injected storage ({r['storage_faults']}) or queue "
                      f"({r['queue_faults']}) faults")
                return 1
        rr = recovery_results["replay"]
        rc = recovery_results["checkpoint"]
        rs = recovery_results["sabotage"]
        # the baseline must really be checkpoint-free and really have had
        # decode progress to lose, or the comparison is vacuous
        if rr["checkpoints_published"] != 0 or rr["tokens_recovered"] != 0:
            print("[bench_serving] REGRESSION: recovery/replay leg wrote "
                  "checkpoints despite generation_checkpoints=false")
            return 1
        if rr["tokens_redecoded"] <= 0:
            print("[bench_serving] REGRESSION: recovery drill never "
                  "interrupted a generation mid-decode")
            return 1
        # the tentpole's payoff: checkpointed drains hand their emitted
        # tail to the resuming worker instead of re-decoding it
        if (rc["checkpoints_published"] <= 0 or rc["checkpoint_resumes"] <= 0
                or rc["tokens_recovered"] <= 0):
            print("[bench_serving] REGRESSION: recovery/checkpoint leg never "
                  "resumed from a generation checkpoint")
            return 1
        if report["recovery_drill"]["redecode_reduction"] < 3.0:
            print(f"[bench_serving] REGRESSION: re-decode reduction "
                  f"{report['recovery_drill']['redecode_reduction']}x < 3x")
            return 1
        # fallback ladder: with every checkpoint unreadable the fleet must
        # degrade to full replay (counted), never resume from a checkpoint,
        # and still change nothing
        if rs["checkpoint_fallbacks"] <= 0 or rs["checkpoint_resumes"] != 0:
            print("[bench_serving] REGRESSION: recovery/sabotage leg did not "
                  "walk the checkpoint fallback ladder "
                  f"(fallbacks={rs['checkpoint_fallbacks']}, "
                  f"resumes={rs['checkpoint_resumes']})")
            return 1
    if disagg_results:
        dg_mono = disagg_results["monolith"]
        dg_split = disagg_results["split"]
        for name in ("monolith", "split"):
            r = disagg_results[name]
            # the hard gates: a fleet topology change must lose NOTHING
            # and change NOTHING, and every queue must drain clean
            if r["lost_requests"] != 0 or not r["byte_identical"]:
                print(f"[bench_serving] REGRESSION: disagg/{name} lost "
                      f"{r['lost_requests']} request(s) or diverged from "
                      "the undisturbed run")
                return 1
            if r["dead_letters"] != 0:
                print(f"[bench_serving] REGRESSION: disagg/{name} left "
                      f"{r['dead_letters']} dead-lettered message(s)")
                return 1
        # every request must travel the storage-mediated handoff path —
        # published once, admitted once, no replay fallbacks needed on a
        # healthy store, no seal rejects
        dg_n = disagg_scenario["n_requests"]
        if not (dg_split["handoffs_published"] == dg_split["handoffs_admitted"]
                == dg_n):
            print(f"[bench_serving] REGRESSION: disagg/split handoffs "
                  f"published={dg_split['handoffs_published']} "
                  f"admitted={dg_split['handoffs_admitted']} != {dg_n}")
            return 1
        if dg_split["handoff_fallbacks"] != 0 or dg_split["handoff_seal_rejects"] != 0:
            print(f"[bench_serving] REGRESSION: disagg/split walked the "
                  f"replay ladder on a healthy store "
                  f"(fallbacks={dg_split['handoff_fallbacks']}, "
                  f"seal_rejects={dg_split['handoff_seal_rejects']})")
            return 1
        if dg_mono["handoffs_published"] != 0:
            print("[bench_serving] REGRESSION: disagg/monolith published "
                  "handoff records from unified workers")
            return 1
        # role purity: the prefill pool never decodes a token
        dg_pre = dg_split["roles"]["prefill"]
        if dg_pre["tokens_emitted"] != 0 or dg_pre["decode_dispatches"] != 0:
            print(f"[bench_serving] REGRESSION: disagg prefill pool decoded "
                  f"(tokens={dg_pre['tokens_emitted']}, "
                  f"decode_dispatches={dg_pre['decode_dispatches']})")
            return 1
        # the decode pool must really hydrate its KV from the store, not
        # re-prefill the prompts the prefill pool already processed
        if (dg_split["prefix_store_pages_hydrated"] <= 0
                or dg_split["hydration_fetch_ops"] <= 0
                or dg_split["prefix_store_bytes_fetched"] <= 0):
            print("[bench_serving] REGRESSION: disagg decode pool never "
                  "hydrated from the prefix store")
            return 1
        # the payoff, both counter-derived and deterministic: decode-side
        # p99 TTFT and tokens-per-tick strictly beat the monolith
        if dg_split["ttft_ticks_p99"] >= dg_mono["ttft_ticks_p99"]:
            print(f"[bench_serving] REGRESSION: disagg decode p99 TTFT "
                  f"{dg_split['ttft_ticks_p99']:.1f} ticks not below "
                  f"monolith {dg_mono['ttft_ticks_p99']:.1f}")
            return 1
        if dg_split["tokens_per_tick"] <= dg_mono["tokens_per_tick"]:
            print(f"[bench_serving] REGRESSION: disagg decode tokens/tick "
                  f"{dg_split['tokens_per_tick']:.3f} not above monolith "
                  f"{dg_mono['tokens_per_tick']:.3f}")
            return 1
        # margin gate only outside smoke (the full workload is big enough
        # to demand a real win, not a tie-breaker)
        dg_ratio = report["disaggregation"]["decode_ttft_p99_reduction"]
        if not args.smoke and dg_ratio < 1.3:
            print(f"[bench_serving] REGRESSION: disagg decode TTFT reduction "
                  f"{dg_ratio}x < 1.3x")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Validate ``BENCH_serving.json`` and its tier-1 coverage.

Two checks, both cheap enough to run inside the test suite:

1. **Schema** — the report has every scenario block the benchmark is
   supposed to produce, and every engine entry inside each block carries
   the metric and counter keys downstream tooling (dashboards, the
   README tables, regression diffs) reads.  A bench refactor that drops
   or renames a field fails here instead of silently publishing an
   incomplete report.
2. **Coverage** — every scenario block in the report is referenced by
   name in ``tests/test_bench_serving.py``, so no scenario can be added
   to the benchmark without a tier-1 smoke assertion gating it.

Run standalone against a written report::

    PYTHONPATH=src python benchmarks/check_bench.py BENCH_serving.json

or import :func:`check_report` / :func:`check_test_coverage` (the smoke
test does both on the report it just generated).
"""

from __future__ import annotations

import json
import os
import sys
from typing import List

# every run_engine() result must carry these (the counter block mirrors
# bench_serving._COUNTERS plus the derived rates)
ENGINE_KEYS = (
    "wall_s", "tokens_per_sec", "dispatches_per_token",
    "accepted_per_dispatch", "prompt_tokens_per_prefill_dispatch",
    "decode_dispatches", "prefill_dispatches", "dispatches",
    "tokens_emitted", "prompt_tokens_ingested", "prompt_tokens_skipped",
    "prefix_hit_tokens", "prefix_hit_tokens_partial",
    "cow_partial_stitches",
    "spec_dispatches", "draft_dispatches",
    "draft_tokens_proposed", "draft_tokens_accepted", "spec_tokens_emitted",
    "timing", "cache_mode",
)
# staggered runs go through run_staggered(), which reports scheduling
# latency rather than the dispatch-counter block
STAGGERED_KEYS = ("refill_policy", "wall_s", "ticks", "dispatches",
                  "tokens_emitted", "timing", "mean_ttft_ticks")
# the churn drill reports fleet-level robustness facts (virtual-time
# throughput/latency, chaos accounting, recovery counters) rather than
# single-engine dispatch counters
CHURN_KEYS = ("sim_seconds", "tokens_per_sim_s", "p99_ttft_s",
              "lost_requests", "revocations_injected", "requests_requeued",
              "requests_resumed", "prefix_store_pages_hydrated",
              "byte_identical", "workers_peak")
# the recovery drill adds the work-preserving-recovery books on top of
# the fleet-robustness facts: generation-checkpoint activity, the
# re-decode accounting the headline ratio is derived from, and the
# injected-fault counts proving the flaky windows actually fired
RECOVERY_KEYS = CHURN_KEYS + (
    "checkpoints_published", "checkpoint_resumes", "tokens_recovered",
    "checkpoint_fallbacks", "decode_tokens_discarded", "tokens_redecoded",
    "publish_retries", "prefix_store_hash_mismatches",
    "storage_faults", "queue_faults",
)
# the disaggregation drill reports per-leg serving-side latency and
# throughput (engine-tick derived, deterministic), the storage-mediated
# handoff books, and the decode pool's hydration accounting
DISAGG_KEYS = (
    "sim_seconds", "tokens_per_sim_s", "p99_turnaround_s",
    "lost_requests", "dead_letters", "workers_peak", "ticks",
    "ttft_ticks_p99", "tokens_per_tick",
    "prompt_tokens_ingested_serving_side",
    "prefix_store_pages_hydrated", "hydration_fetch_ops",
    "prefix_store_bytes_fetched",
    "handoffs_published", "handoffs_admitted",
    "handoff_fallbacks", "handoff_seal_rejects",
    "publish_dedup_hits", "roles", "byte_identical",
)

# Keys the schema requires that are NOT EngineStats counters: bench- or
# fleet-level facts (wall-clock, virtual-time rates, chaos accounting,
# A/B deltas) computed by bench_serving / the drills, not by snapshot().
# dslint R4 cross-checks every schema key against EngineStats fields,
# snapshot()-derived keys, and this set — a renamed counter that leaves
# its old name in a schema tuple fails tier-1 instead of silently
# demanding a key no report can carry.  Add here ONLY keys the bench
# itself derives; counter renames must update the schema tuples.
DERIVED_KEYS = frozenset({
    # wall-clock / rate metrics (bench-level, non-deterministic)
    "wall_s", "tokens_per_sec", "dispatches_per_token",
    "prompt_tokens_per_prefill_dispatch", "timing",
    # engine-config echoes
    "cache_mode", "refill_policy", "roles",
    # staggered-run scheduling facts
    "mean_ttft_ticks", "ttft_ticks_p99", "tokens_per_tick",
    # fleet-drill virtual-time + robustness facts
    "sim_seconds", "tokens_per_sim_s", "p99_ttft_s", "p99_turnaround_s",
    "lost_requests", "dead_letters", "revocations_injected",
    "requests_requeued", "workers_peak", "byte_identical",
    "tokens_redecoded", "storage_faults", "queue_faults",
    "prompt_tokens_ingested_serving_side",
    # block-level A/B derived metrics
    "dispatch_reduction", "paged_cache_reduction", "prefill_reduction",
    "peak_reduction_vs_paged", "prefill_reduction_vs_page_aligned",
    "best_proposer", "tokens_per_sec_vs_off", "dispatch_reduction_vs_off",
    "ttft_reduction", "p99_ttft_reduction", "redecode_reduction",
    "decode_ttft_p99_reduction", "decode_tokens_per_tick_vs_monolith",
})

# scenario block -> (path to its engines dict, required engine names,
# per-engine required keys, block-level derived metrics)
SCENARIOS = {
    "engines": (("engines",), ("grouped", "fused", "paged"), ENGINE_KEYS,
                ("dispatch_reduction", "paged_cache_reduction")),
    "shared_prefix": (("shared_prefix", "engines"),
                      ("fused", "paged", "paged_prefix"), ENGINE_KEYS,
                      ("prefill_reduction", "peak_reduction_vs_paged")),
    "midpage_divergence": (("midpage_divergence", "engines"),
                           ("fused", "paged_prefix_page",
                            "paged_prefix_token"), ENGINE_KEYS,
                           ("prefill_reduction_vs_page_aligned",)),
    "speculative": (("speculative", "engines"),
                    ("off", "ngram", "draft"), ENGINE_KEYS,
                    ("best_proposer", "tokens_per_sec_vs_off",
                     "dispatch_reduction_vs_off")),
    "continuous_batching": (("continuous_batching", "engines"),
                            ("continuous", "drain"), STAGGERED_KEYS,
                            ("ttft_reduction",)),
    "elastic_churn": (("elastic_churn", "engines"),
                      ("static", "autoscaled"), CHURN_KEYS,
                      ("p99_ttft_reduction",)),
    "recovery_drill": (("recovery_drill", "engines"),
                       ("replay", "checkpoint", "sabotage"), RECOVERY_KEYS,
                       ("redecode_reduction",)),
    "disaggregation": (("disaggregation", "engines"),
                       ("monolith", "split"), DISAGG_KEYS,
                       ("decode_ttft_p99_reduction",
                        "decode_tokens_per_tick_vs_monolith")),
}


def _dig(report: dict, path) -> dict:
    node = report
    for key in path:
        if not isinstance(node, dict) or key not in node:
            raise KeyError("/".join(path))
        node = node[key]
    return node


def check_report(report: dict) -> List[str]:
    """Return a list of schema problems (empty = valid)."""
    problems = []
    for key in ("arch", "smoke", "scenario"):
        if key not in report:
            problems.append(f"missing top-level key {key!r}")
    for name, (path, engines, engine_keys, derived) in SCENARIOS.items():
        try:
            block = _dig(report, path)
        except KeyError as e:
            problems.append(f"scenario {name!r}: missing {e.args[0]}")
            continue
        parent = _dig(report, path[:-1]) if len(path) > 1 else report
        for metric in derived:
            if metric not in parent:
                problems.append(f"scenario {name!r}: missing derived "
                                f"metric {metric!r}")
        if len(path) > 1 and "scenario" not in parent:
            problems.append(f"scenario {name!r}: missing its config dict")
        for eng in engines:
            if eng not in block:
                problems.append(f"scenario {name!r}: missing engine {eng!r}")
                continue
            for k in engine_keys:
                if k not in block[eng]:
                    problems.append(
                        f"scenario {name!r} engine {eng!r}: missing {k!r}")
            if "outputs" in block[eng]:
                problems.append(
                    f"scenario {name!r} engine {eng!r}: raw per-request "
                    "outputs belong in the gates, not the written report")
    return problems


def check_test_coverage(test_source: str) -> List[str]:
    """Every scenario block must appear (quoted) in the smoke test."""
    return [
        f"scenario {name!r} has no tier-1 smoke assertion referencing it"
        for name in SCENARIOS
        if f'"{name}"' not in test_source and f"'{name}'" not in test_source
    ]


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else "BENCH_serving.json"
    with open(path) as f:
        report = json.load(f)
    problems = check_report(report)
    test_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "..", "tests", "test_bench_serving.py")
    with open(test_path) as f:
        problems += check_test_coverage(f.read())
    for p in problems:
        print(f"[check_bench] {p}")
    print(f"[check_bench] {path}: "
          + ("OK" if not problems else f"{len(problems)} problem(s)"))
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())

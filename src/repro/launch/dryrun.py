import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every applicable (architecture x input shape) cell this script
lowers + compiles the production step on

  - the single-pod mesh  (16, 16)    ("data", "model")   = 256 chips
  - the multi-pod mesh   (2, 16, 16) ("pod", "data", "model") = 512 chips

records ``compiled.memory_analysis()`` (does it fit 16 GiB/chip?) and
``compiled.cost_analysis()``, and (optionally) runs the roofline probes
(see repro.roofline.analysis for the methodology).

The serving engine's hot paths are cells here too and lower with
``--all`` (or ``--shape serve_prefill_32k`` / ``--shape
serve_ragged_32k`` / ``--shape serve_paged_32k``): fused chunked
prefill (``Model.prefill_chunk`` writing the sharded decode cache in
one dispatch), ragged continuous-batching decode (per-row position
vector ``[B]`` — the single dispatch ``ServeEngine.step`` issues per
tick), and the same ragged decode against the PAGED cache (a shared
page pool at half the dense reservation, sharded over 'model' on the
pool dim, plus the replicated per-slot page table).

The two lines at the very top of this file run BEFORE any jax import so
the host platform exposes 512 placeholder devices; nothing here allocates
device memory (ShapeDtypeStruct stand-ins only).

Usage:
    python -m repro.launch.dryrun --all
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --arch mixtral-8x7b --roofline
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax

from repro.configs import SHAPES, cell_applicable, get_arch, list_archs
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import TPU_V5E, make_production_mesh, n_chips
from repro.roofline.analysis import (
    CollectiveStats,
    ProbeCost,
    RooflineResult,
    collective_bytes,
    extrapolate,
    model_flops,
)
from repro.roofline.hbm import hbm_traffic

GiB = 1024**3


def _analytic_arg_bytes(cell, mesh) -> int:
    """Exact per-device bytes of all step inputs (params/opt/cache/batch)
    from declared dtypes + shardings — immune to CPU bf16 emulation."""
    import numpy as np
    from repro.sharding.rules import axis_size

    total = 0
    for arg, sharding in zip(cell.args, cell.in_shardings):
        leaves = jax.tree.leaves(arg)
        shards = jax.tree.leaves(sharding, is_leaf=lambda x: hasattr(x, "spec"))
        if len(shards) == 1 and len(leaves) > 1:
            shards = shards * len(leaves)
        for leaf, sh in zip(leaves, shards):
            spec = tuple(sh.spec) if hasattr(sh, "spec") else ()
            spec = spec + (None,) * (len(leaf.shape) - len(spec))
            n = 1
            for d, ax in zip(leaf.shape, spec):
                n *= -(-d // axis_size(mesh, ax))
            total += n * np.dtype(leaf.dtype).itemsize
    return total


def probe_layer_pair(cfg) -> Tuple[int, Optional[int]]:
    """Reduced depths for the unrolled differencing probes."""
    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        return e, 2 * e
    if cfg.family == "moe" and cfg.first_k_dense:
        return cfg.first_k_dense + 1, cfg.first_k_dense + 3
    if cfg.n_layers <= 6:
        return cfg.n_layers, None  # small enough: unroll exactly
    return 2, 4


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, verbose: bool = True) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": reason,
        }
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    lowered = lower_cell(cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    per_dev = (
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes
    )
    fits = per_dev <= TPU_V5E["hbm_bytes"]
    # analytic state bytes (exact, from the input trees' declared dtypes and
    # shardings) + emulation-corrected temp: the CPU backend upcasts bf16
    # compute to f32, roughly doubling temp vs the TPU lowering.
    state_bytes = _analytic_arg_bytes(cell, mesh)
    # alias credit: donated outputs (cache/params) are updated in place on
    # TPU; the CPU emulation materializes an extra converted copy in temp.
    projected_temp = max(0.0, ma.temp_size_in_bytes / 2 - ma.alias_size_in_bytes)
    projected = state_bytes + projected_temp
    fits_projected = projected <= TPU_V5E["hbm_bytes"]
    # collectives visible in the top-level module (scan bodies parsed too —
    # presence proves the pod axis shards; bytes come from roofline probes)
    txt = compiled.as_text()
    colls = collective_bytes(txt, n_chips(mesh))
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "policy": {
            "fsdp_axes": list(cell.policy.fsdp_axes),
            "tp_axis": cell.policy.tp_axis,
        },
        "microbatches": cell.microbatches,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_gib": ma.argument_size_in_bytes / GiB,
            "output_gib": ma.output_size_in_bytes / GiB,
            "temp_gib": ma.temp_size_in_bytes / GiB,
            "alias_gib": ma.alias_size_in_bytes / GiB,
            "per_device_gib": per_dev / GiB,
            "fits_16gib": fits,
            "state_gib_analytic": state_bytes / GiB,
            "projected_tpu_gib": projected / GiB,
            "fits_16gib_projected": fits_projected,
        },
        "entry_cost": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collective_counts": colls.count_by_op,
    }
    if verbose:
        print(
            f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:8s} "
            f"compile={t2 - t1:6.1f}s mem/dev={per_dev / GiB:7.2f}GiB "
            f"proj={projected / GiB:6.2f}GiB fits={'Y' if fits_projected else 'N'} "
            f"colls={sum(colls.count_by_op.values())}"
        )
    return result


def run_probe(arch: str, shape_name: str, layers: int, policy) -> ProbeCost:
    mesh = make_production_mesh(multi_pod=False)
    cfg = dataclasses.replace(get_arch(arch), n_layers=layers)
    cell = build_cell(
        arch,
        shape_name,
        mesh,
        cfg_override=cfg,
        attn_impl="direct",
        unroll_layers=True,
        microbatches=1,
        policy=policy,
    )
    lowered = lower_cell(cell, mesh)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = collective_bytes(txt, n_chips(mesh))
    hbm = hbm_traffic(txt)
    if hbm.has_while:
        print(f"  [warn] probe {arch}/{shape_name} L={layers} still contains a while loop")
    return ProbeCost(
        flops=float(ca.get("flops", 0.0)),
        bytes=hbm.bytes_flash,
        collectives=colls,
        bytes_jnp=hbm.bytes_jnp,
        quadratic_bytes=hbm.quadratic_bytes,
    )


def run_roofline(arch: str, shape_name: str, *, verbose: bool = True) -> Dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=False)
    # reuse the production cell's sharding policy for the probes
    cell = build_cell(arch, shape_name, mesh)
    l1, l2 = probe_layer_pair(cfg)
    c1 = run_probe(arch, shape_name, l1, cell.policy)
    if l2 is None:
        flops, bytes_, bytes_jnp, coll = c1.flops, c1.bytes, c1.bytes_jnp, c1.collectives
        pair = (l1, l1)
    else:
        c2 = run_probe(arch, shape_name, l2, cell.policy)
        flops, bytes_, bytes_jnp, coll = extrapolate(c1, c2, l1, l2, cfg.n_layers)
        pair = (l1, l2)
    rr = RooflineResult(
        arch=arch,
        shape=shape_name,
        n_layers=cfg.n_layers,
        probe_layers=pair,
        flops=flops,
        bytes=bytes_,
        bytes_jnp=bytes_jnp,
        collective=coll,
        model_flops_global=model_flops(cfg, shape),
        n_devices=n_chips(mesh),
    )
    out = {"status": "ok", **rr.to_json()}
    if verbose:
        print(
            f"[roofline] {arch:18s} {shape_name:12s} "
            f"compute={rr.compute_s * 1e3:9.3f}ms memory={rr.memory_s * 1e3:9.3f}ms "
            f"coll={rr.collective_s * 1e3:9.3f}ms dom={rr.dominant:10s} "
            f"useful={rr.useful_ratio:5.2f} frac={rr.roofline_fraction:5.2f}"
        )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--roofline", action="store_true", help="also run roofline probes")
    ap.add_argument("--roofline-only", action="store_true")
    ap.add_argument("--out", default="experiments")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else [a for a in list_archs() if a != "ds-paper-100m"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(os.path.join(args.out, "dryrun"), exist_ok=True)
    os.makedirs(os.path.join(args.out, "roofline"), exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            if not args.roofline_only:
                meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
                for multi in meshes:
                    mesh_name = "2x16x16" if multi else "16x16"
                    path = os.path.join(
                        args.out, "dryrun", f"{arch}__{shape}__{mesh_name}.json"
                    )
                    try:
                        res = run_cell(arch, shape, multi)
                    except Exception as e:  # noqa: BLE001
                        res = {
                            "arch": arch, "shape": shape, "mesh": mesh_name,
                            "status": "error", "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc(limit=8),
                        }
                        failures.append((arch, shape, mesh_name, str(e)))
                        print(f"[dryrun] {arch} {shape} {mesh_name} FAILED: {e}")
                    with open(path, "w") as f:
                        json.dump(res, f, indent=2)
            if args.roofline or args.roofline_only:
                path = os.path.join(args.out, "roofline", f"{arch}__{shape}.json")
                try:
                    res = run_roofline(arch, shape)
                except Exception as e:  # noqa: BLE001
                    res = {
                        "arch": arch, "shape": shape, "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc(limit=8),
                    }
                    failures.append((arch, shape, "roofline", str(e)))
                    print(f"[roofline] {arch} {shape} FAILED: {e}")
                with open(path, "w") as f:
                    json.dump(res, f, indent=2)

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nall requested cells passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

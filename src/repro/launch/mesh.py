"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; smoke tests and benchmarks see the 1 real CPU device.

Target hardware: TPU v5e, 256 chips/pod.
  peak bf16:   197 TFLOP/s / chip
  HBM:         16 GiB @ 819 GB/s / chip
  ICI:         ~50 GB/s / link
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh

TPU_V5E = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bytes": 16 * 1024**3,
    "hbm_bw": 819e9,  # B/s per chip
    "ici_bw": 50e9,  # B/s per link
}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over host devices for distribution tests."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def n_chips(mesh: Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n

"""Worker host: the process that plays the EC2 spot fleet + ECS placement.

Spawned (detached) by ``run_ds startCluster``; builds the DSRuntime over
the shared on-disk queue/store, registers the payload "Somethings", runs
the ThreadRunner until the queue drains, then tears down and exports logs
— the automatic actions of the paper's Step 3/4.
"""

from __future__ import annotations

import argparse
import os

# register the payload Somethings
import repro.launch.serve  # noqa: F401
import repro.launch.train  # noqa: F401
from repro.core import DSRuntime, FleetFile, ThreadRunner
from repro.core.config import load_config, load_fleet_file


def run_worker_host(workdir: str) -> int:
    cfg = load_config(os.path.join(workdir, "config.json"))
    fleet_path = os.path.join(workdir, "fleet.json")
    ff = load_fleet_file(fleet_path) if os.path.exists(fleet_path) else FleetFile()

    rt = DSRuntime(cfg, store_root=os.path.join(workdir, "store"))
    rt.setup()  # reattaches to the existing sqlite queue (same path)
    rt.start_cluster(ff)
    runner = ThreadRunner(rt)
    summary = runner.run()
    rt.store.put_json(
        f"summary/{cfg.app_name}.json",
        {
            "jobs_done": summary.jobs_done,
            "jobs_skipped": summary.jobs_skipped,
            "jobs_failed": summary.jobs_failed,
            "idle_terminations": summary.idle_terminations,
            "wall_time": summary.wall_time,
        },
    )
    pid_file = os.path.join(workdir, "worker_host.pid")
    if os.path.exists(pid_file):
        os.unlink(pid_file)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", required=True)
    args = ap.parse_args(argv)
    return run_worker_host(args.workdir)


if __name__ == "__main__":
    raise SystemExit(main())

"""Cell construction shared by the dry-run and the roofline harness.

A *cell* = (architecture x input shape x mesh).  For each cell this
module provides:

- ``input_specs``      — ShapeDtypeStruct stand-ins for every input
                         (weak-type-correct, shardable, no allocation);
- ``build_step``       — the jit-able step function (train / prefill /
                         decode) with logical-axis rules bound;
- ``shardings``        — in_shardings pytrees matched to the step inputs.

Decode cells lower ``serve_step`` (one new token against a seq_len KV
cache); ``long_500k`` additionally shards the cache sequence dim over
every mesh axis (context parallelism, DESIGN §3).  The serving-engine
hot paths lower as their own cells: ``serve_prefill_*`` (fused chunked
prefill, ``Model.prefill_chunk``) and ``serve_ragged_*`` (vectorized
per-row-position decode — the engine's one-dispatch-per-tick step).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, cell_applicable
from repro.models import Model, ModelRuntime
from repro.sharding.logical import axis_rules, train_rules
from repro.sharding.rules import (
    ShardingPolicy,
    axis_size,
    bytes_per_device,
    choose_policy,
    param_specs,
)
from repro.train.optimizer import AdamWConfig, Schedule, init_opt_state, opt_state_specs
from repro.train.steps import TrainStepConfig, make_train_step


# tokens ingested per row per serve_prefill dispatch (chunked prefill)
SERVE_PREFILL_CHUNK = 512

# serve_paged cell: page size (MXU-aligned) and pool fraction of the dense
# reservation — the cell exists to prove the paged decode step lowers with
# a pool strictly smaller than batch * max_len
SERVE_PAGE_SIZE = 128
SERVE_PAGED_POOL_FRACTION = 0.5


@dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    cfg: ArchConfig
    model: Model
    step_fn: Callable
    args: Tuple  # ShapeDtypeStructs
    in_shardings: Tuple
    donate: Tuple[int, ...]
    rules: Dict
    policy: ShardingPolicy
    microbatches: int


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def decode_cell_rules(mesh: Mesh, shape: ShapeSpec) -> Dict:
    """Decode rule sets (DESIGN §3): KV cache seq over 'model'
    (flash-decode); long-context additionally over the dp axes."""
    multi = "pod" in mesh.shape
    r = train_rules(multi)
    if shape.name == "long_500k":
        r["kv_seq"] = tuple(dp_axes(mesh)) + ("model",)
        r["batch"] = None  # batch=1
        r["cache_batch"] = None
        r["heads"] = None
        r["kv_heads"] = None
        return r
    # flash-decode: cache seq over 'model'; heads/kv-heads must then
    # stay unsharded (a spec may use each mesh axis only once)
    r["kv_seq"] = "model"
    # paged cache: the page POOL dim shards over 'model' (pages are
    # unordered, the table indirection restores logical order per row)
    r["kv_pages"] = "model"
    r["kv_heads"] = None
    r["heads"] = None
    # decode reshards ACTIVATIONS, not weights (§Perf iter 3.2/3.3): the
    # FFN inputs are constrained to the 'data'-sharded hidden dim
    # ("act_embed" rule) so x @ W contracts over a sharded dim and lowers
    # to partial-matmul + psum of small activations instead of
    # all-gathering FSDP weight shards every token step.  Applying the
    # same to the whole residual stream (iter 3.2) made GSPMD gather the
    # batch-replicated cache — refuted; FFN-only keeps the cache layout.
    r["act_embed"] = "data"
    r["act_heads"] = "model"  # wo contraction over its 'model'-sharded dim
    r["act_batch"] = None  # these activations replicate batch while the
    #                        mesh axes carry their contraction dims
    # the residual stream itself lives d-sharded over 'data' at decode, so
    # row-parallel outputs (wo, FFN down-proj) keep their 'data'-sharded
    # output dim instead of forcing a weight gather (§Perf iter 3.5); the
    # KV cache keeps batch over 'data' via "cache_batch" (cf. refuted 3.2)
    r["embed"] = "data"
    r["batch"] = None
    return r


def pick_microbatches(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """Keep ~128k global tokens per microbatch for train cells."""
    if shape.kind != "train":
        return 1
    tokens = shape.seq_len * shape.global_batch
    mb = max(1, tokens // 131_072)
    while shape.global_batch % mb:
        mb -= 1
    return mb


def auto_train_knobs(cfg: ArchConfig) -> Dict[str, Any]:
    """Size-adaptive production defaults (§Perf iterations 1.1-1.2):
    big models get 8-bit Adam moments, bf16 gradient accumulation and
    sqrt-segmented remat; small models keep plain fp32 state."""
    big = cfg.param_count() >= 30e9
    seg = 0
    if cfg.n_layers >= 24 and cfg.family in ("dense", "moe", "vlm"):
        target = max(2, int(round(cfg.n_layers ** 0.5)))
        layers = cfg.n_layers - (cfg.first_k_dense if cfg.family == "moe" else 0)
        for k in range(target, 1, -1):
            if layers % k == 0:
                seg = k
                break
    return {
        "moments_dtype": "int8" if big else "f32",
        "accum_dtype": "bf16" if big else "f32",
        "remat_segment": seg if big else 0,
    }


def make_opt_config(
    cfg: ArchConfig, *, moments_dtype: str = "f32", master_fp32: bool = True
) -> AdamWConfig:
    return AdamWConfig(
        schedule=Schedule(peak_lr=3e-4, warmup_steps=100, total_steps=10_000),
        moments_dtype=moments_dtype,
        master_fp32=master_fp32,  # bf16 params (+ fp32 master by default)
    )


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> Tuple[Dict, Dict]:
    """(ShapeDtypeStructs, PartitionSpecs) for a training batch."""
    b, s = shape.global_batch, shape.seq_len
    dp = dp_axes(mesh)
    structs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    specs: Dict[str, Any] = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.is_encoder_decoder:
        structs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        specs["frames"] = P(dp, None, None)
    if cfg.n_vision_tokens:
        structs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
        specs["patches"] = P(dp, None, None)
    return structs, specs


def _spec_tree_to_shardings(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def cache_specs(cfg: ArchConfig, cache_shapes, rules, mesh) -> Any:
    """PartitionSpecs for the decode cache from the logical rule set."""
    from repro.sharding.rules import axis_size

    def leaf_spec(path, leaf):
        # cache layouts (see Model.init_cache):
        #   k/v:            (L, B, T, Hkv, hd)   logical (None,batch,kv_seq,kv_heads,None)
        #   c_kv/k_rope:    (L, B, T, r)         (None,batch,kv_seq,None)
        #   cross_k/v:      (L, B, Tenc, H, hd)  (None,batch,None,kv_heads,None)
        #   shared_k/v:     (I, B, T, H, hd)
        #   state.conv_*:   (L, B, k-1, C)       (None,batch,None,ssm_inner?)
        #   state.ssm:      (L, B, h, p, n)      (None,batch,ssm_heads,None,None)
        name = path[-1] if path else ""
        logical: Tuple[Optional[str], ...]
        if name in ("k_pages", "v_pages"):
            #   k_pages/v_pages: (L, n_pages, ps, Hkv, hd)
            logical = (None, "kv_pages", None, "kv_heads", None)
        elif name == "kv_pages":
            #   MLA pool: (L, n_pages, ps, r+qr)
            logical = (None, "kv_pages", None, None)
        elif name == "page_table":
            logical = (None, None)  # tiny, replicated
        elif name in ("k", "v", "shared_k", "shared_v"):
            logical = (None, "cache_batch", "kv_seq", "kv_heads", None)
        elif name in ("cross_k", "cross_v"):
            logical = (None, "cache_batch", None, "kv_heads", None)
        elif name in ("c_kv", "k_rope"):
            logical = (None, "cache_batch", "kv_seq", None)
        elif name in ("conv_x",):
            logical = (None, "cache_batch", None, "ssm_inner")
        elif name in ("conv_B", "conv_C"):
            logical = (None, "cache_batch", None, None)
        elif name == "ssm":
            logical = (None, "cache_batch", "ssm_heads", None, None)
        else:
            logical = tuple(None for _ in leaf.shape)
        axes = []
        for dim, lg in zip(leaf.shape, logical):
            mesh_axes = rules.get(lg) if lg else None
            if mesh_axes is None:
                axes.append(None)
                continue
            size = axis_size(mesh, mesh_axes)
            axes.append(mesh_axes if (size <= dim and dim % size == 0) else None)
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for kp, leaf in flat:
        path = tuple(k.key if hasattr(k, "key") else str(k) for k in kp)
        out.append(leaf_spec(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    cfg_override: Optional[ArchConfig] = None,
    moments_dtype: str = "f32",
    master_fp32: bool = True,
    accum_dtype: str = "f32",
    remat_segment: int = 0,
    attn_impl: str = "auto",
    remat: bool = True,
    unroll_layers: bool = False,
    microbatches: Optional[int] = None,
    policy: Optional[ShardingPolicy] = None,
    logit_dtype=jnp.float32,
    sequence_parallel: bool = False,
) -> Cell:
    cfg = cfg_override or get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({arch},{shape_name}) not applicable: {reason}")

    if shape.kind == "train" and not unroll_layers:
        auto = auto_train_knobs(cfg)
        if moments_dtype == "f32":
            moments_dtype = auto["moments_dtype"]
        if accum_dtype == "f32":
            accum_dtype = auto["accum_dtype"]
        if remat_segment == 0:
            remat_segment = auto["remat_segment"]

    multi = "pod" in mesh.shape
    rt = ModelRuntime(
        dtype=jnp.bfloat16,
        attn_impl=attn_impl,
        remat=remat and shape.kind == "train",
        remat_segment=remat_segment,
        unroll_layers=unroll_layers,
        logit_dtype=logit_dtype,
        # shard_map EP is the production MoE path (roofline probes compile
        # it unrolled at full width/mesh in seconds); inside a full-depth
        # lax.scan the CPU SPMD pipeline's compile time is pathological
        # (>25 min for deepseek), so the scanned dry-run cells lower the
        # GSPMD gather path instead — same math, §Perf records both.
        moe_strategy="shardmap" if unroll_layers else "capacity",
    )
    model = Model(cfg, rt)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if policy is None:
        # train state multiplier over bf16 params: fp32 master (2x) +
        # moments (int8 ~1x / f32 4x) + accumulator (1-2x) + params (1x)
        mult = 1.0
        if shape.kind == "train":
            mult = 5.0 if moments_dtype == "int8" else 7.0
        policy = choose_policy(params_shape, mesh, multi_pod=multi, state_multiplier=mult)
    p_specs, report = param_specs(params_shape, mesh, policy)
    p_shard = _spec_tree_to_shardings(p_specs, mesh)

    if shape.kind == "train":
        rules = train_rules(multi)
        if sequence_parallel:
            rules = dict(rules, residual_seq="model")
        if attn_impl == "auto":
            # flash-style memory for training backward: chunked attention
            # never materializes (S x S) score tensors as bwd residuals
            # (the Pallas kernel's recompute behaviour, in jnp form)
            attn_impl = "chunked"
            rt = dataclasses.replace(rt, attn_impl="chunked")
            model = Model(cfg, rt)
            params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        mb = microbatches if microbatches is not None else pick_microbatches(cfg, shape)
        opt_cfg = make_opt_config(cfg, moments_dtype=moments_dtype, master_fp32=master_fp32)
        tstep = make_train_step(
            model,
            TrainStepConfig(microbatches=mb, accum_dtype=accum_dtype, opt=opt_cfg),
            grad_shardings=p_shard,
        )

        def step(params, opt_state, batch, rng):
            return tstep(params, opt_state, batch, rng)

        opt_shape = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_shape)
        o_specs = opt_state_specs(p_specs, opt_cfg)
        o_shard = _spec_tree_to_shardings(o_specs, mesh)
        b_structs, b_specs = batch_specs(cfg, shape, mesh)
        b_shard = _spec_tree_to_shardings(b_specs, mesh)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        args = (params_shape, opt_shape, b_structs, rng)
        in_shardings = (p_shard, o_shard, b_shard, NamedSharding(mesh, P()))
        donate = (0, 1)
    elif shape.kind == "prefill":
        rules = train_rules(multi)
        mb = 1

        def step(params, tokens, frames=None, patches=None):
            return model.prefill(params, tokens, frames=frames, patches=patches)

        b_structs, b_specs = batch_specs(cfg, shape, mesh)
        args = (params_shape, b_structs["tokens"])
        in_shardings = (p_shard, NamedSharding(mesh, b_specs["tokens"]))
        if cfg.is_encoder_decoder:
            args += (b_structs["frames"],)
            in_shardings += (NamedSharding(mesh, b_specs["frames"]),)
        if cfg.n_vision_tokens:
            args += (b_structs["patches"],)
            in_shardings += (NamedSharding(mesh, b_specs["patches"]),)
        step = _wrap_prefill(model, cfg)
        donate = ()
    elif shape.kind == "serve_prefill":
        # fused chunked prefill: the serving engine's prompt-ingestion
        # dispatch (SERVE_PREFILL_CHUNK tokens per row per call) writing
        # the decode cache in one shot
        rules = decode_cell_rules(mesh, shape)
        mb = 1
        b = shape.global_batch
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len, dtype=jnp.bfloat16)
        )
        c_specs = cache_specs(cfg, cache_shape, rules, mesh)
        c_shard = _spec_tree_to_shardings(c_specs, mesh)

        def step(params, cache, tokens, offsets, lengths):
            return model.prefill_chunk(params, cache, tokens, offsets, lengths)

        args = (
            params_shape,
            cache_shape,
            jax.ShapeDtypeStruct((b, SERVE_PREFILL_CHUNK), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),  # per-row start offsets
            jax.ShapeDtypeStruct((b,), jnp.int32),  # per-row valid lengths
        )
        in_shardings = (
            p_shard,
            c_shard,
            NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
        )
        donate = (1,)
    else:  # decode / serve_decode / serve_paged
        rules = decode_cell_rules(mesh, shape)
        mb = 1
        b = shape.global_batch
        if shape.kind == "serve_paged":
            # paged decode: the cell's whole point is a page pool strictly
            # smaller than the dense reservation — tokens resident, not
            # worst case.  The pool dim shards over 'model'; the page
            # table is scalar freight and stays replicated.
            pages_per_slot = shape.seq_len // SERVE_PAGE_SIZE
            n_pages = int(b * pages_per_slot * SERVE_PAGED_POOL_FRACTION)
            n_pages -= n_pages % axis_size(mesh, "model")
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(
                    b, shape.seq_len, dtype=jnp.bfloat16,
                    paged=True, page_size=SERVE_PAGE_SIZE, n_pages=n_pages,
                )
            )
        else:
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(b, shape.seq_len, dtype=jnp.bfloat16)
            )
        c_specs = cache_specs(cfg, cache_shape, rules, mesh)
        c_shard = _spec_tree_to_shardings(c_specs, mesh)
        tok_spec = P(None, None)  # tokens tiny; activations reshard per rules

        def step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        if shape.kind in ("serve_decode", "serve_paged"):
            # ragged continuous batching: per-row position vector [B] —
            # every slot advances in ONE dispatch regardless of depth mix
            pos_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
        else:
            pos_struct = jax.ShapeDtypeStruct((), jnp.int32)  # uniform (serving cells)
        args = (
            params_shape,
            cache_shape,
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            pos_struct,
        )
        in_shardings = (
            p_shard,
            c_shard,
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, P()),
        )
        donate = (1,)

    return Cell(
        arch=arch,
        shape=shape,
        cfg=cfg,
        model=model,
        step_fn=step,
        args=args,
        in_shardings=in_shardings,
        donate=donate,
        rules=rules,
        policy=policy,
        microbatches=mb,
    )


def _wrap_prefill(model: Model, cfg: ArchConfig):
    if cfg.is_encoder_decoder:
        return lambda params, tokens, frames: model.prefill(params, tokens, frames=frames)
    if cfg.n_vision_tokens:
        return lambda params, tokens, patches: model.prefill(params, tokens, patches=patches)
    return lambda params, tokens: model.prefill(params, tokens)


def lower_cell(cell: Cell, mesh: Mesh):
    """Trace + lower the cell's step under its rule context."""
    with axis_rules(mesh, cell.rules):
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate,
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(*cell.args)
    return lowered

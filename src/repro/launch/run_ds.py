"""The paper's four one-line commands.

    python -m repro.launch.run_ds setup        --workdir W --config files/config.json
    python -m repro.launch.run_ds submitJob    --workdir W files/job.json
    python -m repro.launch.run_ds startCluster --workdir W files/fleet.json
    python -m repro.launch.run_ds monitor      --workdir W [--cheapest]

State layout under ``--workdir`` (the control node's view):
    config.json                         run configuration (Step 1)
    store/                              the object store (S3 analogue)
    store/_runtime/<queue>.sqlite       the durable queue (SQS analogue)
    <APP_NAME>SpotFleetRequestId.json   written by startCluster (Step 3)

``startCluster`` spawns a detached *worker host* process (the EC2 fleet
analogue) that places workers and drains the queue; ``monitor`` polls the
queue, reports progress, and finishes when everything is drained — so the
four commands can run from separate shells, like the paper's.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.core import DSConfig, DSRuntime, DurableQueue, FleetFile, JobFile, ObjectStore
from repro.core.config import load_config, load_fleet_file
from repro.core.jobs import load_job_file


def _paths(workdir: str):
    return {
        "config": os.path.join(workdir, "config.json"),
        "store": os.path.join(workdir, "store"),
        "fleet": os.path.join(workdir, "fleet.json"),
        "pid": os.path.join(workdir, "worker_host.pid"),
    }


def _queue(cfg: DSConfig, paths) -> DurableQueue:
    qpath = os.path.join(paths["store"], "_runtime", f"{cfg.sqs_queue_name}.sqlite")
    return DurableQueue(
        qpath,
        default_visibility=cfg.sqs_message_visibility,
        max_receive_count=cfg.max_receive_count,
    )


def cmd_setup(args) -> int:
    os.makedirs(args.workdir, exist_ok=True)
    cfg = load_config(args.config) if args.config else DSConfig()
    cfg.validate()
    paths = _paths(args.workdir)
    with open(paths["config"], "w") as f:
        f.write(cfg.to_json())
    _queue(cfg, paths)  # creates queue + DLQ tables
    print(f"setup complete: app={cfg.app_name} queue={cfg.sqs_queue_name}")
    return 0


def cmd_submit(args) -> int:
    paths = _paths(args.workdir)
    cfg = load_config(paths["config"])
    jf = load_job_file(args.jobfile)
    q = _queue(cfg, paths)
    bodies = jf.expand()
    q.send_batch(bodies)
    print(f"submitted {len(bodies)} jobs to {cfg.sqs_queue_name}")
    return 0


def cmd_start_cluster(args) -> int:
    paths = _paths(args.workdir)
    cfg = load_config(paths["config"])
    ff = load_fleet_file(args.fleetfile) if args.fleetfile else FleetFile()
    with open(paths["fleet"], "w") as f:
        f.write(ff.to_json())
    store = ObjectStore(paths["store"])
    store.put_json(
        f"{cfg.app_name}SpotFleetRequestId.json",
        {"app_name": cfg.app_name, "workdir": os.path.abspath(args.workdir)},
    )
    if args.foreground:
        from repro.launch.worker_host import run_worker_host

        return run_worker_host(args.workdir)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.worker_host", "--workdir", args.workdir],
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
        start_new_session=True,
        env={**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH", "")},
    )
    with open(paths["pid"], "w") as f:
        f.write(str(proc.pid))
    print(f"spot fleet requested; worker host pid={proc.pid}")
    return 0


def cmd_monitor(args) -> int:
    paths = _paths(args.workdir)
    cfg = load_config(paths["config"])
    q = _queue(cfg, paths)
    t0 = time.time()
    while True:
        c = q.counts()
        host_alive = False
        if os.path.exists(paths["pid"]):
            pid = int(open(paths["pid"]).read().strip())
            try:
                os.kill(pid, 0)
                host_alive = True
            except OSError:
                host_alive = False
        print(
            f"[monitor t={time.time() - t0:6.1f}s] visible={c['visible']} "
            f"in_flight={c['in_flight']} dead={c['dead']} worker_host={'up' if host_alive else 'down'}"
        )
        if c["visible"] == 0 and c["in_flight"] == 0:
            print("queue drained; monitor exiting (teardown handled by worker host)")
            return 0
        if not host_alive and c["visible"] > 0:
            print("WARNING: worker host down with jobs remaining")
        time.sleep(args.poll)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="run_ds", description=__doc__)
    ap.add_argument("--workdir", default="./ds_workdir")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("setup")
    p.add_argument("--config", default=None)
    p.set_defaults(fn=cmd_setup)

    p = sub.add_parser("submitJob")
    p.add_argument("jobfile")
    p.set_defaults(fn=cmd_submit)

    p = sub.add_parser("startCluster")
    p.add_argument("fleetfile", nargs="?", default=None)
    p.add_argument("--foreground", action="store_true")
    p.set_defaults(fn=cmd_start_cluster)

    p = sub.add_parser("monitor")
    p.add_argument("--cheapest", action="store_true")
    p.add_argument("--poll", type=float, default=1.0)
    p.set_defaults(fn=cmd_monitor)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())

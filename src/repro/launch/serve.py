"""``distributed-serve`` — the serving "Something".

A job is a batch of generation requests; the worker builds the model
(from a checkpoint when ``run`` is set, fresh weights otherwise), runs
the continuous-batching engine, and writes completions to the output
prefix.  Each engine step heartbeats.
"""

from __future__ import annotations

from typing import Dict

import jax

from repro.core.worker import WorkerContext, register_payload
from repro.launch.train import build_model
from repro.serving.engine import Request, ServeEngine
from repro.train.checkpoint import latest_step, restore_checkpoint


@register_payload("distributed-serve")
def serve_payload(job: Dict, ctx: WorkerContext) -> Dict:
    model = build_model(job)
    run = job.get("run")
    if run:
        step = latest_step(ctx.store, run)
        if step is None:
            raise RuntimeError(f"no checkpoint for run {run!r}")
        like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params, _ = restore_checkpoint(ctx.store, run, step, like)
    else:
        params = model.init(jax.random.PRNGKey(job.get("init_seed", 0)))

    prompts = job["prompts"]  # list of token-id lists
    max_new = int(job.get("max_new_tokens", 8))
    cache_mode = str(job.get("cache_mode", "dense"))
    paged_kwargs = {}
    if cache_mode == "paged":
        paged_kwargs["page_size"] = int(job.get("page_size", 16))
        # omitted total_pages => the engine sizes the pool adaptively from
        # the queue depth at submit (and logs the chosen size)
        if job.get("total_pages"):
            paged_kwargs["total_pages"] = int(job["total_pages"])
        paged_kwargs["prefix_cache"] = bool(job.get("prefix_cache", True))
    stop = job.get("stop_token")
    engine = ServeEngine(
        model,
        params,
        max_batch=int(job.get("max_batch", 4)),
        max_len=int(job.get("max_len", 128)),
        prefill_chunk=int(job.get("prefill_chunk", 16)),
        dispatch_mode=str(job.get("dispatch_mode", "fused")),
        sample_on_device=bool(job.get("sample_on_device", True)),
        cache_mode=cache_mode,
        heartbeat=lambda: ctx.heartbeat(),
        **paged_kwargs,
    )
    engine.submit(
        [
            Request(uid=f"req{i}", prompt=[int(t) for t in p], max_new_tokens=max_new,
                    temperature=float(job.get("temperature", 0.0)),
                    stop_token=int(stop) if stop is not None else None)
            for i, p in enumerate(prompts)
        ]
    )
    finished = engine.run_to_completion()
    results = {
        r.uid: {"prompt": r.prompt, "completion": r.output} for r in finished
    }
    out = job.get("output_prefix", "serve/batch0")
    dispatch_stats = {
        "engine_steps": engine.steps_executed,
        "decode_dispatches": engine.decode_dispatches,
        "prefill_dispatches": engine.prefill_dispatches,
        "dispatches": engine.dispatches,
        "tokens_emitted": engine.tokens_emitted,
        "prompt_tokens_ingested": engine.prompt_tokens_ingested,
    }
    if cache_mode == "paged":
        dispatch_stats.update(
            pages_in_use_peak=engine.peak_pages,
            peak_cache_bytes=engine.peak_cache_bytes,
            dense_cache_bytes=engine.dense_cache_bytes,
            total_pages=engine.n_pages,
            prefix_hit_tokens=engine.prefix_hit_tokens,
            prompt_tokens_skipped=engine.prompt_tokens_skipped,
            pages_shared_peak=engine.pages_shared_peak,
            cow_copies=engine.cow_copies,
            prefix_evictions=engine.prefix_evictions,
            preemptions=engine.preemptions,
            tokens_discarded=engine.tokens_discarded,
        )
    ctx.store.put_json(f"{out}/RESULTS.json", {"requests": results, **dispatch_stats})
    return {"n_requests": len(finished), **dispatch_stats}

"""``distributed-serve`` — the serving "Something".

Two shapes of serving job, sharing one engine construction path:

- **static batch** (the original): the job carries ``prompts``; the
  worker builds the model, runs the continuous-batching engine over the
  batch, and writes completions + the full engine counter snapshot to
  the output prefix.  Each engine step heartbeats.
- **queue-streaming** (``request_queue`` set): the job is a *serving
  lease*, not a batch.  The worker opens the named
  :class:`~repro.core.queue.DurableQueue` of per-request messages and
  streams them into the scheduler — admission happens mid-flight into
  freed rows (continuous batching), each completed request's message is
  acknowledged (deleted) individually, and in-flight request leases are
  extended on the heartbeat cadence.  Fault story: a request message is
  deleted only after its completion is recorded, so a worker crash (or
  a ``Preempted`` heartbeat) resurfaces every unfinished request via
  the visibility timeout — including requests the engine had preempted
  under pool pressure and requeued locally — and another worker serves
  them.  At-least-once, exactly like the paper's job queue, but at
  request granularity.

**Elastic leases** (``stream_slice_ticks`` > 0): instead of holding a
lease to completion, the worker runs at most that many engine ticks per
claim, then raises :class:`~repro.core.worker.LeaseYield` — the lease
message is released (budget refunded) and re-claimed next tick, its
warm engine state cached per worker in between.  Lease messages become
interchangeable *work permits*: any permit a worker claims resumes that
worker's own engine, so a fleet can submit ``max_workers`` permits and
let the autoscaler decide how many workers exist to claim them.  On a
spot-revocation notice (``WorkerContext.revoked()``) the lease drains
gracefully: active rows are preempted back, prefix-store publications
flushed, in-flight request messages made visible immediately (receive
counts intact, so poison requests still march to the DLQ), the
segment's counters persisted under ``{out}/leases/``, and the permit
yielded.  A replacement worker cold-builds — cheaply, because models,
params and jitted dispatches are memoized process-wide and the
cross-host prefix store hydrates the KV pages the dead worker already
published.

Engine knobs accepted from the job dict: ``max_batch``, ``max_len``,
``prefill_chunk``, ``dispatch_mode``, ``sample_on_device``,
``cache_mode``, ``page_size``, ``total_pages`` (omitted => adaptive),
``prefix_cache``, ``prefix_match`` (``token`` = sub-page CoW reuse,
``page`` = page-aligned only), scheduler knobs ``refill_policy`` and
``prefill_token_budget``, and the cross-host prefix store
(``prefix_store`` truthy + optional ``prefix_store_namespace``): with
the store on, completed prompts' KV pages are content-hashed into the
shared object store and cold workers hydrate instead of re-prefilling
(see ``docs/serving.md``).

**Disaggregated prefill/decode** (``worker_role``): ``prefill`` leases
chunk-prefill prompts from the request queue, publish each prompt's
full KV chain through the prefix store (chain keys pinned against the
TTL sweep), and enqueue a sealed handoff record onto ``decode_queue``
— they never decode a token.  ``decode`` leases claim handoff records
(their ``request_queue`` IS the decode queue), demand-hydrate exactly
the chained pages, and decode to completion; a record that fails its
seal check is never admitted and marches to the DLQ.  Outputs are
byte-identical to a ``unified`` fleet (see ``docs/serving.md``).

Speculative decoding knobs: ``speculative`` (``off`` | ``ngram`` |
``draft``), ``spec_k`` (drafts per verify dispatch), and for ``draft``
mode ``draft_arch`` / ``draft_arch_overrides`` / ``draft_init_seed``
(the small draft model, built like the target).  Greedy output is
byte-identical to non-speculative serving; only tokens-per-dispatch
changes.  ``DSConfig.speculative`` / ``DSConfig.spec_k`` are the
fleet-level defaults operators copy into serve job templates.
"""

from __future__ import annotations

import hashlib
import json
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.queue import DurableQueue
from repro.core.worker import (
    LeaseYield,
    NotReady,
    WorkerContext,
    backoff_delay,
    register_payload,
)
from repro.launch.train import build_model
from repro.serving.engine import Request, ServeEngine
from repro.serving.prefix_store import PrefixStore
from repro.train.checkpoint import latest_step, restore_checkpoint

# process-wide caches: a serving fleet rebuilds engines constantly
# (slice resumes after takeover, post-revocation replacements), and
# model construction / seed-init / jit tracing dominate a cold build.
# All three are content-keyed, so sharing across engines is sound.
_MODEL_CACHE: Dict[tuple, object] = {}  # dslint: disable=R5(content-keyed memo: concurrent workers racing a cold key rebuild identical values and last-writer-wins on a single GIL-atomic dict store)
_PARAM_CACHE: Dict[tuple, object] = {}  # dslint: disable=R5(content-keyed memo: same last-writer-wins-identical-value argument as _MODEL_CACHE)
# warm lease state, keyed (worker_id, request_queue, output_prefix):
# survives LeaseYield between claims by the same worker; dropped on
# completion, drain, or crash
_LEASE_STATES: Dict[tuple, "_LeaseState"] = {}  # dslint: disable=R5(keys embed worker_id, so each worker thread only ever touches its own entries; individual dict ops are GIL-atomic)


def reset_serve_state() -> None:
    """Drop all cached lease state.  Tests and benchmarks call this
    between independent simulated runs: worker ids repeat across fresh
    runtimes, and a stale warm engine would otherwise let state
    "survive" a simulated crash.  Model/param/jit caches are kept —
    they are content-keyed and runs legitimately share them."""
    for st in list(_LEASE_STATES.values()):
        try:
            st.rq.close()
        except Exception:
            pass
        try:
            if st.dq is not None:
                st.dq.close()
        except Exception:
            pass
    _LEASE_STATES.clear()


def _freeze(value):
    if isinstance(value, dict):
        return tuple(sorted(value.items()))
    return value


def _model_key(job: Dict) -> tuple:
    return (
        job.get("arch"),
        _freeze(job.get("arch_overrides")),
        job.get("moe_strategy", "dense"),
    )


def _cached_model(job: Dict):
    try:
        key = _model_key(job)
        hash(key)
    except TypeError:  # exotic unhashable overrides: build uncached
        return build_model(job)
    model = _MODEL_CACHE.get(key)
    if model is None:
        model = _MODEL_CACHE[key] = build_model(job)
    return model


def _build_params(job: Dict, ctx: WorkerContext, model) -> Tuple[object, str]:
    """Model parameters + a string pinning their identity (the prefix
    store namespace must change whenever page bytes could)."""
    run = job.get("run")
    if run:
        step = latest_step(ctx.store, run)
        if step is None:
            raise RuntimeError(f"no checkpoint for run {run!r}")
        like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params, _ = restore_checkpoint(ctx.store, run, step, like)
        return params, f"run={run}@{step}"
    seed = int(job.get("init_seed", 0))
    # seed-init params are pure functions of (arch, seed): cache them so
    # post-churn engine rebuilds skip re-initialization (checkpoint
    # params are NOT cached — the run's latest step advances)
    try:
        pkey = (_model_key(job), seed)
        hash(pkey)
    except TypeError:
        return model.init(jax.random.PRNGKey(seed)), f"seed={seed}"
    params = _PARAM_CACHE.get(pkey)
    if params is None:
        params = _PARAM_CACHE[pkey] = model.init(jax.random.PRNGKey(seed))
    return params, f"seed={seed}"


def _build_engine(job: Dict, ctx: WorkerContext) -> ServeEngine:
    model = _cached_model(job)
    params, param_id = _build_params(job, ctx, model)
    cache_mode = str(job.get("cache_mode", "dense"))
    if job.get("prefix_store") and cache_mode != "paged":
        raise ValueError(
            "job sets prefix_store but cache_mode is not 'paged'; the "
            "cross-host prefix store would be silently inert"
        )
    paged_kwargs = {}
    if cache_mode == "paged":
        page_size = int(job.get("page_size", 16))
        paged_kwargs["page_size"] = page_size
        # omitted total_pages => the engine sizes the pool adaptively from
        # the queue depth at submit (and logs the chosen size)
        if job.get("total_pages"):
            paged_kwargs["total_pages"] = int(job["total_pages"])
        paged_kwargs["prefix_cache"] = bool(job.get("prefix_cache", True))
        paged_kwargs["prefix_match"] = str(job.get("prefix_match", "token"))
        if job.get("prefix_store"):
            namespace = str(
                job.get("prefix_store_namespace")
                or f"{job.get('arch', 'arch')}/{job.get('arch_overrides', '')}"
                f"/{param_id}/ps{page_size}"
            )
            paged_kwargs["prefix_store"] = PrefixStore(ctx.store, namespace)
    budget = job.get("prefill_token_budget")  # 0 reaches the scheduler's
    #                                           validation and is refused
    speculative = str(job.get("speculative", "off"))
    spec_kwargs = {}
    if speculative != "off":
        spec_kwargs["speculative"] = speculative
        spec_kwargs["spec_k"] = int(job.get("spec_k", 4))
        if speculative == "draft":
            # the draft model is built exactly like the target (arch name
            # + optional overrides) but is typically a much smaller
            # config; random-init by draft_init_seed — drafts are only
            # proposals, the target model still decides every token
            draft_job = {
                "arch": job.get("draft_arch", "ds-paper-100m"),
                "arch_overrides": job.get("draft_arch_overrides", "reduced"),
            }
            draft_model = _cached_model(draft_job)
            draft_seed = int(job.get("draft_init_seed", 0))
            dkey = (_model_key(draft_job), draft_seed)
            draft_params = _PARAM_CACHE.get(dkey)
            if draft_params is None:
                draft_params = _PARAM_CACHE[dkey] = draft_model.init(
                    jax.random.PRNGKey(draft_seed)
                )
            spec_kwargs["draft_model"] = draft_model
            spec_kwargs["draft_params"] = draft_params
    return ServeEngine(
        model,
        params,
        max_batch=int(job.get("max_batch", 4)),
        max_len=int(job.get("max_len", 128)),
        prefill_chunk=int(job.get("prefill_chunk", 16)),
        dispatch_mode=str(job.get("dispatch_mode", "fused")),
        sample_on_device=bool(job.get("sample_on_device", True)),
        cache_mode=cache_mode,
        refill_policy=str(job.get("refill_policy", "continuous")),
        prefill_token_budget=int(budget) if budget is not None else None,
        worker_role=str(job.get("worker_role", "unified")),
        heartbeat=lambda: ctx.heartbeat(),
        **paged_kwargs,
        **spec_kwargs,
    )


def _request_from(body: Dict, job: Dict, fallback_uid: str) -> Request:
    stop = body.get("stop_token", job.get("stop_token"))
    return Request(
        uid=str(body.get("uid", fallback_uid)),
        prompt=[int(t) for t in body["prompt"]],
        max_new_tokens=int(body.get("max_new_tokens", job.get("max_new_tokens", 8))),
        temperature=float(body.get("temperature", job.get("temperature", 0.0))),
        stop_token=int(stop) if stop is not None else None,
    )


def _snapshot(engine: ServeEngine) -> Dict:
    """Full scheduler/cache counter snapshot, plus the legacy key aliases
    earlier RESULTS.json consumers grew up with."""
    snap = engine.snapshot()
    snap["engine_steps"] = snap["steps_executed"]
    if engine.cache_mode == "paged":
        snap["pages_in_use_peak"] = snap["peak_pages"]
    return snap


# --------------------------------------------- work-preserving recovery
def _with_retries(op: Callable, *, key: str, clock, attempts: int = 4,
                  base: float = 0.01, cap: float = 0.5):
    """Run a store/queue operation with capped content-keyed backoff
    against *transient* faults (``ConnectionError`` is what the chaos
    harness's ``flaky_storage``/``flaky_queue`` faults raise, and what a
    real S3/SQS SDK surfaces for retryable errors).  Anything else —
    including ``FileNotFoundError`` misses — propagates immediately."""
    for attempt in range(1, attempts + 1):
        try:
            return op()
        except ConnectionError:
            if attempt == attempts:
                raise
            clock.sleep(backoff_delay(base, attempt, cap=cap, key=key))


def _uid_safe(uid: str) -> str:
    return str(uid).replace("/", "~")


def _seal_checkpoint(ckpt: Dict) -> Dict:
    """Attach the sha256 of the canonical-JSON checkpoint body: the
    resume path re-derives it, so a torn write or bit-flipped record is
    detected and degrades to full replay instead of corrupting output."""
    body = json.dumps(ckpt, sort_keys=True, separators=(",", ":"))
    return {**ckpt, "sha": hashlib.sha256(body.encode("utf-8")).hexdigest()}


def _checkpoint_valid(ckpt: Dict, req: Request) -> bool:
    """A checkpoint is trusted only if its content hash verifies AND it
    describes exactly the request the queue message carries (the message
    is the source of truth; the checkpoint is an optimization)."""
    if not isinstance(ckpt, dict) or "sha" not in ckpt:
        return False
    body = {k: v for k, v in ckpt.items() if k != "sha"}
    digest = hashlib.sha256(
        json.dumps(body, sort_keys=True, separators=(",", ":")).encode("utf-8")
    ).hexdigest()
    if digest != ckpt["sha"]:
        return False
    try:
        output = [int(t) for t in ckpt["output"]]
        return (
            str(ckpt["uid"]) == req.uid
            and [int(t) for t in ckpt["prompt"]] == req.prompt
            and 0 < len(output) <= req.max_new_tokens
            and int(ckpt["max_new_tokens"]) == req.max_new_tokens
            and float(ckpt["temperature"]) == req.temperature
            and ckpt.get("stop_token") == req.stop_token
            and int(ckpt["sample_stream"]) >= 0
        )
    except (KeyError, TypeError, ValueError):
        return False


def _try_resume(engine: ServeEngine, ctx: WorkerContext, ckpt_prefix: str,
                req: Request) -> Optional[Request]:
    """Fallback ladder, rung one: admit ``req`` from its generation
    checkpoint.  Returns the resumed Request, or None — counting a
    ``checkpoint_fallback`` — when the checkpoint is missing, unreadable
    or fails validation; the caller then submits the request normally
    (rung two: whatever prefix pages survive in the store still turn
    most of the replay into a stitch; rung three: full replay, byte-
    identical either way via the deterministic sampling streams)."""
    key = f"{ckpt_prefix}{_uid_safe(req.uid)}.json"
    try:
        ckpt = _with_retries(
            lambda: ctx.store.get_json(key), key=key, clock=ctx.clock
        )
    except FileNotFoundError:
        ckpt = None
    except Exception:  # noqa: BLE001 - unreadable/corrupt blob: replay
        ckpt = None
    if ckpt is None or not _checkpoint_valid(ckpt, req):
        engine.stats.checkpoint_fallbacks += 1
        return None
    return engine.submit_resume(ckpt)


# --------------------------------------- disaggregated prefill/decode
def _handoff_valid(rec) -> bool:
    """A decode-queue message is admitted only if its content hash
    verifies and it is shaped like a handoff: the checkpoint record
    format with an EMPTY output (nothing decoded yet).  Unlike
    ``_checkpoint_valid`` there is no request to cross-check against —
    the sealed record IS the source of truth on the decode side."""
    if not isinstance(rec, dict) or "sha" not in rec:
        return False
    body = {k: v for k, v in rec.items() if k != "sha"}
    digest = hashlib.sha256(
        json.dumps(body, sort_keys=True, separators=(",", ":")).encode("utf-8")
    ).hexdigest()
    if digest != rec["sha"]:
        return False
    try:
        return (
            len(list(rec["output"])) == 0
            and len([int(t) for t in rec["prompt"]]) > 0
            and int(rec["sample_stream"]) >= 0
            and int(rec["max_new_tokens"]) > 0
        )
    except (KeyError, TypeError, ValueError):
        return False


def _publish_handoff(ctx: WorkerContext, st: "_LeaseState", r: Request,
                     m) -> None:
    """Seal and enqueue one finished prefill onto the decode queue.

    Ordering is the handoff contract (durable-before-ack, extended to a
    three-party exchange): (1) the prompt's KV chain — full pages plus
    the sub-page tail — is flushed durable in the prefix store; (2)
    every chain key is pinned so the TTL sweep cannot reclaim the pages
    before a decode worker admits them; (3) the sealed record lands on
    the decode queue; (4) the handoff marker is persisted (the prefill
    lease's completion record — ``_served_uids`` termination and uid
    dedup read these); (5) only then is the original request message
    acked.  A crash between any two steps re-delivers the request and
    the whole sequence re-runs idempotently (publishes memo-skip, the
    duplicate decode-queue record dedups by uid on the decode side)."""
    engine = st.engine
    hand = _seal_checkpoint({
        "uid": r.uid,
        "prompt": [int(t) for t in r.prompt],
        "output": [],
        "sample_stream": int(r.sample_stream),
        "max_new_tokens": int(r.max_new_tokens),
        "temperature": float(r.temperature),
        "stop_token": r.stop_token,
    })
    engine.cache_mgr.flush_store()
    store = engine.cache_mgr.store
    for k in engine.cache_mgr.chain_keys_for(r.prompt):
        _with_retries(
            lambda k=k: store.pin(k), key=f"pin/{k}", clock=ctx.clock
        )
    _with_retries(
        lambda: st.dq.send(hand), key=f"handoff/{r.uid}", clock=ctx.clock
    )
    mark_key = f"{st.req_prefix}{r.uid}.json"
    _with_retries(
        lambda: ctx.store.put_json(mark_key, hand),
        key=mark_key, clock=ctx.clock,
    )
    if m is not None:
        _with_retries(
            lambda: st.rq.delete(m), key=mark_key, clock=ctx.clock,
        )
        st.acked += 1
    engine.stats.handoffs_published += 1


@register_payload("distributed-serve")
def serve_payload(job: Dict, ctx: WorkerContext) -> Dict:
    if job.get("request_queue"):
        # the streaming path builds (or resumes) its engine lazily: a
        # lease claimed after the fleet already finished never builds one
        return _serve_stream(job, ctx)

    engine = _build_engine(job, ctx)
    prompts = job["prompts"]  # list of token-id lists
    engine.submit(
        [_request_from({"prompt": p}, job, f"req{i}") for i, p in enumerate(prompts)]
    )
    finished = engine.run_to_completion()
    results = {
        r.uid: {"prompt": r.prompt, "completion": r.output} for r in finished
    }
    out = job.get("output_prefix", "serve/batch0")
    snap = _snapshot(engine)
    results_key = f"{out}/RESULTS.json"
    _with_retries(
        lambda: ctx.store.put_json(results_key, {"requests": results, **snap}),
        key=results_key, clock=ctx.clock,
    )
    return {"n_requests": len(finished), **snap}


class _LeaseState:
    """Warm per-worker serving state carried across lease slices."""

    __slots__ = (
        "key", "worker_id", "out", "req_prefix", "results_key", "ctx",
        "engine", "rq", "inflight", "served", "marks", "acked", "idle",
        "last_ext", "ckpt_prefix", "role", "dq",
    )

    def __init__(self, key, ctx, out, req_prefix, results_key, engine, rq):
        self.key = key
        self.worker_id = ctx.worker_id
        self.ctx = ctx
        self.out = out
        self.req_prefix = req_prefix
        self.results_key = results_key
        self.engine = engine
        self.rq = rq
        self.inflight: Dict[str, object] = {}  # uid -> queue Message
        self.served = set()
        self.marks = engine.scheduler.sample_marks()
        self.acked = 0  # THIS worker's acks (returned as n_requests)
        self.idle = 0
        self.last_ext = ctx.clock.now()
        # generation-checkpoint prefix (None = work-preserving recovery
        # disabled for this job); set right after construction
        self.ckpt_prefix: Optional[str] = None
        # disaggregation: the lease's role and, for prefill leases, the
        # decode-queue handle handoffs are enqueued onto
        self.role = "unified"
        self.dq: Optional[DurableQueue] = None


def _report_progress(ctx: WorkerContext, st: _LeaseState) -> None:
    """Publish the autoscaler's inputs: shared request-queue backlog
    (every lease reports the same queue — the policy takes the max, not
    the sum) and this lease's latency percentiles in engine ticks."""
    qc = st.rq.counts()
    timing = st.engine.scheduler.timing(**st.marks)
    active = len(st.engine.scheduler.pending) + sum(
        1 for s in st.engine.slots if s.req is not None
    )
    ctx.report_progress({
        "kind": "serve",
        "role": st.role,
        "backlog": qc["visible"] + qc["in_flight"],
        "active": active,
        "p99_ttft": timing["ttft_ticks"]["p99"],
        "p99_queue_wait": timing["queue_wait_ticks"]["p99"],
        "served": len(st.served),
    })


def _persist_segment(ctx: WorkerContext, st: _LeaseState, wid_safe: str) -> None:
    """Overwrite this worker's cumulative segment counters under
    ``{out}/leases/``.  Called at every lease-slice yield and at drain,
    so a worker whose permit is never re-claimed (another lease observed
    completion first, or the host is reclaimed between slices) loses at
    most one slice of counters instead of its whole segment.  Best
    effort: counters are reporting, not correctness — a persistent
    storage fault here is logged and dropped, never raised."""
    engine = st.engine
    snap = _snapshot(engine)
    snap["timing"] = engine.scheduler.timing(**st.marks)
    snap["n_requests"] = st.acked
    snap["worker_id"] = st.worker_id
    lease_key = f"{st.out}/leases/{wid_safe}.json"
    try:
        _with_retries(
            lambda: ctx.store.put_json(lease_key, snap),
            key=lease_key, clock=ctx.clock,
        )
    except Exception:  # noqa: BLE001
        ctx.log("segment-counter persist failed (dropped)")


def _revocation_drain(ctx: WorkerContext, st: _LeaseState, wid_safe: str) -> None:
    """Graceful spot-revocation drain, inside the notice window: stop
    admitting, checkpoint every active generation (emitted tokens +
    sampling position durably recorded, resident KV — sub-page tail
    included — published through the prefix store), roll active rows
    back, flush prefix-store publications (they must outlive this
    worker — hydration is what makes the replacement cheap), make every
    in-flight request message visible NOW (receive counts intact: churn
    must still march poison requests toward the DLQ), and persist this
    segment's counters — the replacement's summary cannot include them.

    Ordering is the whole contract: checkpoint records and page
    publications land in the object store BEFORE the requeue makes the
    messages claimable (durable-before-ack), so a resuming worker either
    sees a complete checkpoint or none at all — never a half one."""
    engine = st.engine
    engine.stats.revocation_notices += 1
    for row, slot in enumerate(engine.slots):
        if slot.req is not None:
            if st.ckpt_prefix is not None:
                try:
                    ckpt = engine.checkpoint_slot(row)
                    if ckpt is not None:
                        key = f"{st.ckpt_prefix}{_uid_safe(ckpt['uid'])}.json"
                        _with_retries(
                            lambda k=key, c=ckpt: ctx.store.put_json(
                                k, _seal_checkpoint(c)
                            ),
                            key=key, clock=ctx.clock,
                        )
                except Exception:  # noqa: BLE001 - checkpointing is an
                    # optimization: a storage fault here must never block
                    # the drain (the request full-replays instead)
                    ctx.log(
                        f"checkpoint for {slot.req.uid!r} failed; "
                        "request will replay from token zero"
                    )
            engine.scheduler.preempt(row)
    # durable copies of everything local live in st.inflight; dropping
    # the local queue loses no requests
    engine.scheduler.pending.clear()
    engine.cache_mgr.flush_store()
    requeued = 0
    for uid, m in st.inflight.items():
        if _with_retries(
            lambda m=m: st.rq.change_visibility(m, 0.0),
            key=f"drain/{uid}", clock=ctx.clock,
        ):
            requeued += 1
    engine.stats.drain_requeued_requests += requeued
    _persist_segment(ctx, st, wid_safe)
    _report_progress(ctx, st)
    _LEASE_STATES.pop(st.key, None)
    st.rq.close()
    if st.dq is not None:
        st.dq.close()
    ctx.log(
        f"revocation drain: requeued {requeued} in-flight requests, "
        f"flushed prefix publications, persisted segment counters"
    )


def _serve_stream(job: Dict, ctx: WorkerContext) -> Dict:
    """Stream request messages from a DurableQueue through the scheduler.

    Loop shape: top up a bounded admission backlog from the queue, run
    one engine tick, ack whatever finished, extend in-flight leases on
    the heartbeat cadence.  Exits when ``expected_requests`` acks have
    landed, or after ``stream_idle_polls`` consecutive iterations with
    no messages and no active work; with ``stream_slice_ticks`` > 0 it
    additionally yields the lease every that-many engine ticks (elastic
    mode — see the module docstring).
    """
    out = job.get("output_prefix", "serve/stream0")
    role = str(job.get("worker_role", "unified"))
    if role == "prefill" and not job.get("decode_queue"):
        raise ValueError(
            "worker_role='prefill' requires a 'decode_queue' in the job "
            "(where else would the sealed handoff records go?)"
        )
    # a prefill lease's completion records are its handoff markers: one
    # sealed record per prompt handed off, written before the request
    # ack.  Termination, uid dedup and resume seeding all read this
    # prefix, so the rename re-points them wholesale; decode/unified
    # leases keep writing plain completion records under requests/
    req_prefix = (
        f"{out}/handoffs/" if role == "prefill" else f"{out}/requests/"
    )
    slice_ticks = int(job.get("stream_slice_ticks", 0))
    wid_safe = ctx.worker_id.replace("/", "~")
    # elastic leases write per-worker summaries (many workers share one
    # output prefix); the legacy single-holder lease keeps RESULTS.json
    results_key = (
        f"{out}/RESULTS-{wid_safe}.json" if slice_ticks else f"{out}/RESULTS.json"
    )
    expected: Optional[int] = (
        int(job["expected_requests"]) if job.get("expected_requests") else None
    )
    key = (ctx.worker_id, str(job["request_queue"]), out)
    st = _LEASE_STATES.get(key)

    if ctx.revoked():
        if st is not None:
            # our notice arrived between slices: this claim is the drain
            _revocation_drain(ctx, st, wid_safe)
            raise LeaseYield("revocation notice: drained", retry_in=0.0)
        # nothing of ours to drain — refuse new work for the remainder
        # of the notice window (the fleet reclaims the machine shortly)
        raise NotReady("revocation notice: refusing new lease", retry_in=0.0)

    def _served_uids() -> set:
        # lease memory is O(inflight), not O(total served): completions
        # live in the object store (one record per request, written
        # before the ack) and only the uid SET is held in RAM.  Records
        # persisted by a previous (crashed/revoked) holder seed the set,
        # so ``expected_requests`` still terminates and the final
        # summary includes them.
        return {
            info.key[len(req_prefix):-len(".json")]
            for info in _with_retries(
                lambda: ctx.store.list(req_prefix),
                key=req_prefix, clock=ctx.clock,
            )
            if info.key.endswith(".json")
        }

    if st is None:
        served = _served_uids()
        if slice_ticks and expected is not None and len(served) >= expected:
            # spare permit claimed after the fleet already finished:
            # ack it without building an engine
            summary = {"n_requests": 0, "noop": True}
            if not _with_retries(
                lambda: ctx.store.exists(results_key),
                key=results_key, clock=ctx.clock,
            ):
                _with_retries(
                    lambda: ctx.store.put_json(results_key, summary),
                    key=results_key, clock=ctx.clock,
                )
            return summary
        engine = _build_engine(job, ctx)
        rq = DurableQueue(
            str(job["request_queue"]),
            default_visibility=float(job.get("request_visibility", 120.0)),
            # the DLQ threshold is a consumer-side setting: every lease on
            # this queue must claim with the same one or they disagree on
            # when a poison request is dead
            max_receive_count=int(job.get("request_max_receive_count", 3)),
            clock=ctx.clock,
        )
        st = _LeaseState(key, ctx, out, req_prefix, results_key, engine, rq)
        st.served = served
        st.role = role
        if role == "prefill":
            # handoffs ride the same durable-queue machinery as requests
            # (visibility resurfacing, receive counting, the DLQ march)
            st.dq = DurableQueue(
                str(job["decode_queue"]),
                default_visibility=float(job.get("request_visibility", 120.0)),
                max_receive_count=int(job.get("request_max_receive_count", 3)),
                clock=ctx.clock,
            )
        # prefill leases never resume from generation checkpoints: their
        # rows finish with zero output (nothing to preserve), and a
        # decode-side checkpoint under the shared prefix describes work
        # this role must not admit
        if job.get("generation_checkpoints", True) and role != "prefill":
            st.ckpt_prefix = f"{out}/checkpoints/"
        if served:
            # cold build joining a run with prior progress: a resume.
            # (Hard-killed segments lose at most their LAST slice of
            # counters — every slice yield persists the cumulative
            # snapshot under leases/, and drains persist theirs too.)
            engine.stats.lease_resumes += 1
        _LEASE_STATES[key] = st
    else:
        # warm resume by the same worker: re-point the engine's heartbeat
        # at THIS claim's context (lease extension needs the new receipt)
        st.ctx = ctx
        st.engine.heartbeat = lambda: ctx.heartbeat()
        st.last_ext = ctx.clock.now()

    engine, rq = st.engine, st.rq
    inflight, served = st.inflight, st.served
    # generous idle default (~2.5 s of queue quiet at the default poll):
    # the lease ending strands later arrivals with no consumer, so err
    # well past ordinary arrival gaps; tune down for batch-like use
    idle_limit = int(job.get("stream_idle_polls", 50))
    poll = float(job.get("stream_poll_seconds", 0.05))
    vis = rq.default_visibility
    iters = 0
    try:
        while True:
            if ctx.revoked():
                # notice arrived mid-slice (a beat-triggered fault)
                _revocation_drain(ctx, st, wid_safe)
                raise LeaseYield("revocation notice: drained", retry_in=0.0)
            # keep a pending backlog one batch deep so freed rows refill
            # from local memory instead of waiting on a queue round-trip
            backlog = len(engine.pending) + sum(
                1 for s in engine.slots if s.req is not None
            )
            want = 2 * engine.max_batch - backlog
            claimed = (
                _with_retries(
                    lambda: rq.receive_batch(want),
                    key=str(job["request_queue"]), clock=ctx.clock,
                )
                if want > 0 else []
            )
            for m in claimed:
                req = _request_from(m.body, job, fallback_uid=m.id)
                # resolve client uid collisions FIRST: a DIFFERENT prompt
                # under a known uid is its own request, disambiguated by
                # message id — which is stable across redeliveries, so
                # the dedup below applies to the renamed uid too
                known_prompt = None
                if req.uid in inflight:
                    known_prompt = [
                        int(t) for t in inflight[req.uid].body["prompt"]
                    ]
                elif req.uid in served:
                    rec_key = f"{req_prefix}{req.uid}.json"
                    known_prompt = _with_retries(
                        lambda: ctx.store.get_json(rec_key),
                        key=rec_key, clock=ctx.clock,
                    )["prompt"]
                if known_prompt is not None and known_prompt != req.prompt:
                    ctx.log(f"uid collision on {req.uid!r}: distinct prompt, "
                            f"serving as {req.uid}~{m.id[:8]}")
                    req.uid = f"{req.uid}~{m.id[:8]}"
                if req.uid in served:
                    # redelivery of a request already served here (its
                    # earlier delete hit a stale receipt): ack this copy
                    _with_retries(
                        lambda m=m: rq.delete(m),
                        key=f"ack/{req.uid}", clock=ctx.clock,
                    )
                    continue
                if req.uid in inflight:
                    # duplicate delivery while the first copy is still
                    # being served: the receipt has rotated, so keep the
                    # FRESH handle or the eventual ack becomes a no-op
                    # and the served request marches to the DLQ
                    inflight[req.uid] = m
                    continue
                if m.receive_count > 1:
                    # a request delivered before (requeued by a drain or
                    # resurfaced by a dead worker's visibility timeout)
                    # resuming on this lease
                    engine.stats.requests_resumed += 1
                    if st.ckpt_prefix is not None and _try_resume(
                        engine, ctx, st.ckpt_prefix, req
                    ) is not None:
                        # work-preserving resume: admitted from its
                        # generation checkpoint with the already-emitted
                        # tokens pre-seeded — only the frontier token and
                        # the remaining budget get decoded
                        inflight[req.uid] = m
                        continue
                if role == "decode":
                    # decode-queue messages ARE sealed handoff records.
                    # One that fails its seal/consistency check is never
                    # admitted (a decode scheduler refuses fresh prefill
                    # work by contract): it is left in flight unacked, so
                    # the visibility timeout resurfaces it and receive
                    # counting marches a genuinely poisoned record to
                    # the DLQ
                    rec = dict(m.body)
                    if not _handoff_valid(rec):
                        engine.stats.handoff_seal_rejects += 1
                        continue
                    # carry a uid-collision rename through (the seal was
                    # verified over the original body above)
                    rec["uid"] = req.uid
                    inflight[req.uid] = m
                    engine.submit_handoff(rec)
                    continue
                inflight[req.uid] = m
                engine.submit([req])
            progressed = bool(claimed)
            if engine.pending or engine.scheduler.has_active():
                engine.step()  # heartbeats once per dispatch
                progressed = True
            # drain (not slice) the finished list: a long-lived lease
            # must not retain every served Request object forever
            for r in engine.scheduler.drain_finished():
                if role == "prefill":
                    # finished here means "prompt ingested and published",
                    # not "completed": seal the handoff, pin its chain,
                    # enqueue it, persist the marker, THEN ack (see
                    # _publish_handoff for the ordering contract)
                    _publish_handoff(ctx, st, r, inflight.pop(r.uid, None))
                    served.add(r.uid)
                    continue
                rec = {
                    # a checkpoint-resumed request ran with an extended
                    # prompt; the record always carries the ORIGINAL one
                    # (uid-collision checks and parity consumers compare
                    # against what the client actually sent)
                    "prompt": r.prompt[: len(r.prompt) - r.resume_base],
                    "completion": r.output,
                    "done_at": ctx.clock.now(),
                }
                m = inflight.pop(r.uid, None)
                if m is not None:
                    # durable-before-ack: the completion must be in the
                    # object store BEFORE its message is deleted, or a
                    # worker crash between ack and the lease-end summary
                    # silently loses served requests (the visibility
                    # timeout cannot resurface a deleted message).  Both
                    # sides retry through transient store/queue faults
                    rec_key = f"{req_prefix}{r.uid}.json"
                    _with_retries(
                        lambda: ctx.store.put_json(rec_key, rec),
                        key=rec_key, clock=ctx.clock,
                    )
                    _with_retries(  # per-request ack: at-least-once upheld
                        lambda: rq.delete(m), key=rec_key, clock=ctx.clock,
                    )
                    st.acked += 1
                served.add(r.uid)
            # a preempted-and-requeued request is still in ``inflight``:
            # its lease (and every other in-flight lease) is extended here,
            # so durable requeue happens only if THIS worker dies
            now = ctx.clock.now()
            if inflight and now - st.last_ext > vis / 2:
                for uid, m in inflight.items():
                    _with_retries(
                        lambda m=m: rq.change_visibility(m, vis),
                        key=f"extend/{uid}", clock=ctx.clock,
                    )
                st.last_ext = now
            # bound per-lease memory: keep only a recent latency window
            # (the reported percentiles describe it) — Request objects
            # are already drained above
            engine.scheduler.trim_samples(10_000)
            ctx.heartbeat()
            iters += 1
            if expected is not None and len(served) >= expected:
                break
            if progressed:
                st.idle = 0
            else:
                st.idle += 1
                if st.idle >= idle_limit:
                    break
                ctx.clock.sleep(poll)
            if slice_ticks and iters >= slice_ticks:
                engine.stats.lease_slices += 1
                # counters survive even if this permit is never re-claimed
                # (consumers dedup per worker: a final RESULTS- summary
                # supersedes this slice-cumulative record)
                _persist_segment(ctx, st, wid_safe)
                _report_progress(ctx, st)
                raise LeaseYield(
                    f"slice budget spent ({slice_ticks} engine ticks)",
                    retry_in=0.0,
                )
    except LeaseYield:
        raise  # warm state stays cached for the next claim
    except BaseException:
        # crash/preemption: drop the warm state.  Unacked requests
        # resurface via their visibility timeouts — the at-least-once
        # story — and in-memory segment counters are lost (a crash is a
        # crash).  Publications are flushed as before so survivors can
        # still hydrate this segment's pages.
        _LEASE_STATES.pop(key, None)
        try:
            engine.cache_mgr.flush_store()
        finally:
            rq.close()
            if st.dq is not None:
                st.dq.close()
        raise
    # completed: this holder saw the run through to its exit condition
    _LEASE_STATES.pop(key, None)
    _report_progress(ctx, st)
    rq.close()
    if st.dq is not None:
        st.dq.close()
    # lease end is a drain seam: background prefix-store publishes
    # must be durable before the lease's counters are reported
    engine.cache_mgr.flush_store()
    # lease-end aggregate, assembled FROM the per-request records (the
    # single source of truth); only this one-shot summary materializes
    # every completion in memory at once
    results = {
        info.key[len(req_prefix):-len(".json")]: _with_retries(
            lambda k=info.key: ctx.store.get_json(k),
            key=info.key, clock=ctx.clock,
        )
        for info in _with_retries(
            lambda: ctx.store.list(req_prefix),
            key=req_prefix, clock=ctx.clock,
        )
        if info.key.endswith(".json")
    }
    snap = _snapshot(engine)
    # window the lease's percentiles by the recorded absolute marks; only
    # samples still retained after trims are summarizable, and the count
    # of trimmed-away samples is reported alongside so a bounded window
    # is visible, not silent
    snap["timing"] = engine.scheduler.timing(**st.marks)
    snap["timing_samples_trimmed"] = (
        engine.scheduler.waits_dropped + engine.scheduler.ttfts_dropped
    )
    _with_retries(
        lambda: ctx.store.put_json(results_key, {"requests": results, **snap}),
        key=results_key, clock=ctx.clock,
    )
    return {"n_requests": st.acked, **snap}

"""``distributed-train`` — the training "Something".

A job is a **step span** ``{arch, run, start_step, num_steps, ...}``:
checkpoint-delimited so every job is idempotent (the paper's
CHECK_IF_DONE generalized to training state):

- pre-flight: if the span's DONE marker exists the worker skips it
  (handled by the generic worker's check_if_done);
- prerequisite: a span with ``start_step > 0`` requires a checkpoint at
  (or inside) the span; if missing, the job *fails fast* and resurfaces
  via the visibility timeout until an earlier span produces it — "submit
  everything, only missing work recomputes";
- mid-span preemption: intra-span checkpoints every ``ckpt_every`` steps
  mean a replacement worker resumes from the latest one inside the span;
- every train step heartbeats (extends the SQS lease, raises Preempted on
  spot kill).

Also registers ``distributed-eval`` (perplexity over a data shard) — the
third "Something", mirroring the paper's three public implementations.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core.worker import NotReady, WorkerContext, register_payload
from repro.models import Model, ModelRuntime
from repro.train.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig, Schedule, init_opt_state
from repro.train.steps import TrainStepConfig, make_train_step


def build_model(job: Dict) -> Model:
    cfg = get_arch(job["arch"])
    overrides = job.get("arch_overrides")
    if overrides == "reduced":
        cfg = reduced(cfg)
    elif isinstance(overrides, dict):
        cfg = dataclasses.replace(cfg, **overrides)
    rt = ModelRuntime(moe_strategy=job.get("moe_strategy", "dense"))
    return Model(cfg, rt)


def build_train_step(job: Dict, model: Model):
    opt = AdamWConfig(
        schedule=Schedule(
            peak_lr=job.get("lr", 3e-4),
            warmup_steps=job.get("warmup_steps", 20),
            total_steps=job.get("total_steps", 1000),
        ),
        weight_decay=job.get("weight_decay", 0.1),
        moments_dtype=job.get("moments_dtype", "f32"),
    )
    tcfg = TrainStepConfig(
        microbatches=job.get("microbatches", 1),
        accum_dtype=job.get("accum_dtype", "f32"),
        opt=opt,
    )
    return make_train_step(model, tcfg), opt


@register_payload("distributed-train")
def train_payload(job: Dict, ctx: WorkerContext) -> Dict:
    run = job.get("run", "run0")
    start, num = int(job["start_step"]), int(job["num_steps"])
    end = start + num
    ckpt_every = int(job.get("ckpt_every", max(1, num)))

    model = build_model(job)
    train_step, opt_cfg = build_train_step(job, model)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))
    data = SyntheticLM(
        model.cfg,
        DataConfig(
            seq_len=job.get("seq_len", 64),
            global_batch=job.get("global_batch", 4),
            seed=job.get("data_seed", 0),
        ),
    )

    # ---- restore or init ---------------------------------------------------
    have = latest_step(ctx.store, run)
    if start == 0 and (have is None or have < 0):
        params = model.init(jax.random.PRNGKey(job.get("init_seed", 0)))
        opt_state = init_opt_state(params, opt_cfg)
        state_step = 0
    else:
        if have is None or have < start:
            raise NotReady(
                f"span [{start},{end}) prerequisite checkpoint missing (latest={have})",
                retry_in=float(job.get("prereq_retry_s", 10.0)),
            )
        resume = min(have, end)
        like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params, _ = restore_checkpoint(ctx.store, run, resume, like)
        opt_like = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), like)
        try:
            opt_state, _ = restore_checkpoint(ctx.store, f"{run}-opt", resume, opt_like)
        except Exception:
            opt_state = init_opt_state(params, opt_cfg)  # opt state lost: cold moments
        state_step = resume
        opt_state["step"] = jnp.asarray(state_step, jnp.int32)

    losses = []
    for step in range(state_step, end):
        batch = data.batch(step)
        params, opt_state, metrics = train_step(
            params, opt_state, batch, jax.random.PRNGKey(step)
        )
        losses.append(float(metrics["loss"]))
        ctx.heartbeat(progress=f"step {step + 1}/{end} loss={losses[-1]:.4f}")
        done_step = step + 1
        if done_step % ckpt_every == 0 or done_step == end:
            save_checkpoint(ctx.store, run, done_step, params, extra_meta={"loss": losses[-1]})
            save_checkpoint(ctx.store, f"{run}-opt", done_step, opt_state)

    result = {
        "run": run,
        "span": [start, end],
        "steps_run": len(losses),
        "final_loss": losses[-1] if losses else None,
    }
    out = job.get("output_prefix", f"runs/{run}/spans/{start:06d}-{end:06d}")
    ctx.store.put_json(f"{out}/DONE.json", result)
    return result


@register_payload("distributed-eval")
def eval_payload(job: Dict, ctx: WorkerContext) -> Dict:
    """Perplexity over a deterministic shard of the synthetic stream."""
    run = job.get("run", "run0")
    model = build_model(job)
    data = SyntheticLM(
        model.cfg,
        DataConfig(
            seq_len=job.get("seq_len", 64),
            global_batch=job.get("global_batch", 4),
            seed=job.get("data_seed", 1234),
        ),
    )
    step_ck = latest_step(ctx.store, run)
    if step_ck is None:
        raise RuntimeError(f"no checkpoint for run {run!r}")
    like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params, _ = restore_checkpoint(ctx.store, run, step_ck, like)

    loss_fn = jax.jit(lambda p, b: model.loss(p, b)[0])
    shard_idx = int(job.get("shard", 0))
    n_batches = int(job.get("n_batches", 4))
    losses = []
    for i in range(n_batches):
        batch = data.batch(shard_idx * n_batches + i)
        losses.append(float(loss_fn(params, batch)))
        ctx.heartbeat(progress=f"eval batch {i + 1}/{n_batches}")
    mean = sum(losses) / len(losses)
    result = {"run": run, "shard": shard_idx, "ckpt_step": step_ck, "loss": mean,
              "ppl": float(jnp.exp(jnp.asarray(mean)))}
    out = job.get("output_prefix", f"runs/{run}/eval/shard{shard_idx}")
    ctx.store.put_json(f"{out}/METRICS.json", result)
    return result

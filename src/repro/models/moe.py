"""Mixture-of-Experts layers (mixtral-style top-k, deepseek shared experts).

Two dispatch strategies, selectable per call:

- ``dense``   — every expert computes every token, outputs gated.  Exact;
                used as the correctness oracle and for tiny smoke configs.
- ``capacity``— sort-based dropless-ish dispatch with a static per-expert
                capacity: tokens are argsorted by expert id, scattered into
                an (E, C, D) buffer (experts shardable over the ``model``
                axis for expert parallelism), batched expert matmuls, then
                scatter-add combine.  Tokens overflowing an expert's
                capacity are dropped (GShard semantics, capacity_factor
                controls the drop rate).

Router: softmax over expert logits then top-k, gates renormalized over the
selected experts (mixtral convention).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import GATED, apply_mlp, dense_init, mlp_init
from repro.sharding.logical import shard

Params = Dict[str, jax.Array]


def moe_init(key, cfg: ArchConfig, dtype, depth_scale: float) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        "wi": _experts_init(ks[1], e, d, f, dtype),
        "wo": _experts_init(ks[2], e, f, d, dtype, scale=depth_scale),
    }
    if cfg.activation in GATED:
        p["wg"] = _experts_init(ks[3], e, d, f, dtype)
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(
            ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts, cfg.activation, dtype, depth_scale
        )
    return p


def _experts_init(key, e: int, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * std).astype(dtype)


def _expert_ffn(p: Params, x: jax.Array, activation: str) -> jax.Array:
    """x: (E, C, D) -> (E, C, D), batched over experts.

    Sharding strategy matches the rules engine: expert-parallel when the
    expert count divides the tp axis (deepseek: 160/16), TP-inside-expert
    otherwise (mixtral: 8 experts on a 16-way axis) — the hidden dim then
    takes the 'ff' sharding instead, never both (one mesh axis, one dim).
    """
    from repro.sharding.logical import rule_divides

    e = x.shape[0]
    d = x.shape[-1]
    ep = rule_divides(e, "experts")
    ff_ax = None if ep else "ff"
    # decode ("act_embed" active): hidden dim takes the FSDP axis so the
    # expert matmuls consume weight shards in place; the capacity dim must
    # then release that axis (one mesh axis, one dim per spec)
    dec = rule_divides(d, "act_embed")
    cap_ax = None if dec else "expert_cap"
    emb_ax = "act_embed" if dec else None
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"])
    h = shard(h, "experts", cap_ax, ff_ax)
    if activation == "silu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    elif activation == "gelu_gated":
        h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", x, p["wg"])
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(activation)
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    # hidden dim follows "act_embed" (None in training; the FSDP axis at
    # decode, so wo's data-sharded output dim is produced in place instead
    # of gathering the weight — §Perf 3.6)
    return shard(out, "experts", cap_ax, emb_ax)


def route(p: Params, x: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array]:
    """Returns (gates (T,k) fp32, expert_ids (T,k) int32) for flat tokens."""
    logits = x.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, ids.astype(jnp.int32)


def apply_moe_dense(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Oracle path: compute all experts for all tokens."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, ids = route(p, xt, cfg.top_k)  # (T,k)
    e = cfg.n_experts
    # dense gate matrix (T, E)
    gmat = jnp.zeros((xt.shape[0], e), jnp.float32)
    gmat = gmat.at[jnp.arange(xt.shape[0])[:, None], ids].add(gates)
    # all experts on all tokens: (E, T, D)
    xe = jnp.broadcast_to(xt[None], (e, xt.shape[0], d))
    ye = _expert_ffn(p, xe, cfg.activation)  # (E, T, D)
    y = jnp.einsum("etd,te->td", ye.astype(jnp.float32), gmat).astype(x.dtype)
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg.activation)
    return y


def apply_moe_gather(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Production path: static-capacity, **gather-only** dispatch.

    No scatters anywhere: XLA lowers large scatters into index-broadcast
    monsters with an extra x D memory factor (measured 18.7 GiB of u32
    index tensors for deepseek-v2 train_4k, EXPERIMENTS §Perf).  Instead:

      order      = argsort(expert_id)                  (T·k ints)
      starts[e]  = searchsorted(sorted_ids, e)         (E ints)
      slot (e,c) -> sorted entry p = starts[e] + c     (pure gather)
      buf[e,c]   = x[token_of_sorted[p]]  if valid     (row gather)
      expert FFN on (E, C, D)
      y[t]       = sum_j gate_j * ye[e_j, c_j]         (row gather back)

    Tokens beyond an expert's capacity are dropped (GShard semantics).
    """
    b, s, d = x.shape
    t = b * s
    k, e = cfg.top_k, cfg.n_experts
    cap = int((t * k / e) * cfg.capacity_factor + 0.999)
    cap = max(cap, 1)

    xt = x.reshape(t, d)
    gates, ids = route(p, xt, k)  # (T,k)

    flat_ids = ids.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    sorted_tok = flat_tok[order]

    first_occurrence = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    pos_in_expert = jnp.arange(t * k, dtype=jnp.int32) - first_occurrence.astype(jnp.int32)

    # ---- dispatch: slot (e,c) -> source token (gather) ----------------------
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e, dtype=jnp.int32), side="left")
    ends = jnp.searchsorted(sorted_ids, jnp.arange(e, dtype=jnp.int32), side="right")
    slot_p = starts[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]  # (E, C)
    slot_valid = slot_p < ends[:, None]
    slot_tok = sorted_tok[jnp.clip(slot_p, 0, t * k - 1)]  # (E, C)
    buf = jnp.where(slot_valid[..., None], xt[slot_tok], jnp.zeros((), x.dtype))
    # "act_embed" (None in training, the FSDP axis at decode) puts the
    # buffer's hidden dim on the FSDP axis so the expert matmuls consume
    # weight shards in place instead of all-gathering them per token step;
    # the capacity dim releases the axis when it's taken (§Perf 3.6)
    from repro.sharding.logical import rule_divides as _rd

    _dec = _rd(d, "act_embed")
    buf = shard(buf, "experts", None if _dec else "expert_cap",
                "act_embed" if _dec else None)

    ye = _expert_ffn(p, buf, cfg.activation)  # (E, C, D)

    # ---- combine: entry (t,j) -> expert output (gather back) ------------------
    inv = jnp.argsort(order)  # original entry -> sorted position
    entry_pos = pos_in_expert[inv].reshape(t, k)  # (T, k) slot within expert
    entry_e = ids  # (T, k)
    kept = entry_pos < cap
    y_gathered = ye[entry_e, jnp.clip(entry_pos, 0, cap - 1)]  # (T, k, D)
    w = jnp.where(kept, gates, 0.0).astype(jnp.float32)
    y = jnp.einsum("tkd,tk->td", y_gathered.astype(jnp.float32), w)
    y = y.astype(x.dtype).reshape(b, s, d)
    y = shard(y, "batch", "seq", "embed")

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg.activation)
    return y


def apply_moe_capacity(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Scatter-based capacity dispatch (kept as the §Perf 'before'; the
    gather-only path above is the production default)."""
    b, s, d = x.shape
    t = b * s
    k, e = cfg.top_k, cfg.n_experts
    cap = int((t * k / e) * cfg.capacity_factor + 0.999)
    cap = max(cap, 1)

    xt = x.reshape(t, d)
    gates, ids = route(p, xt, k)  # (T,k)

    flat_ids = ids.reshape(t * k)  # expert id per (token, slot)
    flat_gates = gates.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    sorted_tok = flat_tok[order]
    sorted_gates = flat_gates[order]

    # position of each entry within its expert's run
    first_occurrence = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    pos_in_expert = jnp.arange(t * k, dtype=jnp.int32) - first_occurrence.astype(jnp.int32)
    keep = pos_in_expert < cap

    # scatter tokens into the (E, C, D) buffer; dropped entries go to a
    # scratch row that is never read back
    safe_e = jnp.where(keep, sorted_ids, e - 1)
    safe_c = jnp.where(keep, pos_in_expert, cap - 1)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[safe_e, safe_c].set(
        jnp.where(keep[:, None], xt[sorted_tok], jnp.zeros((1, d), x.dtype)),
        mode="drop",
    )
    buf = shard(buf, "experts", "expert_cap", "embed")

    ye = _expert_ffn(p, buf, cfg.activation)  # (E, C, D)

    # gather back and combine with gates
    y_entries = ye[safe_e, safe_c]  # (T*k, D)
    weights = jnp.where(keep, sorted_gates, 0.0).astype(jnp.float32)
    y = jnp.zeros((t, d), jnp.float32)
    y = y.at[sorted_tok].add(y_entries.astype(jnp.float32) * weights[:, None])
    y = y.astype(x.dtype).reshape(b, s, d)
    y = shard(y, "batch", "seq", "embed")

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg.activation)
    return y


def apply_moe(p: Params, x: jax.Array, cfg: ArchConfig, strategy: str = "capacity") -> jax.Array:
    if strategy == "dense":
        return apply_moe_dense(p, x, cfg)
    if strategy in ("capacity", "shardmap"):
        if strategy == "shardmap":
            from repro.models.moe_shardmap import apply_moe_shardmap, shardmap_applicable

            if shardmap_applicable(cfg, x.shape):
                return apply_moe_shardmap(p, x, cfg)
        return apply_moe_gather(p, x, cfg)  # production GSPMD path / fallback
    if strategy == "capacity_scatter":  # §Perf baseline for comparison
        return apply_moe_capacity(p, x, cfg)
    raise ValueError(f"unknown moe strategy {strategy!r}")

"""Mamba2 blocks via the SSD (state-space duality) chunked algorithm.

Training/prefill uses the chunked SSD form ("Transformers are SSMs",
arXiv:2405.21060, Listing 1): within-chunk quadratic attention-like term
plus an inter-chunk linear recurrence over per-chunk states.  Decode uses
the O(1) recurrent update.  The within/inter-chunk einsums are the
perf-critical TPU hot-spot — `repro.kernels.ssd` provides the Pallas
kernel; this module is the pure-jnp path (also the kernel's oracle).

TP note: the input projection is stored as *separate* matrices (w_z, w_x,
w_B, w_C, w_dt) rather than one fused matrix.  A fused projection whose
output is `jnp.split` at boundaries that don't align with the ``model``
axis shards would force GSPMD realignment collectives; separate matrices
let w_z/w_x shard cleanly on their output dim while the small B/C/dt
projections stay replicated.  Since the depthwise conv is per-channel,
convolving x, B, C separately is exactly equivalent to mamba2's fused
conv over their concatenation.

Layout conventions (g = 1 state group, as in mamba2-1.3b):
    x  : (b, l, h, p)    inner activations, h heads of size p
    dt : (b, l, h)       per-head timestep (after softplus)
    A  : (h,)            negative decay
    B,C: (b, l, n)       state in/out projections, n = d_state
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rmsnorm_gated
from repro.sharding.logical import shard

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------- SSD core
def segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k].

    Returns -inf above the diagonal (masked decay matrix in log space).
    """
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    chunk: int,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    if l % chunk:
        raise ValueError(f"seq len {l} not divisible by chunk {chunk}")
    c = l // chunk

    dA = dt * A  # (b, l, h) in log space, negative
    xr = x.reshape(b, c, chunk, h, p)
    dtr = dt.reshape(b, c, chunk, h)
    dAr = dA.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,q)
    Br = B.reshape(b, c, chunk, n)
    Cr = C.reshape(b, c, chunk, n)

    A_cumsum = jnp.cumsum(dAr, axis=-1)  # (b,h,c,q)

    # 1. within-chunk (quadratic, "diagonal" term)
    L = jnp.exp(segsum(dAr))  # (b,h,c,q,q)
    Y_diag = jnp.einsum(
        "bcqn,bckn,bhcqk,bckh,bckhp->bcqhp", Cr, Br, L, dtr, xr,
        preferred_element_type=jnp.float32,
    )

    # 2. per-chunk states (low-rank term): decay from position to chunk end
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)  # (b,h,c,q)
    states = jnp.einsum(
        "bckn,bhck,bckh,bckhp->bchpn", Br, decay_states, dtr, xr,
        preferred_element_type=jnp.float32,
    )

    # 3. inter-chunk recurrence over chunk states
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    chunk_decay = jnp.exp(A_cumsum[..., -1])  # (b,h,c) total decay per chunk

    def scan_fn(carry, inp):
        st, dec = inp  # st (b,h,p,n), dec (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1))  # lead dim c
    final, prev_states = jax.lax.scan(scan_fn, initial_state.astype(jnp.float32), xs)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    # 4. state -> output within each chunk
    state_decay_out = jnp.exp(A_cumsum)  # (b,h,c,q)
    Y_off = jnp.einsum(
        "bcqn,bchpn,bhcq->bcqhp", Cr, prev_states, state_decay_out,
        preferred_element_type=jnp.float32,
    )

    y = (Y_diag + Y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, final


def ssd_decode_step(
    state: jax.Array,  # (b,h,p,n) fp32
    x: jax.Array,  # (b,h,p)
    dt: jax.Array,  # (b,h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b,n)
    C: jax.Array,  # (b,n)
) -> Tuple[jax.Array, jax.Array]:
    """O(1) recurrent update: h' = exp(dt*A) h + dt * x ⊗ B ; y = C · h'."""
    decay = jnp.exp(dt * A)  # (b,h)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x.astype(jnp.float32), B.astype(jnp.float32))
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    return y.astype(x.dtype), new_state


# ------------------------------------------------------------------- mamba2 block
def mamba2_init(key, cfg: ArchConfig, dtype, depth_scale: float) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    k = cfg.ssm_conv
    ks = jax.random.split(key, 9)
    return {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[1], d, di, dtype),
        "w_B": dense_init(ks[2], d, n, dtype),
        "w_C": dense_init(ks[3], d, n, dtype),
        "w_dt": dense_init(ks[4], d, h, dtype),
        "conv_x": {"w": _conv_init(ks[5], k, di, dtype), "b": jnp.zeros((di,), dtype)},
        "conv_B": {"w": _conv_init(ks[6], k, n, dtype), "b": jnp.zeros((n,), dtype)},
        "conv_C": {"w": _conv_init(ks[7], k, n, dtype), "b": jnp.zeros((n,), dtype)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": {"w": jnp.ones((di,), dtype)},
        "out_proj": dense_init(ks[8], di, d, dtype, scale=depth_scale),
    }


def _conv_init(key, k: int, c: int, dtype):
    return (jax.random.normal(key, (k, c), jnp.float32) * 0.1).astype(dtype)


def causal_conv(xc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d over (b, l, c) with kernel (k, c), then silu."""
    k = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled taps (k is small): avoids conv_general dilated lowering surprises
    acc = jnp.zeros(xc.shape, jnp.float32)
    for i in range(k):
        acc = acc + pad[:, i : i + xc.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(acc + b.astype(jnp.float32)).astype(xc.dtype)


def _project(p: Params, x: jax.Array):
    """x (b,l,d) -> z (b,l,di), xi/B/C pre-conv, dt logits (b,l,h)."""
    z = shard(x @ p["w_z"], "batch", "seq", "ssm_inner")
    xi = shard(x @ p["w_x"], "batch", "seq", "ssm_inner")
    B = x @ p["w_B"]
    C = x @ p["w_C"]
    dt = x @ p["w_dt"]
    return z, xi, B, C, dt


def apply_mamba2(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    use_kernel: bool = False,
) -> jax.Array:
    """Full-sequence mamba2 block (train / prefill)."""
    b, l, d = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim

    z, xi, B, C, dt = _project(p, x)
    xi = causal_conv(xi, p["conv_x"]["w"], p["conv_x"]["b"])
    B = causal_conv(B, p["conv_B"]["w"], p["conv_B"]["b"])
    C = causal_conv(C, p["conv_C"]["w"], p["conv_C"]["b"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,l,h)
    A = -jnp.exp(p["A_log"])  # (h,)
    xh = xi.reshape(b, l, h, hp)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)

    if use_kernel:
        from repro.kernels import ops as kops

        y, _ = kops.ssd(xh, dt, A, B, C, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, B, C, chunk=cfg.ssm_chunk)
    y = y + (p["D"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, l, di)
    y = rmsnorm_gated(p["norm_w"], y, z, cfg.norm_eps)
    out = y @ p["out_proj"]
    return shard(out, "batch", "residual_seq", "embed")


def mamba2_decode_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    k = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, k - 1, di), dtype),
        "conv_B": jnp.zeros((batch, k - 1, n), dtype),
        "conv_C": jnp.zeros((batch, k - 1, n), dtype),
        "ssm": jnp.zeros((batch, h, hp, n), jnp.float32),
    }


def _conv_step(state_win: jax.Array, xt: jax.Array, w: jax.Array, b: jax.Array):
    """Rolling depthwise conv update.  state_win (b,k-1,c), xt (b,c)."""
    window = jnp.concatenate([state_win, xt[:, None, :]], axis=1)  # (b,k,c)
    acc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    out = jax.nn.silu(acc + b.astype(jnp.float32)).astype(xt.dtype)
    return out, window[:, 1:, :]


def _conv_extend(
    win: jax.Array,  # (b, k-1, c) raw inputs preceding the chunk
    raw: jax.Array,  # (b, T, c) raw chunk inputs (right-padded)
    w: jax.Array,
    b: jax.Array,
    lengths: jax.Array,  # (b,) valid tokens per row
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over a chunk with the cached window as left
    context; returns (silu outputs (b,T,c), new window ending at each
    row's last VALID token)."""
    k = w.shape[0]
    T = raw.shape[1]
    full = jnp.concatenate([win.astype(raw.dtype), raw], axis=1)  # (b, k-1+T, c)
    acc = jnp.zeros(raw.shape, jnp.float32)
    for i in range(k):
        acc = acc + full[:, i : i + T, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = jax.nn.silu(acc + b.astype(jnp.float32)).astype(raw.dtype)
    # window after ingesting `lengths` tokens = full[lengths : lengths+k-1]
    # (lengths == 0 reproduces the old window unchanged)
    idx = lengths[:, None] + jnp.arange(k - 1)[None, :]
    new_win = jnp.take_along_axis(full, idx[..., None], axis=1)
    return out, new_win


def _ssd_prefill_chunk(T: int, target: int) -> int:
    """Largest divisor of T that is <= the configured ssd chunk."""
    for c in range(min(target, T), 0, -1):
        if T % c == 0:
            return c
    return 1


def apply_mamba2_prefill(
    p: Params,
    x: jax.Array,  # (b, T, d) right-padded chunk
    state: Dict[str, jax.Array],
    cfg: ArchConfig,
    *,
    valid: jax.Array,  # (b, T) bool mask of real tokens
    lengths: jax.Array,  # (b,) = valid.sum(1), passed in to stay traceable
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked prefill: ingest each row's valid tokens through the SSD scan
    starting from `state` in ONE call, returning outputs for the whole
    chunk and the per-row recurrent state positioned after the last valid
    token.  Padded positions are neutralized by zeroing dt (decay = 1,
    update = 0) — the causal conv never leaks padding left-ward, and the
    padded outputs are simply unused by the caller."""
    b, T, d = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim

    z, xi, B, C, dt = _project(p, x)
    xi, new_cx = _conv_extend(state["conv_x"], xi, p["conv_x"]["w"], p["conv_x"]["b"], lengths)
    B, new_cb = _conv_extend(state["conv_B"], B, p["conv_B"]["w"], p["conv_B"]["b"], lengths)
    C, new_cc = _conv_extend(state["conv_C"], C, p["conv_C"]["w"], p["conv_C"]["b"], lengths)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,T,h)
    dt = dt * valid[..., None].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, T, h, hp)

    chunk = _ssd_prefill_chunk(T, cfg.ssm_chunk)
    y, final = ssd_chunked(xh, dt, A, B, C, chunk=chunk, initial_state=state["ssm"])
    y = y + (p["D"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, T, di)
    y = rmsnorm_gated(p["norm_w"], y, z, cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"conv_x": new_cx, "conv_B": new_cb, "conv_C": new_cc, "ssm": final}


def apply_mamba2_decode(
    p: Params,
    x: jax.Array,  # (b, 1, d)
    state: Dict[str, jax.Array],
    cfg: ArchConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, _, d = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim

    z, xi, B, C, dt = _project(p, x)
    z, xi, B, C, dt = z[:, 0], xi[:, 0], B[:, 0], C[:, 0], dt[:, 0]

    xi, new_cx = _conv_step(state["conv_x"], xi, p["conv_x"]["w"], p["conv_x"]["b"])
    B, new_cb = _conv_step(state["conv_B"], B, p["conv_B"]["w"], p["conv_B"]["b"])
    C, new_cc = _conv_step(state["conv_C"], C, p["conv_C"]["w"], p["conv_C"]["b"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, h, hp)

    y, new_ssm = ssd_decode_step(state["ssm"], xh, dt, A, B, C)
    y = y + (p["D"][None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, di)
    y = rmsnorm_gated(p["norm_w"], y, z, cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv_x": new_cx, "conv_B": new_cb, "conv_C": new_cc, "ssm": new_ssm}

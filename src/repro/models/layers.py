"""Model primitives: norms, activations, RoPE, dense/gated MLPs, attention.

Pure-functional JAX; parameters are plain dict pytrees.  Activation
shardings are logical (`repro.sharding.logical.shard`) so the same code
serves smoke tests (1 CPU device) and the production mesh.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.logical import shard

Params = Dict[str, jax.Array]


# ------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0) -> jax.Array:
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ------------------------------------------------------------------- norms
def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["w"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_gated(p: Params, x: jax.Array, gate: jax.Array, eps: float) -> jax.Array:
    """Mamba2's gated RMSNorm: norm(x * silu(gate)) * w."""
    xf = (x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p["w"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP
GATED = {"silu", "gelu_gated"}


def mlp_init(key, d: int, f: int, activation: str, dtype, depth_scale: float) -> Params:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, f, dtype)}
    if activation in GATED:
        p["wg"] = dense_init(ks[1], d, f, dtype)
    p["wo"] = dense_init(ks[2], f, d, dtype, scale=depth_scale)
    return p


def apply_mlp(p: Params, x: jax.Array, activation: str) -> jax.Array:
    # "act_embed" is None in training (no-op) and 'data' at decode: the
    # contraction dim of the FFN matmuls is then sharded, so FSDP weight
    # shards are consumed in place (partial matmul + psum) instead of
    # being all-gathered per token step
    x = shard(x, "act_batch", "seq", "act_embed")
    h = x @ p["wi"]
    h = shard(h, "batch", "seq", "ff")
    if activation == "silu":
        h = jax.nn.silu(h) * (x @ p["wg"])
    elif activation == "gelu_gated":
        h = jax.nn.gelu(h) * (x @ p["wg"])
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    elif activation == "relu2":  # nemotron squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown activation {activation!r}")
    out = h @ p["wo"]
    # TP boundary: in Megatron-SP mode ("residual_seq" -> 'model') the
    # psum here lowers as reduce-scatter over the sequence dim instead of
    # a full all-reduce (half the bytes); default is unconstrained
    return shard(out, "batch", "residual_seq", "embed")


# ------------------------------------------------------------------- attention
def attn_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    dtype,
    depth_scale: float,
    qkv_bias: bool = False,
) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype, scale=depth_scale),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def qkv_project(
    p: Params,
    x: jax.Array,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    # decode: contraction dim sharded ('act_embed') -> FSDP weight shards
    # consumed in place instead of gathered (no-op in training)
    x = shard(x, "act_batch", "seq", "act_embed")
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,Hkv,hd) -> (B,S,H,hd) by repeating groups (GQA)."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    reps = n_heads // n_kv
    return jnp.repeat(k, reps, axis=2)


def gqa_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, Hkv, hd) — NOT repeated
    v: jax.Array,
    *,
    q_positions: jax.Array,  # (B, Sq)
    kv_positions: jax.Array,  # (B, Skv)
    sliding_window: int = 0,
    kv_mask: Optional[jax.Array] = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Grouped-query attention without materializing repeated KV heads.

    Used on the decode path, where repeating an H/Hkv-grouped 32k-token
    cache would multiply HBM traffic and footprint by the group size.
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, hkv, g, hd)
    logits = jnp.einsum(
        "bqkgd,btkd->bkgqt", qg, k, preferred_element_type=jnp.float32
    ) * scale  # (b, hkv, g, sq, t)
    # follow the CACHE's batch sharding: at decode the residual stream may
    # be batch-replicated (d-sharded), but attention state must stay
    # batch-sharded with the cache or GSPMD gathers cache shards
    logits = shard(logits, "cache_batch", "kv_heads", None, None, "kv_seq")
    if logit_softcap > 0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    qp = q_positions[:, None, None, :, None]
    kp = kv_positions[:, None, None, None, :]
    mask = kp <= qp
    if sliding_window > 0:
        mask = jnp.logical_and(mask, kp > qp - sliding_window)
    if kv_mask is not None:
        mask = jnp.logical_and(mask, kv_mask[:, None, None, None, :])
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs, v)
    out = out.reshape(b, sq, h, hd)
    return shard(out, "cache_batch", "seq", "heads", "head_dim")


def attention_scores(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    sliding_window: int = 0,
    kv_mask: Optional[jax.Array] = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Reference attention.  q: (B,Sq,H,hd); k,v: (B,Skv,H,hd).

    Computed in fp32 accumulations; positions allow decode (Sq=1 with a
    long cache) and sliding windows.  ``kv_mask`` masks invalid cache slots.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    logits = shard(logits, "batch", "heads", None, "kv_seq")
    if logit_softcap > 0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    if q_positions is None:
        q_positions = jnp.arange(sq)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(skv)[None, :]
    qp = q_positions[:, None, :, None]  # (b,1,sq,1)
    kp = kv_positions[:, None, None, :]  # (b,1,1,skv)
    mask = jnp.ones((), jnp.bool_)
    if causal:
        mask = kp <= qp
    if sliding_window > 0:
        mask = jnp.logical_and(mask, kp > qp - sliding_window)
    if kv_mask is not None:
        mask = jnp.logical_and(mask, kv_mask[:, None, None, :])
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return shard(out, "batch", "seq", "heads", "head_dim")

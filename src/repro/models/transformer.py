"""Unified model builder: every assigned architecture family behind one API.

    model = Model(cfg, runtime)
    params = model.init(rng)                       # or jax.eval_shape for dry-run
    loss, metrics = model.loss(params, batch)      # train forward (causal LM)
    cache = model.init_cache(batch, max_len)       # decode cache pytree
    logits, cache = model.decode_step(params, cache, tokens, pos)
    cache, last_logits = model.prefill(params, tokens)

Families: dense (llama/nemotron/qwen/granite), moe (mixtral/deepseek+MLA),
ssm (mamba2), hybrid (zamba2 = mamba2 + shared attention block), audio
(whisper enc-dec, stub frontend), vlm (internvl2, stub frontend).

Layer stacks lower as ``jax.lax.scan`` over stacked parameters so the
512-device dry-run compiles in seconds; ``runtime.unroll_layers`` unrolls
instead (used by the roofline per-layer probe).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    apply_rope,
    attn_init,
    attention_scores,
    dense_init,
    embed_init,
    gqa_attention,
    mlp_init,
    norm_init,
    qkv_project,
    repeat_kv,
)
from repro.sharding.logical import shard

Params = Dict[str, Any]


@dataclass(frozen=True)
class ModelRuntime:
    """Execution knobs, orthogonal to the architecture."""

    dtype: Any = jnp.float32
    attn_impl: str = "auto"  # auto | direct | chunked | kernel
    attn_chunk: int = 1024
    # paged-cache attention: "kernel" = Pallas flash-decode/chunk-extend
    # through the page table (interpret mode off-TPU), "jnp" = gather the
    # pages and reuse the dense attention path (the CPU-fast reference)
    paged_attn_impl: str = "auto"  # auto | kernel | jnp
    moe_strategy: str = "capacity"
    use_ssd_kernel: bool = False
    remat: bool = False
    # 0 = checkpoint every layer; k>1 = checkpoint every k layers
    # (sqrt-remat: residuals = L/k boundaries + k inner during recompute)
    remat_segment: int = 0
    unroll_layers: bool = False
    logit_dtype: Any = jnp.float32

    def resolve_attn(self, seq_len: int) -> str:
        if self.attn_impl != "auto":
            return self.attn_impl
        return "chunked" if seq_len > 4096 else "direct"

    def resolve_paged_attn(self) -> str:
        if self.paged_attn_impl != "auto":
            return self.paged_attn_impl
        return "kernel" if jax.default_backend() == "tpu" else "jnp"


# ============================================================ chunked attention
def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    sliding_window: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention over kv chunks (pure-jnp flash pattern).

    q,k,v: (B, S, H, hd) with equal q/kv length (prefill/train).  Memory is
    O(B·H·chunk²) instead of O(B·H·S²).  FLOPs equal the full rectangle
    (masked) — same count XLA produces for direct attention; the Pallas
    kernel is the path that skips masked tiles on TPU.
    """
    b, s, h, hd = q.shape
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nq = s // chunk
    scale = 1.0 / math.sqrt(hd)
    qs = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 3, 2, 4)  # (nq,b,h,c,hd)
    ks = k.reshape(b, nq, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nq, chunk, h, hd).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        q_pos = iq * chunk + jnp.arange(chunk)

        def kv_step(carry, kj_idx):
            m, l, acc = carry
            kj, vj, jk = kj_idx
            k_pos = jk * chunk + jnp.arange(chunk)
            logits = (
                jnp.einsum("bhqd,bhkd->bhqk", qi, kj, preferred_element_type=jnp.float32)
                * scale
            )
            mask = jnp.ones((chunk, chunk), bool)
            if causal:
                mask = k_pos[None, :] <= q_pos[:, None]
            if sliding_window > 0:
                mask = jnp.logical_and(mask, k_pos[None, :] > q_pos[:, None] - sliding_window)
            logits = jnp.where(mask[None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(qi.dtype), vj, preferred_element_type=jnp.float32
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, chunk), jnp.float32)
        a0 = jnp.zeros((b, h, chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nq)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))  # (nq,b,h,c,hd)
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, hd)


# ================================================================= blocks
def _attn_forward(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    rt: ModelRuntime,
    *,
    positions: jax.Array,
    causal: bool = True,
    n_heads: Optional[int] = None,
    n_kv: Optional[int] = None,
    head_dim: Optional[int] = None,
    use_rope: bool = True,
) -> jax.Array:
    h = n_heads or cfg.n_heads
    hkv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    q, k, v = qkv_project(p, x, h, hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    kr, vr = repeat_kv(k, h), repeat_kv(v, h)
    impl = rt.resolve_attn(x.shape[1])
    if impl == "kernel":
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, kr, vr, causal=causal, sliding_window=cfg.sliding_window)
    elif impl == "chunked":
        out = chunked_attention(
            q, kr, vr, causal=causal, sliding_window=cfg.sliding_window,
            chunk=_pick_chunk(x.shape[1], rt.attn_chunk),
        )
    else:
        out = attention_scores(
            q, kr, vr, causal=causal, sliding_window=cfg.sliding_window,
            q_positions=positions, kv_positions=positions,
            logit_softcap=cfg.attn_logit_softcap,
        )
    out = out.reshape(x.shape[0], x.shape[1], h * hd)
    return shard(out @ p["wo"], "batch", "residual_seq", "embed")


def _attn_extend(
    p: Params,
    x: jax.Array,  # (b,T,d)
    cache_k: jax.Array,  # (b,t,hkv,hd)
    cache_v: jax.Array,
    positions: jax.Array,  # (b,T) absolute positions of the chunk tokens
    cfg: ArchConfig,
    *,
    n_heads: Optional[int] = None,
    n_kv: Optional[int] = None,
    head_dim: Optional[int] = None,
    use_rope: bool = True,
    valid: Optional[jax.Array] = None,  # (b,T) real (non-padded) tokens
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill attention: append T tokens per row to the KV cache
    in one shot and attend each query against the full cache.

    Right-padded tokens (``valid`` False, including whole rows that are
    not ingesting this dispatch) have their writes redirected out of
    bounds — JAX drops out-of-bounds scatter updates — so the cache only
    ever receives real tokens.  Causality then falls out of the
    ``kv_pos <= q_pos`` position mask.  A rolling sliding-window cache
    (t < max position) additionally mislabels wrapped slots via the
    chunk-end reconstruction below, so callers gate those out.
    """
    h = n_heads or cfg.n_heads
    hkv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    b, T, _ = x.shape
    q, k, v = qkv_project(p, x, h, hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    t = cache_k.shape[1]
    write = jnp.mod(positions, t)  # (b,T)
    if valid is not None:
        write = jnp.where(valid, write, t)  # out-of-bounds -> update dropped
    rows = jnp.arange(b)[:, None]
    cache_k = cache_k.at[rows, write].set(k.astype(cache_k.dtype))
    cache_v = cache_v.at[rows, write].set(v.astype(cache_v.dtype))
    cache_k = shard(cache_k, "cache_batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = shard(cache_v, "cache_batch", "kv_seq", "kv_heads", "head_dim")
    # absolute position held by each cache slot, referenced to the chunk end
    last = positions[:, -1]
    slots = jnp.arange(t)
    kv_pos = last[:, None] - jnp.mod(last[:, None] - slots[None, :], t)  # (b,t)
    kv_mask = kv_pos >= 0
    out = gqa_attention(
        q, cache_k, cache_v,
        q_positions=positions, kv_positions=kv_pos,
        sliding_window=cfg.sliding_window, kv_mask=kv_mask,
        logit_softcap=cfg.attn_logit_softcap,
    )
    out = out.reshape(b, T, h * hd)
    out = shard(out, "act_batch", "seq", "act_heads")
    return shard(out @ p["wo"], "batch", "seq", "embed"), cache_k, cache_v


def _attn_decode(
    p: Params,
    x: jax.Array,  # (b,1,d)
    cache_k: jax.Array,  # (b,T,hkv,hd)
    cache_v: jax.Array,
    pos: jax.Array,
    cfg: ArchConfig,
    *,
    n_heads: Optional[int] = None,
    n_kv: Optional[int] = None,
    head_dim: Optional[int] = None,
    use_rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    h = n_heads or cfg.n_heads
    hkv = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    uniform_pos = pos.ndim == 0  # all rows at the same depth (serving cells)
    pos_v = jnp.broadcast_to(pos, (b,))
    positions = pos_v[:, None]
    q, k, v = qkv_project(p, x, h, hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # rolling cache: slot = pos % T.  For full caches (T > pos) this is the
    # identity; for sliding-window caches (T = window+1 padded) it wraps.
    t = cache_k.shape[1]
    if uniform_pos:
        # scalar-position write: dynamic-update-slice partitions cleanly
        # over a kv_seq-sharded cache; the per-row scatter below makes
        # GSPMD all-gather cache shards (measured 79 GB/step for
        # nemotron decode_32k — EXPERIMENTS §Perf iteration 3.1)
        write0 = jnp.mod(pos, t)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, write0, 0, 0)
        )
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, write0, 0, 0)
        )
    else:
        write = jnp.mod(pos_v, t)  # (b,) continuous batching: ragged rows
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, write].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, write].set(v[:, 0].astype(cache_v.dtype))
    cache_k = shard(cache_k, "cache_batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = shard(cache_v, "cache_batch", "kv_seq", "kv_heads", "head_dim")
    # absolute position held by each slot (most recent write <= its row pos)
    slots = jnp.arange(t)
    kv_pos = pos_v[:, None] - jnp.mod(pos_v[:, None] - slots[None, :], t)  # (b,t)
    kv_mask = kv_pos >= 0
    # grouped attention: never materialize repeated KV heads against a
    # long cache (12x HBM blow-up for nemotron's 96/8 grouping)
    out = gqa_attention(
        q, cache_k, cache_v,
        q_positions=positions, kv_positions=kv_pos,
        sliding_window=cfg.sliding_window, kv_mask=kv_mask,
        logit_softcap=cfg.attn_logit_softcap,
    )
    out = out.reshape(b, 1, h * hd)
    # wo is row-parallel over 'model': constrain the contraction input so
    # the decode matmul is partial + psum rather than a weight gather
    out = shard(out, "act_batch", "seq", "act_heads")
    return shard(out @ p["wo"], "batch", "seq", "embed"), cache_k, cache_v


# ------------------------------------------------------- paged KV attention
def _paged_gqa(
    q: jax.Array,  # (b, T, H, hd)
    k_pages: jax.Array,  # (n_pages, ps, Hkv, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (b, P) int32; >= n_pages = unallocated
    q_positions: jax.Array,  # (b, T)
    cfg: ArchConfig,
    rt: ModelRuntime,
) -> jax.Array:
    """Attention against the paged cache: Pallas flash kernel through the
    page table, or the jnp fallback (gather pages -> dense ``gqa_attention``,
    byte-identical math to the dense cache path so paged and dense engines
    stay token-parity).

    Page-table entries are pure indirection: several rows may alias the
    same physical page (shared-prefix stitching), which is transparent to
    both read paths.  The serving engine guarantees writes never target an
    aliased page (copy-on-write privatizes it first), so reads here always
    see immutable shared content."""
    if rt.resolve_paged_attn() == "kernel":
        from repro.kernels import ops as kops

        return kops.paged_attention(
            q, k_pages, v_pages, page_table, q_positions[:, 0],
            softcap=cfg.attn_logit_softcap,
        )
    n_pages, ps, hkv, hd = k_pages.shape
    b = q.shape[0]
    P = page_table.shape[1]
    safe = jnp.minimum(page_table, n_pages - 1)
    kf = k_pages[safe].reshape(b, P * ps, hkv, hd)
    vf = v_pages[safe].reshape(b, P * ps, hkv, hd)
    kv_pos = jnp.broadcast_to(jnp.arange(P * ps, dtype=jnp.int32)[None], (b, P * ps))
    # unallocated pages gather garbage from the clamped physical page; the
    # allocator keeps them past every query's frontier, but masking them
    # also keeps padded prefill rows finite
    kv_mask = jnp.repeat(page_table < n_pages, ps, axis=1)
    return gqa_attention(
        q, kf, vf,
        q_positions=q_positions, kv_positions=kv_pos, kv_mask=kv_mask,
        logit_softcap=cfg.attn_logit_softcap,
    )


def _attn_decode_paged(
    p: Params,
    x: jax.Array,  # (b,1,d)
    k_pages: jax.Array,  # (n_pages, ps, hkv, hd) — this layer's page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # (b, P)
    pos: jax.Array,  # (b,) per-row cache positions
    cfg: ArchConfig,
    rt: ModelRuntime,
    *,
    use_rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode token per row against the paged cache.

    The new K/V lands at physical slot ``(page_table[b, pos//ps], pos%ps)``;
    rows whose table entry is the out-of-bounds sentinel (parked slots —
    their pages were freed) have the scatter dropped by JAX, so a dead row
    can never write into a page now owned by someone else."""
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos_v[:, None]
    q, k, v = qkv_project(p, x, h, hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    n_pages, ps = k_pages.shape[0], k_pages.shape[1]
    P = page_table.shape[1]
    rows = jnp.arange(b)
    phys = page_table[rows, jnp.minimum(pos_v // ps, P - 1)]  # (b,) OOB = dropped
    off = pos_v % ps
    k_pages = k_pages.at[phys, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[phys, off].set(v[:, 0].astype(v_pages.dtype))
    out = _paged_gqa(q, k_pages, v_pages, page_table, positions, cfg, rt)
    out = out.reshape(b, 1, h * hd)
    out = shard(out, "act_batch", "seq", "act_heads")
    return shard(out @ p["wo"], "batch", "seq", "embed"), k_pages, v_pages


def _attn_extend_paged(
    p: Params,
    x: jax.Array,  # (b,T,d)
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    positions: jax.Array,  # (b,T) absolute positions of the chunk tokens
    cfg: ArchConfig,
    rt: ModelRuntime,
    *,
    use_rope: bool = True,
    valid: Optional[jax.Array] = None,  # (b,T) real (non-padded) tokens
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunk-extend against the paged cache: append T tokens per row and
    attend each query through the page table.  Padded tokens write to the
    out-of-bounds page sentinel (dropped); their garbage outputs are
    discarded by the caller's last-valid-token gather.

    ``positions`` may start at any page-aligned (or, after a shared-prefix
    full hit, mid-page copy-on-write) offset: RoPE uses the absolute
    positions and earlier pages — possibly written by a *different* row
    that shares the prefix — are visible through the table, so prefill can
    resume mid-sequence from the first divergent chunk."""
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, T, _ = x.shape
    q, k, v = qkv_project(p, x, h, hkv, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    n_pages, ps = k_pages.shape[0], k_pages.shape[1]
    P = page_table.shape[1]
    rows = jnp.arange(b)[:, None]
    wp = page_table[rows, jnp.minimum(positions // ps, P - 1)]  # (b,T)
    if valid is not None:
        wp = jnp.where(valid, wp, n_pages)  # out of bounds -> dropped
    k_pages = k_pages.at[wp, positions % ps].set(k.astype(k_pages.dtype))
    v_pages = v_pages.at[wp, positions % ps].set(v.astype(v_pages.dtype))
    out = _paged_gqa(q, k_pages, v_pages, page_table, positions, cfg, rt)
    out = out.reshape(b, T, h * hd)
    out = shard(out, "act_batch", "seq", "act_heads")
    return shard(out @ p["wo"], "batch", "seq", "embed"), k_pages, v_pages


# -------------------------------------------------- per-family layer init/apply
def _layer_init(key, cfg: ArchConfig, dtype, dense_layer: bool) -> Params:
    """One decoder layer.  ``dense_layer``: MoE archs keep the first
    ``first_k_dense`` layers dense."""
    depth_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": norm_init(cfg.d_model, cfg.norm, dtype)}
    if cfg.family == "ssm":
        p["mixer"] = ssm_mod.mamba2_init(ks[0], cfg, dtype, depth_scale)
        return p
    if cfg.family == "hybrid":
        p["mixer"] = ssm_mod.mamba2_init(ks[0], cfg, dtype, depth_scale)
        return p
    # attention families
    if cfg.use_mla:
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype, depth_scale)
    else:
        p["attn"] = attn_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            dtype, depth_scale, qkv_bias=cfg.qkv_bias,
        )
    p["ln2"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if cfg.family == "moe" and not dense_layer:
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype, depth_scale)
    else:
        f = cfg.d_ff if cfg.d_ff else cfg.moe_d_ff
        p["mlp"] = mlp_init(ks[1], cfg.d_model, f, cfg.activation, dtype, depth_scale)
    return p


def _layer_apply(
    p: Params, x: jax.Array, cfg: ArchConfig, rt: ModelRuntime, positions: jax.Array
) -> jax.Array:
    h = x + _mixer_apply(p, x, cfg, rt, positions)
    if "ln2" not in p:
        return h
    hn = apply_norm(p["ln2"], h, cfg.norm, cfg.norm_eps)
    if "moe" in p:
        return h + moe_mod.apply_moe(p["moe"], hn, cfg, rt.moe_strategy)
    return h + apply_mlp(p["mlp"], hn, cfg.activation)


def _mixer_apply(p, x, cfg, rt, positions):
    xn = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if "mixer" in p:
        return ssm_mod.apply_mamba2(p["mixer"], xn, cfg, use_kernel=rt.use_ssd_kernel)
    if cfg.use_mla:
        return mla_mod.apply_mla(p["attn"], xn, cfg, positions=positions)
    # archs with learned absolute positions (whisper) do not use RoPE
    return _attn_forward(
        p["attn"], xn, cfg, rt, positions=positions,
        use_rope=not cfg.max_position_embeddings,
    )


# ------------------------------------------------------- zamba2 shared block
def _shared_block_init(key, cfg: ArchConfig, dtype) -> Params:
    """Shared transformer block at width 2·d_model (zamba2)."""
    d2 = 2 * cfg.d_model
    hd = d2 // cfg.n_heads
    depth_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    ks = jax.random.split(key, 5)
    n_inv = cfg.n_layers // cfg.shared_attn_every
    p: Params = {
        "ln1": norm_init(d2, cfg.norm, dtype),
        "attn": attn_init(ks[0], d2, cfg.n_heads, cfg.n_kv_heads, hd, dtype, depth_scale),
        "ln2": norm_init(d2, cfg.norm, dtype),
        "mlp": mlp_init(ks[1], d2, cfg.d_ff, cfg.activation, dtype, depth_scale),
        "down": dense_init(ks[2], d2, cfg.d_model, dtype, scale=depth_scale),
    }
    if cfg.shared_attn_lora_rank:
        r = cfg.shared_attn_lora_rank
        p["lora_a"] = (
            jax.random.normal(ks[3], (n_inv, d2, r), jnp.float32) * (1.0 / math.sqrt(d2))
        ).astype(dtype)
        p["lora_b"] = jnp.zeros((n_inv, r, cfg.n_heads * hd), dtype)
    return p


def _shared_block_apply(
    p: Params,
    h: jax.Array,
    x0: jax.Array,
    inv: int,
    cfg: ArchConfig,
    rt: ModelRuntime,
    positions: jax.Array,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    pos: Optional[jax.Array] = None,
    valid: Optional[jax.Array] = None,
):
    """Returns delta to add to h (and updated kv cache when decoding)."""
    d2h = jnp.concatenate([h, x0], axis=-1)
    xn = apply_norm(p["ln1"], d2h, cfg.norm, cfg.norm_eps)
    attn_p = dict(p["attn"])
    if "lora_a" in p:
        la, lb = p["lora_a"][inv], p["lora_b"][inv]
        attn_p = dict(attn_p)
        attn_p["wq"] = attn_p["wq"] + (la @ lb).astype(attn_p["wq"].dtype)
    hd = 2 * cfg.d_model // cfg.n_heads
    if cache is None:
        a = _attn_forward(
            attn_p, xn, cfg, rt, positions=positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
        )
        new_cache = None
    elif pos is not None:
        a, ck, cv = _attn_decode(
            attn_p, xn, cache[0], cache[1], pos, cfg,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
        )
        new_cache = (ck, cv)
    else:
        # chunked prefill: T tokens per row against the shared-block cache
        a, ck, cv = _attn_extend(
            attn_p, xn, cache[0], cache[1], positions, cfg,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd, valid=valid,
        )
        new_cache = (ck, cv)
    y = d2h + a
    yn = apply_norm(p["ln2"], y, cfg.norm, cfg.norm_eps)
    y = y + apply_mlp(p["mlp"], yn, cfg.activation)
    return y @ p["down"], new_cache


# ================================================================== Model
class Model:
    def __init__(self, cfg: ArchConfig, runtime: Optional[ModelRuntime] = None):
        self.cfg = cfg
        self.rt = runtime or ModelRuntime()

    # ------------------------------------------------------------- init
    def init(self, rng: jax.Array) -> Params:
        cfg, dtype = self.cfg, self.rt.dtype
        keys = jax.random.split(rng, 8)
        params: Params = {"embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype)}

        n_dense = cfg.first_k_dense if cfg.family == "moe" else 0
        layer_keys = jax.random.split(keys[1], cfg.n_layers)
        if n_dense:
            # heterogeneous stack: leading dense layers kept separate
            params["dense_layers"] = _stack_init(
                layer_keys[:n_dense], lambda k: _layer_init(k, cfg, dtype, dense_layer=True)
            )
            params["layers"] = _stack_init(
                layer_keys[n_dense:], lambda k: _layer_init(k, cfg, dtype, dense_layer=False)
            )
        else:
            params["layers"] = _stack_init(
                layer_keys, lambda k: _layer_init(k, cfg, dtype, dense_layer=False)
            )

        if cfg.family == "hybrid":
            params["shared"] = _shared_block_init(keys[2], cfg, dtype)
        if cfg.is_encoder_decoder:
            enc_keys = jax.random.split(keys[3], cfg.n_encoder_layers)
            params["encoder"] = {
                "layers": _stack_init(enc_keys, lambda k: _layer_init(k, cfg, dtype, True)),
                "ln_f": norm_init(cfg.d_model, cfg.norm, dtype),
                "pos": embed_init(keys[4], cfg.encoder_seq, cfg.d_model, dtype),
            }
            params["cross"] = _stack_init(
                jax.random.split(keys[5], cfg.n_layers),
                lambda k: {
                    "ln": norm_init(cfg.d_model, cfg.norm, dtype),
                    "attn": attn_init(
                        k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
                        dtype, 1.0 / math.sqrt(2 * cfg.n_layers),
                    ),
                },
            )
        if cfg.max_position_embeddings:
            params["pos"] = embed_init(keys[6], cfg.max_position_embeddings, cfg.d_model, dtype)
        params["ln_f"] = norm_init(cfg.d_model, cfg.norm, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[7], cfg.d_model, cfg.padded_vocab, dtype)
        return params


    # ---------------------------------------------------------------- scan
    def _maybe_scan(self, body_fn, carry, xs):
        """lax.scan over stacked layers, or an unrolled Python loop when
        runtime.unroll_layers (roofline probes need entry-visible costs)."""
        if not self.rt.unroll_layers:
            return jax.lax.scan(body_fn, carry, xs)
        n = _stack_len(xs)
        ys = []
        for i in range(n):
            x_i = jax.tree.map(lambda a: a[i], xs)
            carry, y = body_fn(carry, x_i)
            ys.append(y)
        if ys and ys[0] is not None:
            stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        else:
            stacked = None
        return carry, stacked

    # ----------------------------------------------------------- embeddings
    def _embed(self, params: Params, tokens: jax.Array, offset: int = 0) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.max_position_embeddings:
            s = tokens.shape[1]
            x = x + jax.lax.dynamic_slice_in_dim(params["pos"], offset, s, axis=0)[None]
        return shard(x.astype(self.rt.dtype), "batch", "seq", "embed")

    def _logits(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = apply_norm(params["ln_f"], h, cfg.norm, cfg.norm_eps)
        h = shard(h, "act_batch", "seq", "act_embed")
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (h @ w).astype(self.rt.logit_dtype)
        return shard(logits, "batch", "seq", "vocab")

    # ----------------------------------------------------------- backbone
    def _run_layers(
        self, params: Params, x: jax.Array, positions: jax.Array
    ) -> jax.Array:
        cfg, rt = self.cfg, self.rt

        def body_fn(h, layer_p):
            out = _layer_apply(layer_p, h, cfg, rt, positions)
            return out, None

        if rt.remat:
            body_fn = jax.checkpoint(body_fn)  # noqa: F821 - jax.checkpoint is jax.remat

        if cfg.family == "hybrid":
            return self._run_hybrid(params, x, positions)

        h = x
        for group in ("dense_layers", "layers"):
            if group not in params:
                continue
            stacked = params[group]
            n = _stack_len(stacked)
            seg = rt.remat_segment
            if rt.unroll_layers:
                for i in range(n):
                    h, _ = body_fn(h, jax.tree.map(lambda a: a[i], stacked))
            elif rt.remat and seg > 1 and n % seg == 0:
                # segmented (sqrt) remat: only segment-boundary activations
                # persist; per-layer residuals materialize transiently while
                # a segment is being recomputed for its backward
                inner_body = lambda hh, lp: (_layer_apply(lp, hh, cfg, rt, positions), None)  # noqa: E731

                def seg_body(hh, seg_params):
                    hh, _ = jax.lax.scan(inner_body, hh, seg_params)
                    return hh, None

                seg_body = jax.checkpoint(seg_body)
                stacked_seg = jax.tree.map(
                    lambda a: a.reshape((n // seg, seg) + a.shape[1:]), stacked
                )
                h, _ = jax.lax.scan(seg_body, h, stacked_seg)
            else:
                h, _ = jax.lax.scan(body_fn, h, stacked)
        return h

    def _run_hybrid(self, params: Params, x: jax.Array, positions: jax.Array) -> jax.Array:
        """zamba2: segments of SSM layers with a shared attn block between."""
        cfg, rt = self.cfg, self.rt
        every = cfg.shared_attn_every
        n_inv = cfg.n_layers // every

        def body_fn(h, layer_p):
            return _layer_apply(layer_p, h, cfg, rt, positions), None

        if rt.remat:
            body_fn = jax.checkpoint(body_fn)
        h, x0 = x, x
        for inv in range(n_inv):
            delta, _ = _shared_block_apply(params["shared"], h, x0, inv, cfg, rt, positions)
            h = h + delta
            seg = jax.tree.map(lambda a: a[inv * every : (inv + 1) * every], params["layers"])
            if rt.unroll_layers:
                for i in range(every):
                    h, _ = body_fn(h, jax.tree.map(lambda a: a[i], seg))
            else:
                h, _ = jax.lax.scan(body_fn, h, seg)
        # trailing layers not covered by full segments
        rem = cfg.n_layers - n_inv * every
        if rem:
            seg = jax.tree.map(lambda a: a[n_inv * every :], params["layers"])
            h, _ = jax.lax.scan(body_fn, h, seg)
        return h

    # ------------------------------------------------------------ encoder
    def _encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg, rt = self.cfg, self.rt
        x = frames.astype(rt.dtype) + params["encoder"]["pos"][None, : frames.shape[1]]
        positions = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])

        def body_fn(h, layer_p):
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a = _attn_forward(
                layer_p["attn"], hn, cfg, rt, positions=positions, causal=False, use_rope=False
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            return h + apply_mlp(layer_p["mlp"], hn, cfg.activation), None

        h, _ = self._maybe_scan(body_fn, x, params["encoder"]["layers"])
        return apply_norm(params["encoder"]["ln_f"], h, cfg.norm, cfg.norm_eps)

    def _run_decoder_with_cross(
        self, params: Params, x: jax.Array, enc: jax.Array, positions: jax.Array
    ) -> jax.Array:
        cfg, rt = self.cfg, self.rt
        enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None], enc.shape[:2])

        def body_fn(h, ps):
            layer_p, cross_p = ps
            h = h + _mixer_apply(layer_p, h, cfg, rt, positions)
            # cross attention
            hn = apply_norm(cross_p["ln"], h, cfg.norm, cfg.norm_eps)
            q, _, _ = qkv_project(
                cross_p["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
            )
            _, k, v = qkv_project(
                cross_p["attn"], enc, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
            )
            a = attention_scores(
                q, repeat_kv(k, cfg.n_heads), repeat_kv(v, cfg.n_heads),
                causal=False, q_positions=positions, kv_positions=enc_pos,
            )
            a = a.reshape(h.shape[0], h.shape[1], -1) @ cross_p["attn"]["wo"]
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            return h + apply_mlp(layer_p["mlp"], hn, cfg.activation), None

        h, _ = self._maybe_scan(body_fn, x, (params["layers"], params["cross"]))
        return h

    # ------------------------------------------------------------- forward
    def forward(
        self,
        params: Params,
        tokens: jax.Array,
        *,
        frames: Optional[jax.Array] = None,
        patches: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Full-sequence causal forward -> logits (B, S_total, Vp)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if cfg.n_vision_tokens and patches is not None:
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        if cfg.is_encoder_decoder:
            enc = self._encode(params, frames)
            h = self._run_decoder_with_cross(params, x, enc, positions)
        else:
            h = self._run_layers(params, x, positions)
        return self._logits(params, h)

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        """Next-token cross-entropy.  batch: tokens, labels, (frames|patches)."""
        cfg = self.cfg
        logits = self.forward(
            params, batch["tokens"], frames=batch.get("frames"), patches=batch.get("patches")
        )
        labels = batch["labels"]
        if cfg.n_vision_tokens and "patches" in batch:
            logits = logits[:, cfg.n_vision_tokens :]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss, {"loss": loss, "tokens": jnp.sum(mask)}

    # ------------------------------------------------------------ decode
    @property
    def supports_paged_cache(self) -> bool:
        """Can this architecture's decode cache be paged?

        SSM/hybrid state is O(1) per slot (nothing to page), encoder-
        decoder carries a static cross cache, and rolling sliding-window
        caches already bound memory by the window (and their slot->position
        reconstruction is incompatible with page indirection)."""
        cfg = self.cfg
        if cfg.family in ("ssm", "hybrid") or cfg.is_encoder_decoder:
            return False
        return cfg.sliding_window == 0

    def init_cache(
        self,
        batch: int,
        max_len: int,
        dtype=None,
        *,
        paged: bool = False,
        page_size: int = 16,
        n_pages: Optional[int] = None,
    ) -> Params:
        """Decode cache pytree.

        ``paged=True`` replaces the per-slot dense ``max_len`` reservation
        with a shared pool of ``n_pages`` fixed-size pages plus a per-slot
        ``page_table`` (``(batch, max_len/page_size)`` int32).  Table
        entries hold the out-of-bounds sentinel ``n_pages`` until the
        owner (the serving engine's allocator) backs them with a physical
        page; cache memory then scales with tokens actually resident
        instead of ``batch * max_len`` worst case.
        """
        cfg = self.cfg
        dtype = dtype or self.rt.dtype
        L = cfg.n_layers
        hd = cfg.resolved_head_dim
        cache: Params = {}
        if paged:
            if not self.supports_paged_cache:
                raise ValueError(
                    f"paged cache unsupported for arch {cfg.name!r} "
                    "(ssm/hybrid state, enc-dec cross cache, or rolling "
                    "sliding-window cache)"
                )
            ps = int(page_size)
            pages_per_slot = -(-max_len // ps)
            pool = batch * pages_per_slot if n_pages is None else int(n_pages)
            cache["page_table"] = jnp.full((batch, pages_per_slot), pool, jnp.int32)
            if cfg.use_mla:
                width = cfg.kv_lora_rank + cfg.rope_head_dim
                cache["kv_pages"] = jnp.zeros((L, pool, ps, width), dtype)
            else:
                cache["k_pages"] = jnp.zeros((L, pool, ps, cfg.n_kv_heads, hd), dtype)
                cache["v_pages"] = jnp.zeros((L, pool, ps, cfg.n_kv_heads, hd), dtype)
            return cache
        if cfg.family == "ssm":
            cache["state"] = _stack_states(ssm_mod.mamba2_decode_state(cfg, batch, dtype), L)
        elif cfg.family == "hybrid":
            cache["state"] = _stack_states(ssm_mod.mamba2_decode_state(cfg, batch, dtype), L)
            n_inv = L // cfg.shared_attn_every
            hd2 = 2 * cfg.d_model // cfg.n_heads
            cache["shared_k"] = jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads, hd2), dtype)
            cache["shared_v"] = jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads, hd2), dtype)
        elif cfg.use_mla:
            cache["c_kv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype)
            cache["k_rope"] = jnp.zeros((L, batch, max_len, cfg.rope_head_dim), dtype)
        else:
            window = cfg.sliding_window or 0
            t = max_len if window == 0 else min(max_len, _pad128(window + 1))
            cache["k"] = jnp.zeros((L, batch, t, cfg.n_kv_heads, hd), dtype)
            cache["v"] = jnp.zeros((L, batch, t, cfg.n_kv_heads, hd), dtype)
            if cfg.is_encoder_decoder:
                cache["cross_k"] = jnp.zeros(
                    (L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype
                )
                cache["cross_v"] = jnp.zeros(
                    (L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype
                )
        return cache

    def decode_step(
        self, params: Params, cache: Params, tokens: jax.Array, pos: jax.Array
    ) -> Tuple[jax.Array, Params]:
        """One token for every sequence in the batch.

        tokens: (B, 1); pos: scalar or (B,) per-row cache positions (rows
        may be at different depths — continuous batching)."""
        cfg, rt = self.cfg, self.rt
        pos = jnp.asarray(pos, jnp.int32)  # scalar (uniform) or (B,) per-row
        x = self._embed_decode(params, tokens, pos)
        if "page_table" in cache:
            if cfg.use_mla:
                return self._decode_mla_paged(params, cache, x, pos)
            return self._decode_attn_paged(params, cache, x, pos)
        if cfg.family in ("ssm", "hybrid"):
            return self._decode_ssm(params, cache, x, pos)
        if cfg.use_mla:
            return self._decode_mla(params, cache, x, pos)
        return self._decode_attn(params, cache, x, pos)

    def _embed_decode(self, params, tokens, pos):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.max_position_embeddings:
            pos_v = jnp.broadcast_to(pos, (tokens.shape[0],))
            x = x + params["pos"][pos_v][:, None, :]
        return x.astype(self.rt.dtype)

    def _decode_attn(self, params, cache, x, pos):
        cfg, rt = self.cfg, self.rt

        def body_fn(h, xs):
            layer_p, ck, cv, extra = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, ck, cv = _attn_decode(
                layer_p["attn"], hn, ck, cv, pos, cfg,
                use_rope=not cfg.max_position_embeddings,
            )
            h = h + a
            if cfg.is_encoder_decoder:
                cross_p, xk, xv = extra
                hn = apply_norm(cross_p["ln"], h, cfg.norm, cfg.norm_eps)
                q, _, _ = qkv_project(
                    cross_p["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
                )
                enc_t = xk.shape[1]
                a = attention_scores(
                    q, repeat_kv(xk, cfg.n_heads), repeat_kv(xv, cfg.n_heads), causal=False
                )
                a = a.reshape(h.shape[0], 1, -1) @ cross_p["attn"]["wo"]
                h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            if "moe" in layer_p:
                h = h + moe_mod.apply_moe(layer_p["moe"], hn, cfg, rt.moe_strategy)
            else:
                h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, (ck, cv)

        h = x
        new_cache = dict(cache)
        groups = [g for g in ("dense_layers", "layers") if g in params]
        k_parts, v_parts = [], []
        offset = 0
        for group in groups:
            stacked = params[group]
            n = _stack_len(stacked)
            ck = cache["k"][offset : offset + n]
            cv = cache["v"][offset : offset + n]
            if cfg.is_encoder_decoder:
                extra = (params["cross"], cache["cross_k"], cache["cross_v"])
            else:
                extra = (None,) if False else _none_like(n)
            xs = (stacked, ck, cv, extra)
            h, (nk, nv) = self._maybe_scan(body_fn, h, xs)
            k_parts.append(nk)
            v_parts.append(nv)
            offset += n
        new_cache["k"] = jnp.concatenate(k_parts, 0) if len(k_parts) > 1 else k_parts[0]
        new_cache["v"] = jnp.concatenate(v_parts, 0) if len(v_parts) > 1 else v_parts[0]
        return self._logits(params, h), new_cache

    def _decode_attn_paged(self, params, cache, x, pos):
        cfg, rt = self.cfg, self.rt
        b = x.shape[0]
        pos_v = jnp.broadcast_to(pos, (b,))
        table = cache["page_table"]

        def body_fn(h, xs):
            layer_p, kp, vp = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, kp, vp = _attn_decode_paged(
                layer_p["attn"], hn, kp, vp, table, pos_v, cfg, rt,
                use_rope=not cfg.max_position_embeddings,
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            if "moe" in layer_p:
                h = h + moe_mod.apply_moe(layer_p["moe"], hn, cfg, rt.moe_strategy)
            else:
                h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, (kp, vp)

        h = x
        new_cache = dict(cache)
        k_parts, v_parts = [], []
        offset = 0
        for group in ("dense_layers", "layers"):
            if group not in params:
                continue
            stacked = params[group]
            n = _stack_len(stacked)
            xs = (stacked, cache["k_pages"][offset : offset + n],
                  cache["v_pages"][offset : offset + n])
            h, (nk, nv) = self._maybe_scan(body_fn, h, xs)
            k_parts.append(nk)
            v_parts.append(nv)
            offset += n
        new_cache["k_pages"] = jnp.concatenate(k_parts, 0) if len(k_parts) > 1 else k_parts[0]
        new_cache["v_pages"] = jnp.concatenate(v_parts, 0) if len(v_parts) > 1 else v_parts[0]
        return self._logits(params, h), new_cache

    def _decode_mla_paged(self, params, cache, x, pos):
        cfg, rt = self.cfg, self.rt
        b = x.shape[0]
        pos_v = jnp.broadcast_to(pos, (b,))
        table = cache["page_table"]

        def body_fn(h, xs):
            layer_p, kvp = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, kvp = mla_mod.apply_mla_paged(
                layer_p["attn"], hn, kvp, table, pos_v[:, None], cfg,
                impl=rt.resolve_paged_attn(),
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            if "moe" in layer_p:
                h = h + moe_mod.apply_moe(layer_p["moe"], hn, cfg, rt.moe_strategy)
            else:
                h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, kvp

        h = x
        parts = []
        offset = 0
        for group in ("dense_layers", "layers"):
            if group not in params:
                continue
            stacked = params[group]
            n = _stack_len(stacked)
            xs = (stacked, cache["kv_pages"][offset : offset + n])
            h, nkv = self._maybe_scan(body_fn, h, xs)
            parts.append(nkv)
            offset += n
        new_cache = dict(cache)
        new_cache["kv_pages"] = jnp.concatenate(parts, 0) if len(parts) > 1 else parts[0]
        return self._logits(params, h), new_cache

    def _decode_mla(self, params, cache, x, pos):
        cfg, rt = self.cfg, self.rt

        def body_fn(h, xs):
            layer_p, ckv, krope = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, new_c = mla_mod.apply_mla_decode(
                layer_p["attn"], hn, {"c_kv": ckv, "k_rope": krope}, pos, cfg
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            if "moe" in layer_p:
                h = h + moe_mod.apply_moe(layer_p["moe"], hn, cfg, rt.moe_strategy)
            else:
                h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, (new_c["c_kv"], new_c["k_rope"])

        h = x
        c_parts, r_parts = [], []
        offset = 0
        for group in ("dense_layers", "layers"):
            if group not in params:
                continue
            stacked = params[group]
            n = _stack_len(stacked)
            xs = (stacked, cache["c_kv"][offset : offset + n], cache["k_rope"][offset : offset + n])
            h, (nc, nr) = self._maybe_scan(body_fn, h, xs)
            c_parts.append(nc)
            r_parts.append(nr)
            offset += n
        new_cache = dict(cache)
        new_cache["c_kv"] = jnp.concatenate(c_parts, 0) if len(c_parts) > 1 else c_parts[0]
        new_cache["k_rope"] = jnp.concatenate(r_parts, 0) if len(r_parts) > 1 else r_parts[0]
        return self._logits(params, h), new_cache

    def _decode_ssm(self, params, cache, x, pos):
        cfg, rt = self.cfg, self.rt

        def body_fn(h, xs):
            layer_p, st = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            out, new_st = ssm_mod.apply_mamba2_decode(layer_p["mixer"], hn, st, cfg)
            return h + out, new_st

        h = x
        new_cache = dict(cache)
        if cfg.family == "ssm":
            h, new_state = self._maybe_scan(body_fn, h, (params["layers"], cache["state"]))
            new_cache["state"] = new_state
            return self._logits(params, h), new_cache

        # hybrid (zamba2): segments with the shared attention block
        every = cfg.shared_attn_every
        n_inv = cfg.n_layers // every
        x0 = x
        state_parts = []
        sk, sv = [], []
        for inv in range(n_inv):
            delta, (nk, nv) = _shared_block_apply(
                params["shared"], h, x0, inv, cfg, rt,
                positions=None, cache=(cache["shared_k"][inv], cache["shared_v"][inv]), pos=pos,
            )
            h = h + delta
            sk.append(nk[None])
            sv.append(nv[None])
            seg_p = jax.tree.map(lambda a: a[inv * every : (inv + 1) * every], params["layers"])
            seg_s = jax.tree.map(lambda a: a[inv * every : (inv + 1) * every], cache["state"])
            h, new_st = self._maybe_scan(body_fn, h, (seg_p, seg_s))
            state_parts.append(new_st)
        rem = cfg.n_layers - n_inv * every
        if rem:
            seg_p = jax.tree.map(lambda a: a[n_inv * every :], params["layers"])
            seg_s = jax.tree.map(lambda a: a[n_inv * every :], cache["state"])
            h, new_st = self._maybe_scan(body_fn, h, (seg_p, seg_s))
            state_parts.append(new_st)
        new_cache["state"] = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *state_parts)
        new_cache["shared_k"] = jnp.concatenate(sk, 0)
        new_cache["shared_v"] = jnp.concatenate(sv, 0)
        return self._logits(params, h), new_cache

    # ------------------------------------------------------------- prefill
    def prefill(
        self,
        params: Params,
        tokens: jax.Array,
        *,
        frames: Optional[jax.Array] = None,
        patches: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Prefill forward: last-position logits (cache fill is fused into
        the same computation on TPU; the dry-run lowers this step)."""
        logits = self.forward(params, tokens, frames=frames, patches=patches)
        return logits[:, -1:]

    # ------------------------------------------------- fused chunked prefill
    @property
    def supports_fused_prefill(self) -> bool:
        """Can ``prefill_chunk`` ingest this architecture's prompts?

        Encoder-decoder / VLM need side inputs the serving cache does not
        carry, and MoE expert capacity is batch-shaped (right-padded chunk
        tokens would displace real tokens from experts, breaking parity
        with the token-at-a-time path)."""
        cfg = self.cfg
        if cfg.is_encoder_decoder or cfg.n_vision_tokens:
            return False
        if cfg.family == "moe":
            return False
        return True

    def prefill_chunk(
        self,
        params: Params,
        cache: Params,
        tokens: jax.Array,  # (B, T) right-padded prompt chunks
        offsets: jax.Array,  # (B,) cache position of each row's first token
        lengths: jax.Array,  # (B,) valid tokens per row; 0 = inactive row
    ) -> Tuple[jax.Array, Params]:
        """Ingest whole prompt chunks into the decode cache in ONE dispatch.

        Row ``b`` writes ``tokens[b, :lengths[b]]`` at cache positions
        ``offsets[b] .. offsets[b]+lengths[b]-1`` and returns the logits of
        its last valid token (``(B, padded_vocab)``) plus the updated cache
        — exactly what token-at-a-time decode ingestion would have produced,
        at chunk-size tokens per dispatch instead of one.
        """
        b, T = tokens.shape
        lengths = jnp.asarray(lengths, jnp.int32)
        h, new_cache = self._prefill_hidden(params, cache, tokens, offsets, lengths)
        # gather each row's last valid hidden state BEFORE the vocab matmul
        # so the dispatch never materializes (B, T, vocab) logits
        last = jnp.clip(lengths - 1, 0, T - 1)
        h_last = h[jnp.arange(b), last][:, None]  # (b,1,d)
        return self._logits(params, h_last)[:, 0], new_cache

    def verify_chunk(
        self,
        params: Params,
        cache: Params,
        tokens: jax.Array,  # (B, T) right-padded [last accepted, k drafts]
        offsets: jax.Array,  # (B,) cache position of each row's first token
        lengths: jax.Array,  # (B,) valid tokens per row; 0 = inactive row
    ) -> Tuple[jax.Array, Params]:
        """Speculative-verify forward: the same fused chunk-extend as
        :meth:`prefill_chunk` but returning EVERY position's logits
        ``(B, T, padded_vocab)`` instead of only the last valid one.

        Position ``t``'s logits are the target model's distribution for
        the token AFTER ``tokens[b, t]``, conditioned on the cache plus
        ``tokens[b, :t+1]`` (the causal mask inside the extend path) —
        exactly what ``t`` sequential decode steps would produce, so the
        serving engine's acceptance rule can compare each draft against
        the token non-speculative decoding would have emitted.  ``T`` is
        ``spec_k + 1`` (small), so materializing the full logits block is
        cheap relative to the saved dispatches."""
        h, new_cache = self._prefill_hidden(params, cache, tokens, offsets, lengths)
        return self._logits(params, h), new_cache

    def _prefill_hidden(
        self, params: Params, cache: Params, tokens, offsets, lengths
    ) -> Tuple[jax.Array, Params]:
        """Shared chunk-extend backbone: embed + run the architecture's
        extend path, returning all-position hidden states ``(B, T, d)``
        and the updated cache.  Padded positions (``>= lengths[b]``) write
        nothing (valid-masked / OOB-sentinel dropped) and their hidden
        states are garbage the caller must not read."""
        cfg = self.cfg
        if not self.supports_fused_prefill:
            raise NotImplementedError(
                f"fused prefill unsupported for arch family {cfg.family!r} "
                "(enc-dec / vlm / moe)"
            )
        T = tokens.shape[1]
        offsets = jnp.asarray(offsets, jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        positions = offsets[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
        valid = jnp.arange(T, dtype=jnp.int32)[None, :] < lengths[:, None]
        x = params["embed"][tokens]
        if cfg.max_position_embeddings:
            x = x + params["pos"][jnp.clip(positions, 0, cfg.max_position_embeddings - 1)]
        x = x.astype(self.rt.dtype)
        if "page_table" in cache:
            if cfg.use_mla:
                h, new_cache = self._prefill_mla_paged(params, cache, x, positions, valid)
            else:
                h, new_cache = self._prefill_attn_paged(params, cache, x, positions, valid)
        elif cfg.family in ("ssm", "hybrid"):
            h, new_cache = self._prefill_ssm(params, cache, x, positions, lengths, valid)
        elif cfg.use_mla:
            h, new_cache = self._prefill_mla(params, cache, x, positions, valid)
        else:
            h, new_cache = self._prefill_attn(params, cache, x, positions, valid)
        return h, new_cache

    def _prefill_attn(self, params, cache, x, positions, valid):
        cfg, rt = self.cfg, self.rt

        def body_fn(h, xs):
            layer_p, ck, cv = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, ck, cv = _attn_extend(
                layer_p["attn"], hn, ck, cv, positions, cfg,
                use_rope=not cfg.max_position_embeddings, valid=valid,
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, (ck, cv)

        h = x
        new_cache = dict(cache)
        k_parts, v_parts = [], []
        offset = 0
        for group in ("dense_layers", "layers"):
            if group not in params:
                continue
            stacked = params[group]
            n = _stack_len(stacked)
            xs = (stacked, cache["k"][offset : offset + n], cache["v"][offset : offset + n])
            h, (nk, nv) = self._maybe_scan(body_fn, h, xs)
            k_parts.append(nk)
            v_parts.append(nv)
            offset += n
        new_cache["k"] = jnp.concatenate(k_parts, 0) if len(k_parts) > 1 else k_parts[0]
        new_cache["v"] = jnp.concatenate(v_parts, 0) if len(v_parts) > 1 else v_parts[0]
        return h, new_cache

    def _prefill_attn_paged(self, params, cache, x, positions, valid):
        cfg, rt = self.cfg, self.rt
        table = cache["page_table"]

        def body_fn(h, xs):
            layer_p, kp, vp = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, kp, vp = _attn_extend_paged(
                layer_p["attn"], hn, kp, vp, table, positions, cfg, rt,
                use_rope=not cfg.max_position_embeddings, valid=valid,
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, (kp, vp)

        h, (nk, nv) = self._maybe_scan(
            body_fn, x, (params["layers"], cache["k_pages"], cache["v_pages"])
        )
        new_cache = dict(cache)
        new_cache["k_pages"] = nk
        new_cache["v_pages"] = nv
        return h, new_cache

    def _prefill_mla_paged(self, params, cache, x, positions, valid):
        cfg, rt = self.cfg, self.rt
        table = cache["page_table"]

        def body_fn(h, xs):
            layer_p, kvp = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, kvp = mla_mod.apply_mla_paged(
                layer_p["attn"], hn, kvp, table, positions, cfg,
                impl=rt.resolve_paged_attn(), valid=valid,
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, kvp

        h, nkv = self._maybe_scan(body_fn, x, (params["layers"], cache["kv_pages"]))
        new_cache = dict(cache)
        new_cache["kv_pages"] = nkv
        return h, new_cache

    def _prefill_mla(self, params, cache, x, positions, valid):
        cfg = self.cfg

        def body_fn(h, xs):
            layer_p, ckv, krope = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            a, new_c = mla_mod.apply_mla_extend(
                layer_p["attn"], hn, {"c_kv": ckv, "k_rope": krope}, positions, cfg,
                valid=valid,
            )
            h = h + a
            hn = apply_norm(layer_p["ln2"], h, cfg.norm, cfg.norm_eps)
            h = h + apply_mlp(layer_p["mlp"], hn, cfg.activation)
            return h, (new_c["c_kv"], new_c["k_rope"])

        h, (nc, nr) = self._maybe_scan(
            body_fn, x, (params["layers"], cache["c_kv"], cache["k_rope"])
        )
        new_cache = dict(cache)
        new_cache["c_kv"] = nc
        new_cache["k_rope"] = nr
        return h, new_cache

    def _prefill_ssm(self, params, cache, x, positions, lengths, valid):
        cfg, rt = self.cfg, self.rt

        def body_fn(h, xs):
            layer_p, st = xs
            hn = apply_norm(layer_p["ln1"], h, cfg.norm, cfg.norm_eps)
            out, new_st = ssm_mod.apply_mamba2_prefill(
                layer_p["mixer"], hn, st, cfg, valid=valid, lengths=lengths
            )
            return h + out, new_st

        h = x
        new_cache = dict(cache)
        if cfg.family == "ssm":
            h, new_state = self._maybe_scan(body_fn, h, (params["layers"], cache["state"]))
            new_cache["state"] = new_state
            return h, new_cache

        # hybrid (zamba2): shared attention block between SSM segments
        every = cfg.shared_attn_every
        n_inv = cfg.n_layers // every
        x0 = x
        state_parts, sk, sv = [], [], []
        for inv in range(n_inv):
            delta, (nk, nv) = _shared_block_apply(
                params["shared"], h, x0, inv, cfg, rt,
                positions=positions,
                cache=(cache["shared_k"][inv], cache["shared_v"][inv]),
                valid=valid,
            )
            h = h + delta
            sk.append(nk[None])
            sv.append(nv[None])
            seg_p = jax.tree.map(lambda a: a[inv * every : (inv + 1) * every], params["layers"])
            seg_s = jax.tree.map(lambda a: a[inv * every : (inv + 1) * every], cache["state"])
            h, new_st = self._maybe_scan(body_fn, h, (seg_p, seg_s))
            state_parts.append(new_st)
        rem = cfg.n_layers - n_inv * every
        if rem:
            seg_p = jax.tree.map(lambda a: a[n_inv * every :], params["layers"])
            seg_s = jax.tree.map(lambda a: a[n_inv * every :], cache["state"])
            h, new_st = self._maybe_scan(body_fn, h, (seg_p, seg_s))
            state_parts.append(new_st)
        new_cache["state"] = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *state_parts)
        new_cache["shared_k"] = jnp.concatenate(sk, 0)
        new_cache["shared_v"] = jnp.concatenate(sv, 0)
        return h, new_cache


# ----------------------------------------------------------------- helpers
def _stack_init(keys, init_fn):
    return jax.vmap(init_fn)(keys)


def _stack_len(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def _stack_states(state: Params, n: int) -> Params:
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), state)


def _none_like(n: int):
    # placeholder pytree broadcastable through scan xs (unused branch)
    return (jnp.zeros((n, 1)), jnp.zeros((n, 1)), jnp.zeros((n, 1)))


def _pad128(x: int) -> int:
    return ((x + 127) // 128) * 128


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (prefer multiples of 128)."""
    best = 1
    for c in range(min(target, s), 0, -1):
        if s % c == 0:
            if c % 128 == 0:
                return c
            if best == 1:
                best = c  # best non-128-aligned fallback so far
    return best

"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a per-token latent ``c_kv`` of rank ``kv_lora_rank``
plus a single shared RoPE key of ``rope_head_dim``; queries go through
their own low-rank path.  The decode cache stores only
``(kv_lora_rank + rope_head_dim)`` per token — the paper's 93% KV-cache
reduction — and attention against the cache is computed in latent space
by *absorbing* ``k_up`` into the query (so the cache is never expanded to
per-head keys at decode time).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_norm, apply_rope, dense_init, norm_init
from repro.sharding.logical import shard

Params = Dict[str, jax.Array]


def mla_init(key, cfg: ArchConfig, dtype, depth_scale: float) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.nope_head_dim
    qr = cfg.rope_head_dim
    v = cfg.v_head_dim
    ks = jax.random.split(key, 7)
    return {
        "q_down": dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": norm_init(cfg.q_lora_rank, cfg.norm, dtype),
        "q_up": dense_init(ks[1], cfg.q_lora_rank, h * (qk + qr), dtype),
        "kv_down": dense_init(ks[2], d, cfg.kv_lora_rank + qr, dtype),
        "kv_norm": norm_init(cfg.kv_lora_rank, cfg.norm, dtype),
        "k_up": dense_init(ks[3], cfg.kv_lora_rank, h * qk, dtype),
        "v_up": dense_init(ks[4], cfg.kv_lora_rank, h * v, dtype),
        "wo": dense_init(ks[5], h * v, d, dtype, scale=depth_scale),
    }


def _project_q(p: Params, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    b, s, _ = x.shape
    h, qk, qr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    q = apply_norm(p["q_norm"], x @ p["q_down"], cfg.norm, cfg.norm_eps) @ p["q_up"]
    q = q.reshape(b, s, h, qk + qr)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _compress_kv(p: Params, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    """Returns (c_kv (b,s,r), k_rope (b,s,qr)) — exactly what decode caches."""
    kv = x @ p["kv_down"]
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
    c_kv = apply_norm(p["kv_norm"], c_kv, cfg.norm, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def apply_mla(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence (train / prefill) MLA with causal masking."""
    b, s, _ = x.shape
    h, qk, qr, vd = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :]

    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _compress_kv(p, x, cfg, positions)

    k_nope = (c_kv @ p["k_up"]).reshape(b, s, h, qk)
    v = (c_kv @ p["v_up"]).reshape(b, s, h, vd)
    k_nope = shard(k_nope, "batch", "seq", "heads", "head_dim")
    v = shard(v, "batch", "seq", "heads", "head_dim")

    scale = 1.0 / math.sqrt(qk + qr)
    logits = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope, preferred_element_type=jnp.float32)
    ) * scale
    logits = shard(logits, "batch", "heads", None, "kv_seq")
    qpos = positions[:, None, :, None]
    kpos = positions[:, None, None, :]
    logits = jnp.where(kpos <= qpos, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(b, s, h * vd)
    return shard(out @ p["wo"], "batch", "seq", "embed")


def mla_decode_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
    }


def apply_mla_extend(
    p: Params,
    x: jax.Array,  # (b, T, d) chunk of new tokens
    cache: Dict[str, jax.Array],
    positions: jax.Array,  # (b, T) absolute cache positions of the chunk
    cfg: ArchConfig,
    *,
    valid: Optional[jax.Array] = None,  # (b, T) real (non-padded) tokens
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked-prefill MLA: T new tokens per row against the compressed
    cache in one shot (absorbed form, same math as ``apply_mla_decode``).

    Right-padded tokens (``valid`` False) have their writes redirected
    out of bounds, where JAX drops them — the cache only ever receives
    real tokens, and the ``slot <= q_pos`` mask supplies causality.
    """
    b, T, _ = x.shape
    h, qk, qr, vd, r = (
        cfg.n_heads,
        cfg.nope_head_dim,
        cfg.rope_head_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    q_nope, q_rope = _project_q(p, x, cfg, positions)  # (b,T,h,*)
    c_new, kr_new = _compress_kv(p, x, cfg, positions)  # (b,T,r), (b,T,qr)

    rows = jnp.arange(b)[:, None]
    t_cache = cache["c_kv"].shape[1]
    write = positions
    if valid is not None:
        write = jnp.where(valid, write, t_cache)  # out of bounds -> dropped
    c_kv = cache["c_kv"].at[rows, write].set(c_new.astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[rows, write].set(kr_new.astype(cache["k_rope"].dtype))
    c_kv = shard(c_kv, "cache_batch", "kv_seq", None)
    k_rope = shard(k_rope, "cache_batch", "kv_seq", None)

    k_up = p["k_up"].reshape(r, h, qk)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, k_up)

    scale = 1.0 / math.sqrt(qk + qr)
    t = c_kv.shape[1]
    logits = (
        jnp.einsum("bqhr,btr->bhqt", q_lat, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,btd->bhqt", q_rope, k_rope, preferred_element_type=jnp.float32)
    ) * scale
    mask = jnp.arange(t)[None, None, None, :] <= positions[:, None, :, None]  # (b,1,T,t)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)

    ctx = jnp.einsum("bhqt,btr->bqhr", probs, c_kv)
    v_up = p["v_up"].reshape(r, h, vd)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, v_up).reshape(b, T, h * vd)
    return shard(out @ p["wo"], "batch", "seq", "embed"), {"c_kv": c_kv, "k_rope": k_rope}


def mla_page_width(cfg: ArchConfig) -> int:
    """Columns per paged-cache slot: latent ``c_kv`` + shared RoPE key."""
    return cfg.kv_lora_rank + cfg.rope_head_dim


def apply_mla_paged(
    p: Params,
    x: jax.Array,  # (b, T, d) — T = 1 is decode, T > 1 chunk-extend
    kv_pages: jax.Array,  # (n_pages, ps, r + qr) — this layer's page pool
    page_table: jax.Array,  # (b, P) int32; entries >= n_pages = unallocated
    positions: jax.Array,  # (b, T) absolute cache positions
    cfg: ArchConfig,
    *,
    impl: str = "jnp",
    valid: Optional[jax.Array] = None,  # (b, T) real (non-padded) tokens
) -> Tuple[jax.Array, jax.Array]:
    """MLA decode / chunk-extend against a *paged* compressed cache.

    Each cache slot stores ``concat(c_kv, k_rope)``; the absorbed-form
    score ``q_lat . c_kv + q_rope . k_rope`` is a single dot against that
    concatenated slot, so the paged flash kernel serves MLA as its
    ``Hkv = 1`` case with values read from the first ``kv_lora_rank``
    columns of the shared page (``v_width``).  The jnp fallback keeps the
    two score terms as separate einsums so it is numerically identical to
    the dense ``apply_mla_decode`` path (token parity with ``cache_mode=
    'dense'``).  Writes for padded/parked rows go to the out-of-bounds
    page sentinel and are dropped.
    """
    b, T, _ = x.shape
    h, qk, qr, vd, r = (
        cfg.n_heads,
        cfg.nope_head_dim,
        cfg.rope_head_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    q_nope, q_rope = _project_q(p, x, cfg, positions)  # (b,T,h,*)
    c_new, kr_new = _compress_kv(p, x, cfg, positions)  # (b,T,r), (b,T,qr)
    kv_new = jnp.concatenate([c_new, kr_new], axis=-1)  # (b,T,r+qr)

    n_pages, ps = kv_pages.shape[0], kv_pages.shape[1]
    P = page_table.shape[1]
    rows = jnp.arange(b)[:, None]
    wp = page_table[rows, jnp.minimum(positions // ps, P - 1)]  # (b,T)
    if valid is not None:
        wp = jnp.where(valid, wp, n_pages)  # out of bounds -> dropped
    kv_pages = kv_pages.at[wp, positions % ps].set(kv_new.astype(kv_pages.dtype))

    k_up = p["k_up"].reshape(r, h, qk)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, k_up)  # (b,T,h,r)
    scale = 1.0 / math.sqrt(qk + qr)

    if impl == "kernel":
        from repro.kernels import ops as kops

        q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)  # (b,T,h,r+qr)
        ctx = kops.paged_attention(
            q_eff, kv_pages[:, :, None, :], kv_pages[:, :, None, :],
            page_table, positions[:, 0], scale=scale, v_width=r,
        )  # (b,T,h,r)
    else:
        safe = jnp.minimum(page_table, n_pages - 1)
        kv_full = kv_pages[safe].reshape(b, P * ps, r + qr)
        logits = (
            jnp.einsum("bqhr,btr->bhqt", q_lat, kv_full[..., :r],
                       preferred_element_type=jnp.float32)
            + jnp.einsum("bqhd,btd->bhqt", q_rope, kv_full[..., r:],
                         preferred_element_type=jnp.float32)
        ) * scale
        kv_pos = jnp.arange(P * ps)[None, None, None, :]
        alloc = jnp.repeat(page_table < n_pages, ps, axis=1)  # (b, P*ps)
        mask = jnp.logical_and(
            kv_pos <= positions[:, None, :, None], alloc[:, None, None, :]
        )
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqt,btr->bqhr", probs, kv_full[..., :r])

    v_up = p["v_up"].reshape(r, h, vd)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, v_up).reshape(b, T, h * vd)
    return shard(out @ p["wo"], "batch", "seq", "embed"), kv_pages


def apply_mla_decode(
    p: Params,
    x: jax.Array,  # (b, 1, d)
    cache: Dict[str, jax.Array],
    pos: jax.Array,  # () current position
    cfg: ArchConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step against the compressed cache (absorbed form).

    score(t) = q_nope·(c_kv[t] K_up)  + q_rope·k_rope[t]
             = (q_nope K_upᵀ)·c_kv[t] + q_rope·k_rope[t]     # absorb k_up
    out      = softmax·(c_kv V_up)    = (softmax·c_kv) V_up  # absorb v_up
    """
    b = x.shape[0]
    h, qk, qr, vd, r = (
        cfg.n_heads,
        cfg.nope_head_dim,
        cfg.rope_head_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    pos = jnp.asarray(pos, jnp.int32)
    uniform_pos = pos.ndim == 0
    pos_v = jnp.broadcast_to(pos, (b,))
    positions = pos_v[:, None]
    q_nope, q_rope = _project_q(p, x, cfg, positions)  # (b,1,h,*)
    c_new, kr_new = _compress_kv(p, x, cfg, positions)  # (b,1,r), (b,1,qr)

    if uniform_pos:  # scalar write partitions cleanly (see transformer.py)
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0)
        )
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0)
        )
    else:
        rows = jnp.arange(b)
        c_kv = cache["c_kv"].at[rows, pos_v].set(c_new[:, 0].astype(cache["c_kv"].dtype))
        k_rope = cache["k_rope"].at[rows, pos_v].set(kr_new[:, 0].astype(cache["k_rope"].dtype))
    c_kv = shard(c_kv, "cache_batch", "kv_seq", None)
    k_rope = shard(k_rope, "cache_batch", "kv_seq", None)

    # absorb k_up into q: (b,1,h,qk) @ (r, h, qk) -> (b,h,r)
    k_up = p["k_up"].reshape(r, h, qk)
    q_lat = jnp.einsum("bqhd,rhd->bhr", q_nope, k_up)  # q=1 squeezed

    scale = 1.0 / math.sqrt(qk + qr)
    t = c_kv.shape[1]
    logits = (
        jnp.einsum("bhr,btr->bht", q_lat, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhd,btd->bht", q_rope, k_rope, preferred_element_type=jnp.float32)
    ) * scale
    mask = jnp.arange(t)[None, None, :] <= pos_v[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)

    # absorbed value path: (b,h,t)·(b,t,r) -> (b,h,r), then V_up
    ctx = jnp.einsum("bht,btr->bhr", probs, c_kv)
    v_up = p["v_up"].reshape(r, h, vd)
    out = jnp.einsum("bhr,rhd->bhd", ctx, v_up).reshape(b, 1, h * vd)
    return shard(out @ p["wo"], "batch", "seq", "embed"), {"c_kv": c_kv, "k_rope": k_rope}

"""Expert-parallel MoE via shard_map (the §Perf iteration for MoE cells).

Under pure GSPMD, the sort-based dispatch makes the partitioner give up
on the gather/combine indexing and replicate token activations —
measured 62.7 TB of all-reduces per step for deepseek-v2 train_4k
(EXPERIMENTS §Perf 2.x).  This layer takes manual control:

    per (data, model) device:
      1. router logits: partial matmul over the fsdp-sharded router + psum
      2. slice the local tokens by model rank (each routes T/ntp tokens)
      3. local gather-based dispatch -> (E, C, D)
      4. all_to_all over 'model'     -> (E_loc, C*ntp, D)   [true EP]
      5. all-gather expert weights over 'data' (FSDP, layer-at-a-time)
      6. local expert FFN
      7. all_to_all back, local combine, all-gather token slices
    backward: shard_map is differentiable; the weight all-gathers
    transpose to reduce-scatters, i.e. ZeRO-sharded expert gradients.

All collectives are activation-sized except the per-layer weight
gathers, which match dense-FSDP behaviour.  Falls back to the GSPMD
gather path when experts don't divide the tp axis (mixtral: 8 on 16) or
no mesh rules are active (CPU smoke tests).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.models.layers import GATED, apply_mlp
from repro.sharding import logical as L

Params = Dict[str, jax.Array]


def shardmap_applicable(cfg: ArchConfig, x_shape) -> bool:
    ctx = L._current()
    if ctx is None:
        return False
    mesh, rules = ctx
    if "model" not in mesh.shape:
        return False
    ntp = mesh.shape["model"]
    if cfg.n_experts % ntp:
        return False
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    b, s, _ = x_shape
    if b % ndp:
        return False
    t_block = (b // ndp) * s
    return t_block % ntp == 0


def apply_moe_shardmap(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    mesh, rules = L._current()
    tp = "model"
    ntp = mesh.shape[tp]
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // ntp
    b, s, d = x.shape
    f = cfg.moe_d_ff
    gated = cfg.activation in GATED

    t_block = (b // ndp) * s
    t_slice = t_block // ntp
    cap = max(1, int(t_slice * k / e * cfg.capacity_factor + 0.999))

    has_fsdp = len(dp) > 0

    def fn(router_b, wi_b, wg_b, wo_b, xb):
        # xb: (b_loc, s, d); router_b: (d/ndp, e); w*_b: (e_loc, d or f /ndp, ...)
        tpi = jax.lax.axis_index(tp)
        xt = xb.reshape(-1, d)

        # 1. routing: gather the (tiny) fsdp-sliced router, then local
        # logits.  NOTE a partial-contraction + psum over 'data' would be
        # WRONG here: tokens differ across data ranks, so partial logits
        # of different tokens must never be summed (refuted iteration 2.2).
        router_full = router_b
        for a in reversed(dp):
            router_full = jax.lax.all_gather(router_full, a, axis=0, tiled=True)
        logits = xt.astype(jnp.float32) @ router_full
        probs = jax.nn.softmax(logits, axis=-1)
        gates_all, ids_all = jax.lax.top_k(probs, k)
        gates_all = gates_all / jnp.sum(gates_all, axis=-1, keepdims=True)

        # 2. this model-rank routes its slice of the local tokens
        xs = jax.lax.dynamic_slice_in_dim(xt, tpi * t_slice, t_slice, axis=0)
        gates = jax.lax.dynamic_slice_in_dim(gates_all, tpi * t_slice, t_slice, axis=0)
        ids = jax.lax.dynamic_slice_in_dim(ids_all, tpi * t_slice, t_slice, axis=0)

        # 3. local gather-based dispatch (same scheme as moe.apply_moe_gather)
        flat_ids = ids.reshape(t_slice * k).astype(jnp.int32)
        flat_tok = jnp.repeat(jnp.arange(t_slice, dtype=jnp.int32), k)
        order = jnp.argsort(flat_ids, stable=True)
        sorted_ids = flat_ids[order]
        sorted_tok = flat_tok[order]
        first = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
        pos_in_e = jnp.arange(t_slice * k, dtype=jnp.int32) - first.astype(jnp.int32)
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e, dtype=jnp.int32), side="left")
        ends = jnp.searchsorted(sorted_ids, jnp.arange(e, dtype=jnp.int32), side="right")
        slot_p = starts[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
        slot_valid = slot_p < ends[:, None]
        slot_tok = sorted_tok[jnp.clip(slot_p, 0, t_slice * k - 1)]
        buf = jnp.where(slot_valid[..., None], xs[slot_tok], jnp.zeros((), xs.dtype))

        # 4. expert-parallel all_to_all (tiled=True keeps ranks stable and
        # has a clean VJP): (E, C, D) -> (E/ntp, C*ntp, D), received
        # chunks concatenated along C in source-rank order
        buf = jax.lax.all_to_all(buf, tp, split_axis=0, concat_axis=1, tiled=True)

        # 5. FSDP weight gather (layer-at-a-time; bwd = reduce-scatter grads)
        def gather_w(wb):
            if wb is None:
                return None
            w = wb
            for a in reversed(dp):
                w = jax.lax.all_gather(w, a, axis=1, tiled=True)
            return w

        wi = gather_w(wi_b)
        wg = gather_w(wg_b)
        wo_g = wo_b
        for a in reversed(dp):
            wo_g = jax.lax.all_gather(wo_g, a, axis=1, tiled=True)

        # 6. local expert FFN on (E_loc, C*ntp, D)
        h = jnp.einsum("ecd,edf->ecf", buf, wi)
        if cfg.activation == "silu":
            h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, wg)
        elif cfg.activation == "gelu_gated":
            h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", buf, wg)
        elif cfg.activation == "gelu":
            h = jax.nn.gelu(h)
        else:  # relu2
            h = jnp.square(jax.nn.relu(h))
        ye = jnp.einsum("ecf,efd->ecd", h, wo_g)

        # 7. all_to_all back: (e_loc, C*ntp, D) -> (E, C, D), expert ids
        # group-major again on the owning rank
        ye = jax.lax.all_to_all(ye, tp, split_axis=1, concat_axis=0, tiled=True)
        inv = jnp.argsort(order)
        entry_pos = pos_in_e[inv].reshape(t_slice, k)
        kept = entry_pos < cap
        y_gath = ye[ids, jnp.clip(entry_pos, 0, cap - 1)]  # (t_slice, k, d)
        w_g = jnp.where(kept, gates, 0.0).astype(jnp.float32)
        ys = jnp.einsum("tkd,tk->td", y_gath.astype(jnp.float32), w_g).astype(xb.dtype)

        # 8. reassemble the block's tokens across model ranks
        y = jax.lax.all_gather(ys, tp, axis=0, tiled=True)  # (t_block, d)
        return y.reshape(xb.shape)

    router_spec = P(dp if has_fsdp else None, None)
    w_spec = P(tp, dp if has_fsdp else None, None)
    in_specs = (router_spec, w_spec, w_spec if gated else P(), w_spec, P(dp, None, None))
    out_specs = P(dp, None, None)

    fn_mapped = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
    wg = p.get("wg") if gated else jnp.zeros((), x.dtype)
    y = fn_mapped(p["router"], p["wi"], wg, p["wo"], x)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg.activation)
    return y

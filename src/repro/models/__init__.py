"""Model substrate: layers, attention variants, MoE, SSM, unified builder."""
from repro.models.transformer import Model, ModelRuntime  # noqa: F401

"""Buffer-centric HBM-traffic model from post-optimization HLO.

XLA's ``cost_analysis()['bytes accessed']`` sums *pre-fusion* op bytes —
a wild overestimate of HBM traffic (fused temporaries never leave
VMEM/registers).  Instead we parse the compiled module's ENTRY
computation, where every def line is a buffer that actually
materializes, and charge:

    traffic(buffer) = bytes x (1 write + n_uses reads)

(parameters get reads only; constants are skipped; fusion internals are
invisible, which is the point).  This matches how roofline tools count
DRAM traffic for an optimized graph.

Additionally we isolate **quadratic attention buffers** (trailing dims
S_q x S_kv, both large): the pure-jnp attention path materializes the
score/prob matrices in HBM, the Pallas flash kernel keeps them in VMEM
tiles.  Both figures are reported:

    bytes_jnp   — as lowered (the dry-run artifact)
    bytes_flash — bytes_jnp - quadratic-buffer traffic (the TPU hot path)

Known bias (documented in EXPERIMENTS.md): fusion decisions come from the
CPU XLA pipeline; TPU fusion differs in detail but not in buffer-level
structure.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<id>[\w.\-]+)\s*=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*(?P<op>[\w\-]+)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_REF_RE = re.compile(r"%([\w.\-]+)")

_SKIP_OPS = {
    "constant", "iota", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "parameter",
}


def _shape_bytes_dims(text: str) -> Tuple[int, Tuple[Tuple[int, ...], ...]]:
    total = 0
    dims_list = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d.strip())
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_list.append(shape)
    return total, tuple(dims_list)


def _entry_text(hlo: str) -> str:
    # ENTRY computation block: from "ENTRY" to its closing brace
    start = hlo.find("ENTRY ")
    if start < 0:
        return hlo
    end = hlo.find("\n}", start)
    return hlo[start : end + 2 if end > 0 else len(hlo)]


@dataclass
class HBMTraffic:
    bytes_jnp: float
    bytes_flash: float
    quadratic_bytes: float
    n_buffers: int
    has_while: bool


def hbm_traffic(hlo: str, *, quad_threshold: int = 1024) -> HBMTraffic:
    entry = _entry_text(hlo)
    lines = entry.splitlines()

    defs: Dict[str, Tuple[int, bool]] = {}  # id -> (bytes, is_quadratic)
    writes: Dict[str, int] = {}
    op_of: Dict[str, str] = {}
    has_while = "while(" in entry or " while(" in entry

    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        bid, shape_txt, op = m.group("id"), m.group("shape"), m.group("op")
        nbytes, dims_list = _shape_bytes_dims(shape_txt)
        quad = any(
            len(s) >= 2 and s[-1] >= quad_threshold and s[-2] >= quad_threshold
            for s in dims_list
        )
        defs[bid] = (nbytes, quad)
        op_of[bid] = op
        writes[bid] = 0 if op in ("parameter", "constant", "iota") else 1

    uses: Dict[str, int] = {bid: 0 for bid in defs}
    for ln in lines:
        m = _DEF_RE.match(ln)
        def_id = m.group("id") if m else None
        for ref in _REF_RE.findall(ln):
            if ref in uses and ref != def_id:
                uses[ref] += 1

    total = 0.0
    quad_total = 0.0
    n_buffers = 0
    for bid, (nbytes, quad) in defs.items():
        op = op_of[bid]
        if op in ("constant",):
            continue
        if op in ("tuple", "get-tuple-element", "bitcast"):
            continue  # aliases, no data movement
        t = nbytes * (writes[bid] + uses[bid])
        total += t
        n_buffers += 1
        if quad and op not in ("parameter",):
            quad_total += t
    return HBMTraffic(
        bytes_jnp=total,
        bytes_flash=total - quad_total,
        quadratic_bytes=quad_total,
        n_buffers=n_buffers,
        has_while=has_while,
    )

"""Three-term roofline from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device  / peak_FLOP/s          (197e12, bf16)
    memory term     = HLO_bytes_per_device  / HBM_bw               (819e9 B/s)
    collective term = collective_bytes_per_device / ICI_bw         (50e9 B/s/link)

Methodology (verified by probe, see DESIGN.md §4): XLA's
``compiled.cost_analysis()`` reports per-device numbers for the ENTRY
computation only — ops inside ``scan``/``while`` bodies are invisible.
Production cells lower layer stacks as ``scan`` (for compile time), so
roofline numbers come from **unrolled differencing probes**: the same
step lowered at two reduced depths (L1 < L2) with ``unroll_layers=True``,
``microbatches=1`` and ``attn_impl='direct'`` (no inner scans anywhere):

    per_layer = (cost(L2) - cost(L1)) / (L2 - L1)
    total(L)  = cost(L1) + per_layer * (L - L1)

The differencing cancels the fixed embed/lm-head/loss/optimizer terms
into ``cost(L1)`` exactly.  Collective bytes are parsed from the probes'
``compiled.as_text()`` with ring-algorithm per-device byte formulas and
the same extrapolation.

Known accounting conventions (stated in EXPERIMENTS.md):
- attention FLOPs count the full S x S rectangle (both the direct and the
  chunked jnp paths compute it); MODEL_FLOPS uses the causal-optimal
  count, so the useful-compute ratio surfaces the 2x attention headroom
  that the Pallas flash kernel's tile-skipping recovers on TPU;
- bytes come from the mb=1 probe: microbatched production steps re-read
  parameters once per microbatch; the memory term is therefore a lower
  bound for mb > 1 (discussed in §Perf).
"""

from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeSpec

TPU_V5E = {
    "peak_flops": 197e12,
    "hbm_bytes": 16 * 1024**3,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    return default


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    def __sub__(self, other: "CollectiveStats") -> "CollectiveStats":
        ops = set(self.bytes_by_op) | set(other.bytes_by_op)
        return CollectiveStats(
            {o: self.bytes_by_op.get(o, 0.0) - other.bytes_by_op.get(o, 0.0) for o in ops},
            {o: self.count_by_op.get(o, 0) - other.count_by_op.get(o, 0) for o in ops},
        )

    def scaled_add(self, other: "CollectiveStats", k: float) -> "CollectiveStats":
        ops = set(self.bytes_by_op) | set(other.bytes_by_op)
        return CollectiveStats(
            {o: self.bytes_by_op.get(o, 0.0) + k * other.bytes_by_op.get(o, 0.0) for o in ops},
            {o: self.count_by_op.get(o, 0) + int(k) * other.count_by_op.get(o, 0) for o in ops},
        )


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device bytes moved over ICI, ring-algorithm convention:

        all-gather:         (g-1)/g * result_bytes
        reduce-scatter:     (g-1)   * result_bytes      (input = g * result)
        all-reduce:         2 * (g-1)/g * result_bytes
        all-to-all:         (g-1)/g * result_bytes
        collective-permute: result_bytes
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("result"))
        g = max(_group_size(line, n_devices), 1)
        if op == "all-gather":
            b = rb * (g - 1) / g
        elif op == "reduce-scatter":
            b = rb * (g - 1)
        elif op == "all-reduce":
            b = 2.0 * rb * (g - 1) / g
        elif op == "all-to-all":
            b = rb * (g - 1) / g
        else:  # collective-permute
            b = float(rb)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + b
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


# ---------------------------------------------------------------- roofline core
@dataclass
class ProbeCost:
    flops: float
    bytes: float  # HBM-model bytes with the flash correction (bytes_flash)
    collectives: CollectiveStats
    bytes_jnp: float = 0.0  # as-lowered (quadratic attention in HBM)
    quadratic_bytes: float = 0.0


@dataclass
class RooflineResult:
    arch: str
    shape: str
    n_layers: int
    probe_layers: Tuple[int, int]
    flops: float  # per device, extrapolated
    bytes: float  # HBM model, flash-corrected
    collective: CollectiveStats
    model_flops_global: float
    n_devices: int
    bytes_jnp: float = 0.0
    hw: Dict = field(default_factory=lambda: dict(TPU_V5E))

    @property
    def compute_s(self) -> float:
        return self.flops / self.hw["peak_flops"]

    @property
    def memory_s(self) -> float:
        return self.bytes / self.hw["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.collective.total_bytes / self.hw["ici_bw"]

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_per_device(self) -> float:
        return self.model_flops_global / self.n_devices

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (remat/masking/redundancy waste)."""
        return self.model_flops_per_device / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the modeled bound: useful compute time over
        the dominating term (perfect overlap assumption)."""
        ideal = self.model_flops_per_device / self.hw["peak_flops"]
        return ideal / max(self.bound_s, 1e-30)

    def to_json(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "n_layers": self.n_layers,
            "probe_layers": list(self.probe_layers),
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes,
            "bytes_per_device_jnp": self.bytes_jnp,
            "collective_bytes_per_device": self.collective.total_bytes,
            "collective_by_op": self.collective.bytes_by_op,
            "collective_counts": self.collective.count_by_op,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_devices": self.n_devices,
        }


def extrapolate(
    c1: ProbeCost, c2: ProbeCost, l1: int, l2: int, n_layers: int
) -> Tuple[float, float, float, CollectiveStats]:
    span = max(l2 - l1, 1)
    df = (c2.flops - c1.flops) / span
    db = (c2.bytes - c1.bytes) / span
    dbj = (c2.bytes_jnp - c1.bytes_jnp) / span
    dc = c2.collectives - c1.collectives
    dc = CollectiveStats(
        {o: v / span for o, v in dc.bytes_by_op.items()},
        {o: v // span for o, v in dc.count_by_op.items()},
    )
    rem = n_layers - l1
    flops = c1.flops + df * rem
    bytes_ = c1.bytes + db * rem
    bytes_jnp = c1.bytes_jnp + dbj * rem
    coll = c1.collectives.scaled_add(dc, rem)
    return flops, bytes_, bytes_jnp, coll


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Global MODEL_FLOPS per step: 6·N_active·tokens for training,
    2·N_active·batch (+attention term) per decode step."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return cfg.flops_per_token(shape.seq_len, decode=False) * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        # forward only = 1/3 of the 6N convention
        return cfg.flops_per_token(shape.seq_len, decode=False) * tokens / 3.0
    return cfg.flops_per_token(shape.seq_len, decode=True) * shape.global_batch

"""Gradient compression utilities.

Microbatch gradient accumulation in int8 with **stochastic rounding**
(unbiased: E[q(x)] = x), used by the train step's accumulation loop, plus
a bf16-reduction option for the cross-replica gradient sum.  On a real
multi-pod fabric the same quantize/dequantize pair wraps the
``psum_scatter`` in the shard_map trainer — the compression math and its
error bounds are what the tests pin down.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Pytree = Any

QBLOCK = 256


def stochastic_round_int8(x: jax.Array, key: jax.Array) -> Dict[str, jax.Array]:
    """Blockwise (last-dim) int8 with stochastic rounding."""
    x = x.astype(jnp.float32)
    shape = x.shape if x.ndim else (1,)
    d = shape[-1]
    nb = max(1, -(-d // QBLOCK))
    pad = nb * QBLOCK - d
    xp = jnp.pad(x.reshape(shape), [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = xp.reshape(shape[:-1] + (nb, QBLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1) / 127.0, 1e-20)
    y = blocks / scale[..., None]
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(key, y.shape)
    q = lo + (u < frac).astype(jnp.float32)
    q = jnp.clip(q, -127, 127)
    q = q.reshape(shape[:-1] + (nb * QBLOCK,))[..., :d].astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale}


def dequant_int8(qd: Dict[str, jax.Array], shape) -> jax.Array:
    q, scale = qd["q"], qd["scale"]
    s = q.shape if q.ndim else (1,)
    d = s[-1]
    nb = scale.shape[-1]
    pad = nb * QBLOCK - d
    qp = jnp.pad(q.reshape(s).astype(jnp.float32), [(0, 0)] * (len(s) - 1) + [(0, pad)])
    blocks = qp.reshape(s[:-1] + (nb, QBLOCK)) * scale[..., None]
    return blocks.reshape(s[:-1] + (nb * QBLOCK,))[..., :d].reshape(shape)


def compress_tree(grads: Pytree, key: jax.Array) -> Pytree:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    return treedef.unflatten(
        [stochastic_round_int8(g, k) for g, k in zip(leaves, keys)]
    )


def decompress_tree(comp: Pytree, like: Pytree) -> Pytree:
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_comp = treedef.flatten_up_to(comp)
    return treedef.unflatten(
        [dequant_int8(c, l.shape) for c, l in zip(flat_comp, flat_like)]
    )


def cast_tree(grads: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda g: g.astype(dtype), grads)

"""Data pipeline: deterministic, shardable, resumable.

Two sources behind one interface:

- :class:`SyntheticLM` — stateless synthetic token stream: batch(step) is
  a pure function of (seed, step), so a preempted training job resumed by
  another worker regenerates byte-identical batches (the data analogue of
  the paper's idempotent-restart requirement);
- :class:`TokenFileDataset` — memory-mapped token corpus chunked into
  fixed-length windows, strided by (dp_rank, n_dp) for data parallelism.

Both also drive the audio/vlm stub frontends (precomputed frame/patch
embeddings derived deterministically from the token batch).
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0


class SyntheticLM:
    """Zipf-ish synthetic tokens with enough structure for loss to fall."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch(self, step: int, *, dp_rank: int = 0, n_dp: int = 1) -> Dict[str, jax.Array]:
        d = self.data
        if d.global_batch % n_dp:
            raise ValueError(f"global_batch {d.global_batch} !% dp {n_dp}")
        local = d.global_batch // n_dp
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(d.seed), step), dp_rank
        )
        k1, k2, k3 = jax.random.split(key, 3)
        v = self.cfg.vocab_size
        # mixture: random tokens + short repeated motifs (learnable structure)
        base = jax.random.randint(k1, (local, d.seq_len + 1), 0, v)
        motif = jax.random.randint(k2, (local, 8), 0, v)
        reps = jnp.tile(motif, (1, (d.seq_len + 8) // 8))[:, : d.seq_len + 1]
        use_motif = jax.random.bernoulli(k3, 0.5, (local, 1))
        toks = jnp.where(use_motif, reps, base)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.is_encoder_decoder:
            kf = jax.random.fold_in(key, 7)
            batch["frames"] = jax.random.normal(
                kf, (local, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32
            )
        if self.cfg.n_vision_tokens:
            kp = jax.random.fold_in(key, 8)
            batch["patches"] = jax.random.normal(
                kp, (local, self.cfg.n_vision_tokens, self.cfg.d_model), jnp.float32
            )
        return batch


class TokenFileDataset:
    """Memory-mapped uint16/uint32 token file -> fixed windows.

    Deterministic addressing: window i of shard r covers tokens
    [ (i*n_dp + r) * seq_len, ... ), so any worker can compute any batch.
    """

    def __init__(self, path: str, cfg: ArchConfig, data: DataConfig, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.data = data

    def n_batches(self, n_dp: int = 1) -> int:
        per = self.data.seq_len + 1
        windows = len(self.tokens) // per
        return windows // self.data.global_batch

    def batch(self, step: int, *, dp_rank: int = 0, n_dp: int = 1) -> Dict[str, jax.Array]:
        d = self.data
        local = d.global_batch // n_dp
        per = d.seq_len + 1
        rows = []
        for b in range(local):
            widx = step * d.global_batch + dp_rank * local + b
            start = widx * per
            rows.append(np.asarray(self.tokens[start : start + per], dtype=np.int32))
        toks = jnp.asarray(np.stack(rows)) % self.cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def write_synthetic_corpus(path: str, n_tokens: int, vocab: int, seed: int = 0) -> str:
    """Materialize a synthetic corpus file (used by examples/tests)."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, min(vocab, 65535), size=n_tokens, dtype=np.uint16)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arr.tofile(path)
    return path

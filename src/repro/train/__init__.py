"""Training substrate: optimizer, steps, data, checkpointing, compression."""

"""Distributed AdamW, built from scratch (no optax in this environment).

Scale features:
- optimizer state inherits the parameter PartitionSpecs, so FSDP policies
  ZeRO-shard the moments for free;
- optional **8-bit moments** (blockwise int8 quantization, bnb-style):
  mu/nu stored as int8 + fp32 scale per 128-value block → ~2.06 bytes of
  optimizer state per parameter instead of 8.  This is what lets
  nemotron-4-340b training fit a single 256-chip pod (EXPERIMENTS §Perf);
- optional fp32 master copy when params are bf16;
- global-norm clipping, linear-warmup + cosine schedule;
- int8 stochastic-rounding gradient compression for the microbatch
  accumulator (`repro.train.compression`).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

QBLOCK = 128


# ----------------------------------------------------- layout-preserving int8
# Quantization blocks run along the LAST dim so ``q`` keeps the parameter's
# shape (and therefore its PartitionSpec — int8 moments stay ZeRO-sharded);
# the per-block fp32 scale has shape[:-1] + (n_blocks,).
def _lastdim_blocks(d: int) -> int:
    return max(1, -(-d // QBLOCK))


def quantize_blockwise(x: jax.Array) -> Dict[str, jax.Array]:
    """Symmetric int8 quantization in QBLOCK-wide blocks along the last dim."""
    x = x.astype(jnp.float32)
    shape = x.shape if x.ndim else (1,)
    d = shape[-1]
    nb = _lastdim_blocks(d)
    pad = nb * QBLOCK - d
    xp = jnp.pad(x.reshape(shape), [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = xp.reshape(shape[:-1] + (nb, QBLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # (..., nb)
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    q = q.reshape(shape[:-1] + (nb * QBLOCK,))[..., :d].astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale.astype(jnp.float32)}


def dequantize_blockwise(qd: Dict[str, jax.Array], shape, dtype=jnp.float32) -> jax.Array:
    q, scale = qd["q"], qd["scale"]
    s = q.shape if q.ndim else (1,)
    d = s[-1]
    nb = scale.shape[-1]
    pad = nb * QBLOCK - d
    qp = jnp.pad(q.reshape(s).astype(jnp.float32), [(0, 0)] * (len(s) - 1) + [(0, pad)])
    blocks = qp.reshape(s[:-1] + (nb, QBLOCK)) * scale[..., None]
    out = blocks.reshape(s[:-1] + (nb * QBLOCK,))[..., :d]
    return out.reshape(shape).astype(dtype)


# ------------------------------------------------------------------ schedule
@dataclass(frozen=True)
class Schedule:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    final_frac: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = self.peak_lr * step / max(self.warmup_steps, 1)
        prog = jnp.clip(
            (step - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0
        )
        cos = self.peak_lr * (
            self.final_frac + (1 - self.final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        )
        return jnp.where(step < self.warmup_steps, warm, cos)


@dataclass(frozen=True)
class AdamWConfig:
    schedule: Schedule = Schedule()
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "f32"  # f32 | int8
    master_fp32: bool = False  # keep fp32 master when params are low-precision


# ------------------------------------------------------------------- optimizer
def init_opt_state(params: Pytree, cfg: AdamWConfig) -> Pytree:
    def zeros_like_moment(p):
        if cfg.moments_dtype == "int8":
            shape = p.shape if p.ndim else (1,)
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros(shape[:-1] + (_lastdim_blocks(shape[-1]),), jnp.float32),
            }
        return jnp.zeros_like(p, dtype=jnp.float32)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros_like_moment, params),
        "nu": jax.tree.map(zeros_like_moment, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Pytree, grads: Pytree, state: Pytree, cfg: AdamWConfig
) -> Tuple[Pytree, Pytree, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.schedule(step)

    gnorm = global_norm(grads)
    scale = jnp.where(
        cfg.clip_norm > 0, jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)), 1.0
    )

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    use_master = "master" in state
    ref_params = state["master"] if use_master else params

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        if cfg.moments_dtype == "int8":
            mu_f = dequantize_blockwise(mu, p.shape)
            nu_f = dequantize_blockwise(nu, p.shape)
        else:
            mu_f, nu_f = mu, nu
        mu_f = cfg.b1 * mu_f + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu_f + (1 - cfg.b2) * jnp.square(g)
        mhat = mu_f / bc1
        nhat = nu_f / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        if cfg.moments_dtype == "int8":
            mu_o, nu_o = quantize_blockwise(mu_f), quantize_blockwise(nu_f)
        else:
            mu_o, nu_o = mu_f, nu_f
        return new_p, mu_o, nu_o

    flat_p, treedef = jax.tree_util.tree_flatten(ref_params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])

    outs = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_master = treedef.unflatten([o[0] for o in outs])
    new_mu = treedef.unflatten([o[1] for o in outs])
    new_nu = treedef.unflatten([o[2] for o in outs])

    param_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda m, dt: m.astype(dt), new_master, param_dtypes)

    new_state = {"step": step, "mu": new_mu, "nu": new_nu}
    if use_master:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr, "clip_scale": scale}
    return new_params, new_state, metrics


def opt_state_specs(param_specs_tree: Pytree, cfg: AdamWConfig) -> Pytree:
    """PartitionSpecs for the optimizer state, derived from param specs.

    int8 moments keep the parameter layout (blocks run along the last dim),
    so ``q`` inherits the parameter spec verbatim and the per-block scale
    inherits every axis except the last (which stays unsharded: the block
    count rarely divides the mesh axis).  Everything stays ZeRO-sharded.
    """
    from jax.sharding import PartitionSpec as P

    def moment_spec(spec):
        if cfg.moments_dtype == "int8":
            axes = tuple(spec)
            scale_axes = axes[:-1] + (None,) if axes else (None,)
            return {"q": spec, "scale": P(*scale_axes)}
        return spec

    is_spec = lambda x: isinstance(x, P)  # noqa: E731
    state_specs = {
        "step": P(),
        "mu": jax.tree.map(moment_spec, param_specs_tree, is_leaf=is_spec),
        "nu": jax.tree.map(moment_spec, param_specs_tree, is_leaf=is_spec),
    }
    if cfg.master_fp32:
        state_specs["master"] = param_specs_tree
    return state_specs

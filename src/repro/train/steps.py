"""Step builders: the pjit-able train_step / serve_step for every arch.

These are the functions the multi-pod dry-run lowers and the local
trainer executes; one code path for both (ShapeDtypeStructs vs arrays).

train_step(params, opt_state, batch, rng) -> (params, opt_state, metrics)
    - microbatch gradient accumulation (scan), optional int8
      stochastic-rounding compression of the accumulator,
    - AdamW update (optionally 8-bit moments / fp32 master).

serve_prefill(params, tokens, ...) -> last-position logits
serve_decode(params, cache, tokens, pos) -> (logits, cache)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import Model, ModelRuntime
from repro.train import compression
from repro.train.optimizer import AdamWConfig, adamw_update

Pytree = Any


@dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    accum_dtype: str = "f32"  # f32 | bf16 | int8 (stochastic rounding)
    opt: AdamWConfig = field(default_factory=AdamWConfig)


def make_loss_fn(model: Model) -> Callable:
    def loss_fn(params: Pytree, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        return model.loss(params, batch)

    return loss_fn


def make_train_step(
    model: Model, tcfg: TrainStepConfig, grad_shardings=None
) -> Callable:
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def constrain(grads):
        # pin gradients to the parameter sharding so cross-replica
        # reduction lowers as reduce-scatter (ZeRO) instead of
        # all-reduce-to-replicated (2x the bytes; §Perf iteration 1.3)
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, grad_shardings)

    def train_step(params, opt_state, batch, rng):
        m = tcfg.microbatches
        if m == 1:
            loss, grads = grad_fn(params, batch)
            grads = constrain(grads)
        else:
            # split the local batch into microbatches along dim 0
            def slice_mb(i, x):
                mb = x.shape[0] // m
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def accum_body(carry, i):
                acc, total = carry
                mb = jax.tree.map(partial(slice_mb, i), batch)
                l, g = grad_fn(params, mb)
                if tcfg.accum_dtype == "bf16":
                    g = compression.cast_tree(g, jnp.bfloat16)
                elif tcfg.accum_dtype == "int8":
                    g = compression.decompress_tree(
                        compression.compress_tree(g, jax.random.fold_in(rng, i)), g
                    )
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, constrain(g))
                return (acc, total + l), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(
                    p.shape, jnp.float32 if tcfg.accum_dtype != "bf16" else jnp.bfloat16
                ),
                params,
            )
            (gsum, lsum), _ = jax.lax.scan(accum_body, (acc0, 0.0), jnp.arange(m))
            grads = jax.tree.map(lambda g: (g / m).astype(jnp.float32), gsum)
            grads = constrain(grads)
            loss = lsum / m

        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt_state, tcfg.opt)
        metrics = {"loss": loss, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


# ------------------------------------------------------------------- serving
def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, tokens, frames=None, patches=None):
        return model.prefill(params, tokens, frames=frames, patches=patches)

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return decode_step

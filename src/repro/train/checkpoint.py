"""Checkpointing: sharded-layout-aware, atomic, manifest-based.

The manifest is the paper's CHECK_IF_DONE generalized to training state:
it is written *last* (after every leaf object), so a checkpoint either
has a complete manifest or does not exist; a preempted save can never be
mistaken for a finished one.  The done-check a worker performs before
re-running a step-span job is "does checkpoint ``step_end`` have a
manifest" — one object HEAD, exactly like counting output files in S3.

Layout in the object store:
    ckpt/<run>/<step>/manifest.json        # tree structure + metadata, LAST
    ckpt/<run>/<step>/<leaf.path>.npy      # one object per leaf

On a real multi-host pod each host writes only the shards it owns
(process-local addressable shards); here a single process owns
everything, and the layout keeps that extension mechanical.
"""

from __future__ import annotations

import hashlib
import io
import json
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.storage import ObjectStore

Pytree = Any


def _leaf_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def _npy_bytes(x: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, x, allow_pickle=False)
    return buf.getvalue()


def save_checkpoint(
    store: ObjectStore,
    run: str,
    step: int,
    tree: Pytree,
    *,
    extra_meta: Optional[Dict] = None,
) -> str:
    """Write every leaf, then the manifest (atomicity barrier)."""
    prefix = f"ckpt/{run}/{step}"
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        data = _npy_bytes(arr)
        store.put_bytes(f"{prefix}/{key}.npy", data)
        leaves.append(
            {
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "bytes": len(data),
                "crc": hashlib.md5(data).hexdigest(),
            }
        )
    manifest = {
        "run": run,
        "step": step,
        "treedef": str(treedef),
        "leaves": leaves,
        "meta": extra_meta or {},
    }
    store.put_json(f"{prefix}/manifest.json", manifest)  # atomic rename inside
    return prefix


def checkpoint_exists(store: ObjectStore, run: str, step: int) -> bool:
    return store.exists(f"ckpt/{run}/{step}/manifest.json")


def latest_step(store: ObjectStore, run: str) -> Optional[int]:
    steps = []
    for info in store.list(f"ckpt/{run}/"):
        parts = info.key.split("/")
        if parts[-1] == "manifest.json" and len(parts) >= 3:
            try:
                steps.append(int(parts[-2]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(
    store: ObjectStore, run: str, step: int, like: Pytree, *, strict_crc: bool = True
) -> Tuple[Pytree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    prefix = f"ckpt/{run}/{step}"
    manifest = store.get_json(f"{prefix}/manifest.json")
    by_key = {l["key"]: l for l in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in flat:
        key = _leaf_key(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        data = store.get_bytes(f"{prefix}/{key}.npy")
        if strict_crc and hashlib.md5(data).hexdigest() != by_key[key]["crc"]:
            raise IOError(f"checksum mismatch for {key!r}")
        arr = np.load(io.BytesIO(data), allow_pickle=False)
        if arr.dtype.kind == "V":
            # low-precision dtypes (bfloat16, ...) round-trip through numpy
            # as void records; re-view them via ml_dtypes
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, by_key[key]["dtype"])))
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != expected {want_shape}")
        out.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["meta"]

"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Optional parallelism dimension for very deep models: the layer stack is
split into S stages sharded over a ``pipe`` mesh axis; microbatches
stream through the classic GPipe schedule (S + M - 1 slots, bubble
fraction (S-1)/(S+M-1)).  Stage hand-off is a single
``jax.lax.ppermute`` per slot — the TPU-native point-to-point.

This module is deliberately self-contained (pure function over stacked
stage parameters) so it composes with the rules engine: within a stage,
parameters may still shard over "model"/"data" axes of the same mesh.

The production mesh (DESIGN §3) does not reserve a pipe axis — FSDP+TP
covers the assigned configs — but the feature is required at the
3D-parallel scale this framework targets; `tests/test_pipeline.py`
validates it on a host-device mesh against the sequential reference.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

Pytree = object


def pipeline_apply(
    mesh: Mesh,
    stage_params: Pytree,  # leaves stacked over stages: (S, ...)
    x: jax.Array,  # (M, mb, ...) microbatched input
    stage_fn: Callable[[Pytree, jax.Array], jax.Array],
    *,
    axis: str = "pipe",
) -> jax.Array:
    """Run x through S pipeline stages; returns (M, mb, ...) outputs."""
    n_stages = mesh.shape[axis]
    m = x.shape[0]

    def _stage(params_local, x_all):
        # params_local: (1, ...) this stage's slice; x_all: full (M, mb, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        mb_shape = x_all.shape[1:]
        state = jnp.zeros(mb_shape, x_all.dtype)  # activation in this stage
        outputs = jnp.zeros_like(x_all)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def slot(t, carry):
            state, outputs = carry
            # stage 0 ingests microbatch t (garbage past M; masked later)
            mb_idx = jnp.clip(t, 0, m - 1)
            injected = jax.lax.dynamic_index_in_dim(x_all, mb_idx, 0, keepdims=False)
            inp = jnp.where(sid == 0, injected, state)
            out = stage_fn(params_local, inp)
            # last stage banks microbatch (t - S + 1) once it's real
            bank_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            do_bank = jnp.logical_and(sid == n_stages - 1, t >= n_stages - 1)
            banked = jax.lax.dynamic_update_index_in_dim(
                outputs, out.astype(outputs.dtype), bank_idx, 0
            )
            outputs = jnp.where(do_bank, banked, outputs)
            # hand off to the next stage
            state = jax.lax.ppermute(out, axis, fwd_perm)
            return state, outputs

        state, outputs = jax.lax.fori_loop(
            0, m + n_stages - 1, slot, (state, outputs)
        )
        # broadcast the last stage's banked outputs to every stage so the
        # result is replicated over the pipe axis
        mask = (sid == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        _stage,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)

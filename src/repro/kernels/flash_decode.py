"""Paged flash-decode / chunk-extend attention as a Pallas TPU kernel.

The serving engine's paged KV cache stores tokens in fixed-size *pages*
drawn from a shared pool; a per-slot *page table* maps each row's logical
page index to a physical page id.  This kernel attends a chunk of queries
``q[B, T]`` (``T = 1`` is plain flash-decode; ``T > 1`` is chunk-extend,
used both for fused prefill and as the speculative-decoding *verify*
primitive — ``ops.paged_verify`` scores the last accepted token plus
``k`` drafts per row in one ``T = k + 1`` launch) against that paged
cache **through the page table**,
without ever gathering the pages into a dense ``(B, max_len)`` cache and
without materializing a ``(B, H, T, max_len)`` score tensor.

TPU mapping (same sequential-grid trick as ``flash_attention.py``): the
page table and per-row query offsets are *scalar-prefetch* operands
(``pltpu.PrefetchScalarGridSpec``), so each KV ``BlockSpec`` index map
dereferences ``page_table[b, j]`` to DMA the right physical page for
grid step ``(b, kv_head, j)``.  The last grid dimension walks a row's
logical pages in order; the online-softmax state ``(m, l, acc)`` lives
in VMEM scratch and carries across steps.  Pages whose logical positions
lie entirely after the row's last query — including unallocated pages,
whose table entries hold the out-of-bounds sentinel ``>= n_pages`` — are
skipped with ``pl.when`` (their DMA index is clamped in bounds, their
compute never runs).

Masking: query ``i`` of a row (grouped-query fold, see below) sits at
absolute position ``offset[b] + i % T``; cache slot ``o`` of logical
page ``j`` holds position ``j * page_size + o``.  The causal mask
``kv_pos <= q_pos`` is exact because the engine's allocator guarantees
every logical position ``< offset + T`` is backed by an allocated,
written page (allocate-on-write), and everything at or beyond the write
frontier is masked.

Layout contract (GQA without repeating KV): callers fold queries
*group-major* to ``(B, Hkv, G*T, dk)`` — fold index ``i = g*T + t`` —
so all ``G`` query heads of a KV group share one grid step.  MLA's
absorbed decode is the ``Hkv=1`` case with ``dk = kv_lora_rank +
rope_head_dim`` and values read from the first ``v_width`` columns of
the (shared) KV page (``ops.paged_attention`` handles both layouts).

Rows with no attendable positions (e.g. parked slots whose pages were
freed) produce zeros, not NaNs.  Validated on CPU in interpret mode
against ``ref.paged_attention_reference``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetch refs
    table_ref,  # (B, P) int32 physical page per (row, logical page)
    off_ref,  # (B,) int32 absolute position of each row's first query
    # blocked operands
    q_ref,  # (1, 1, QL, dk)
    k_ref,  # (1, page_size, 1, dk)
    v_ref,  # (1, page_size, 1, dv_store)
    o_ref,  # (1, 1, QL, dv)
    # scratch
    m_scr,  # (QL, 1) f32
    l_scr,  # (QL, 1) f32
    acc_scr,  # (QL, dv) f32
    *,
    scale: float,
    softcap: float,
    page_size: int,
    tokens_per_row: int,
    n_pages: int,
    pages_per_slot: int,
    v_width: int,
    ql: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # page is attendable iff some logical slot precedes the last query AND
    # the table entry is real (sentinel >= n_pages marks unallocated /
    # freed pages, which the allocator invariant puts past the frontier)
    last_q = off_ref[b] + tokens_per_row - 1
    run = jnp.logical_and(j * page_size <= last_q, table_ref[b, j] < n_pages)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (QL, dk)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (ps, dk)
        v = v_ref[0, :, 0, :v_width].astype(jnp.float32)  # (ps, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (QL, ps)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = off_ref[b] + (
            jax.lax.broadcasted_iota(jnp.int32, (ql, page_size), 0) % tokens_per_row
        )
        kv_pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (ql, page_size), 1
        )
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == pages_per_slot - 1)
    def _writeback():
        # rows with zero attendable positions (all pages skipped) keep
        # l == 0 and write zeros instead of dividing 0/0 into NaNs
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_flash_attention_folded(
    q: jax.Array,  # (B, Hkv, QL, dk) group-major fold, QL = G * T
    k_pages: jax.Array,  # (n_pages, page_size, Hkv, dk)
    v_pages: jax.Array,  # (n_pages, page_size, Hkv, dv_store)
    page_table: jax.Array,  # (B, P) int32; entries >= n_pages = unallocated
    offsets: jax.Array,  # (B,) int32 absolute position of first query token
    *,
    tokens_per_row: int,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    v_width: int = 0,
    interpret: bool = False,
) -> jax.Array:  # (B, Hkv, QL, dv)
    b, hkv, ql, dk = q.shape
    n_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    pages_per_slot = page_table.shape[1]
    dv_store = v_pages.shape[-1]
    dv = v_width or dv_store
    if ql % tokens_per_row:
        raise ValueError(f"QL {ql} must fold a whole group count x T {tokens_per_row}")
    if scale is None:
        scale = 1.0 / math.sqrt(dk)

    kernel = functools.partial(
        _paged_kernel,
        scale=scale,
        softcap=softcap,
        page_size=page_size,
        tokens_per_row=tokens_per_row,
        n_pages=n_pages,
        pages_per_slot=pages_per_slot,
        v_width=dv,
        ql=ql,
    )

    def page_map(bb, hh, jj, table, off):
        # clamp the sentinel in bounds: skipped pages still DMA *something*
        return (jnp.minimum(table[bb, jj], n_pages - 1), 0, hh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pages_per_slot),
        in_specs=[
            pl.BlockSpec((1, 1, ql, dk), lambda bb, hh, jj, table, off: (bb, hh, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dk), page_map),
            pl.BlockSpec((1, page_size, 1, dv_store), page_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, ql, dv), lambda bb, hh, jj, table, off: (bb, hh, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((ql, 1), jnp.float32),
            pltpu.VMEM((ql, 1), jnp.float32),
            pltpu.VMEM((ql, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, ql, dv), q.dtype),
        interpret=interpret,
    )(
        page_table.astype(jnp.int32),
        offsets.astype(jnp.int32),
        q,
        k_pages,
        v_pages,
    )

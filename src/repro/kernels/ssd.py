"""Mamba2 SSD within-chunk kernel (Pallas TPU).

The chunked SSD algorithm splits into (a) a quadratic *within-chunk* term
plus per-chunk state summaries — the compute hot-spot — and (b) a cheap
linear inter-chunk recurrence.  This kernel computes (a) for one
(batch, chunk, head-block) tile per grid step:

    Y_diag[q,h,p] = sum_k C[q,:]·B[k,:] * exp(cum[q,h]-cum[k,h]) * dt[k,h] * x[k,h,p]   (k<=q)
    state[h,p,n]  = sum_k exp(cum[end,h]-cum[k,h]) * dt[k,h] * x[k,h,p] * B[k,n]

Heads are tiled (``block_h``) so the (q x q x block_h) decay tensor fits
VMEM; q is the SSD chunk length (128 by default: MXU-aligned).  The
inter-chunk scan and the low-rank Y_off einsum stay in jnp
(`repro.kernels.ops.ssd`), mirroring how the paper's own implementation
splits the work between the matmul engine and elementwise units.

Validated in interpret mode against `repro.models.ssm.ssd_chunked`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, y_ref, s_ref):
    # blocks: x (1,1,q,hb,p); da/dt (1,1,q,hb); b/c (1,1,q,n)
    x = x_ref[0, 0].astype(jnp.float32)  # (q, hb, p)
    da = da_ref[0, 0].astype(jnp.float32)  # (q, hb)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (q, hb)
    B = b_ref[0, 0].astype(jnp.float32)  # (q, n)
    C = c_ref[0, 0].astype(jnp.float32)  # (q, n)
    q = x.shape[0]

    cum = jnp.cumsum(da, axis=0)  # (q, hb)
    # decay matrix L[i,j,h] = exp(cum[i,h] - cum[j,h]) for j <= i
    diff = cum[:, None, :] - cum[None, :, :]  # (q, q, hb)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = (jj <= ii)[:, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)  # (q, q, hb)

    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, q) = C[i,:]·B[j,:]
    M = scores[:, :, None] * L * dt[None, :, :]  # (q, q, hb)

    # Y_diag = einsum('ijh,jhp->ihp', M, x)
    y = jnp.einsum("ijh,jhp->ihp", M, x, preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state = einsum('jn,jh,jhp->hpn', B, exp(cum[-1]-cum)*dt, x)
    w = jnp.exp(cum[-1:, :] - cum) * dt  # (q, hb)
    s = jnp.einsum("jn,jh,jhp->hpn", B, w, x, preferred_element_type=jnp.float32)
    s_ref[0, 0] = s.astype(s_ref.dtype)


def ssd_chunk_pallas(
    x: jax.Array,  # (b, nc, q, h, p)
    dA: jax.Array,  # (b, nc, q, h)
    dt: jax.Array,  # (b, nc, q, h)
    B: jax.Array,  # (b, nc, q, n)
    C: jax.Array,  # (b, nc, q, n)
    *,
    block_h: int = 8,
    interpret: bool = False,
):
    """Returns (Y_diag (b,nc,q,h,p) fp32, states (b,nc,h,p,n) fp32)."""
    b, nc, q, h, p = x.shape
    n = B.shape[-1]
    block_h = min(block_h, h)
    if h % block_h:
        raise ValueError(f"heads {h} must divide block_h {block_h}")
    nh = h // block_h

    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(b, nc, nh),
        in_specs=[
            pl.BlockSpec((1, 1, q, block_h, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, block_h), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, block_h), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, block_h, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, block_h, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dA, dt, B, C)

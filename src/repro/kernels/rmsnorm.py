"""Fused RMSNorm Pallas kernel.

Row-tiled: each grid step normalizes ``block_rows`` rows of the flattened
(rows, d) input in one VMEM-resident pass (read once, write once) with
fp32 accumulation — the memory-bound fusion XLA sometimes splits into
separate square/mean/rsqrt/mul passes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, d)
    w = w_ref[...].astype(jnp.float32)  # (1, d)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,  # (rows, d)
    w: jax.Array,  # (d,)
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows {rows} !% block_rows {block_rows}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w[None, :])

"""Flash attention as a Pallas TPU kernel.

TPU adaptation (DESIGN.md): instead of a CUDA warp-level online-softmax,
the kernel exploits the *sequential* TPU grid: the last grid dimension
iterates over KV blocks in order, so the running (m, l, acc) state lives
in VMEM scratch and carries across grid steps — no atomics, no
shared-memory staging.  Q/K/V blocks are VMEM tiles via BlockSpec; the
MXU sees (block_q x head_dim) @ (head_dim x block_k) matmuls with
hardware-aligned 128-multiples.

Causal and sliding-window masks are applied per tile; fully-masked tiles
skip their matmuls (`pl.when`), which is the triangle-skipping the pure
jnp path cannot express (EXPERIMENTS §Perf).

Validated on CPU in interpret mode against ``ref.attention_reference``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # tile-level skip: tile is entirely masked out
    run = ik >= 0  # traced "True"
    if causal:
        run = jnp.logical_and(run, ik * block_k <= iq * block_q + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, (ik + 1) * block_k - 1 > iq * block_q - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _writeback():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,  # (BH, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq ({sq},{skv}) must divide blocks ({block_q},{block_k})")
    nq, nk = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Jit-ready wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container), so the same call
sites run the kernel bodies in Python on CPU for validation and compile
the real mosaic kernels on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.flash_decode import paged_flash_attention_folded
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd import ssd_chunk_pallas


def _default_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,  # (B, S, H, hd) — model layout
    k: jax.Array,  # (B, S, H, hd) (kv already repeated to H)
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, s, h, hd = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)  # noqa: E731
    out = flash_attention_bhsd(
        fold(q),
        fold(k),
        fold(v),
        causal=causal,
        window=sliding_window,
        block_q=block_q,
        block_k=block_k,
        interpret=_default_interpret(interpret),
    )
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def paged_attention(
    q: jax.Array,  # (B, T, H, dk) — model layout, RoPE already applied
    k_pages: jax.Array,  # (n_pages, page_size, Hkv, dk)
    v_pages: jax.Array,  # (n_pages, page_size, Hkv, dv_store)
    page_table: jax.Array,  # (B, P) int32; entries >= n_pages = unallocated
    offsets: jax.Array,  # (B,) absolute position of each row's first token
    *,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    v_width: int = 0,
    interpret: Optional[bool] = None,
) -> jax.Array:  # (B, T, H, dv)
    """Flash-decode / chunk-extend against a paged KV cache.

    Grouped-query layout: KV heads are NOT repeated; the kernel processes
    one (row, kv head) pair per grid step with all its query heads folded
    group-major into the query block.  ``T = 1`` is decode, ``T > 1`` the
    chunk-extend used by fused prefill.  MLA's absorbed form is the
    ``Hkv = 1`` case (``v_width`` selects the latent columns of the
    shared KV page).  Query ``t`` of row ``b`` sits at absolute position
    ``offsets[b] + t``; the engine's allocate-on-write invariant makes
    the causal mask exact (see ``flash_decode``).
    """
    b, T, h, dk = q.shape
    hkv = k_pages.shape[2]
    g = h // hkv
    qf = q.reshape(b, T, hkv, g, dk).transpose(0, 2, 3, 1, 4).reshape(b, hkv, g * T, dk)
    out = paged_flash_attention_folded(
        qf,
        k_pages,
        v_pages,
        page_table,
        offsets,
        tokens_per_row=T,
        scale=scale,
        softcap=softcap,
        v_width=v_width,
        interpret=_default_interpret(interpret),
    )  # (B, Hkv, G*T, dv)
    dv = out.shape[-1]
    return out.reshape(b, hkv, g, T, dv).transpose(0, 3, 1, 2, 4).reshape(b, T, h, dv)


def paged_verify(
    q: jax.Array,  # (B, k+1, H, dk) — last accepted token + k draft tokens
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    offsets: jax.Array,  # (B,) each row's write frontier (slot.pos)
    *,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    v_width: int = 0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Speculative-verify attention: score ``k + 1`` candidate positions
    per row in one kernel launch.

    This IS :func:`paged_attention`'s chunk-extend case (``T = k + 1``)
    — the verify primitive needs nothing the extend kernel does not
    already provide.  Query ``t`` sits at absolute position
    ``offsets[b] + t`` and the kernel's causal mask (``kv_pos <=
    q_pos``) scopes each draft's attention to the accepted history plus
    the drafts before it, which is exactly the conditioning sequential
    decode would have used — so per-position logits, and therefore the
    engine's accept/reject decisions, are byte-identical to ``k + 1``
    single-token decode launches.  Rejected positions need no kernel-
    side cleanup: their K/V lands past the rewound write frontier where
    this same mask excludes it from every later query.

    Kept as a named entry so call sites (and the jnp fallback parity
    test) can say *verify* and mean it; the dispatch is shared."""
    return paged_attention(
        q, k_pages, v_pages, page_table, offsets,
        scale=scale, softcap=softcap, v_width=v_width, interpret=interpret,
    )


def ssd(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h)  (post-softplus)
    A: jax.Array,  # (h,) negative
    B: jax.Array,  # (b, l, n)
    C: jax.Array,  # (b, l, n)
    *,
    chunk: int = 128,
    block_h: int = 8,
    initial_state: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: Pallas within-chunk kernel + jnp inter-chunk glue.

    Same contract as `repro.models.ssm.ssd_chunked`.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    if l % chunk:
        raise ValueError(f"seq {l} !% chunk {chunk}")
    nc = l // chunk
    if h % block_h:
        block_h = h  # degrade to one head block

    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    dAr = (dt * A).reshape(b, nc, chunk, h).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, n)
    Cr = C.reshape(b, nc, chunk, n)

    y_diag, states = ssd_chunk_pallas(
        xr, dAr, dtr, Br, Cr, block_h=block_h, interpret=_default_interpret(interpret)
    )

    # inter-chunk recurrence (cheap, O(nc) scan over (b,h,p,n) states)
    cum = jnp.cumsum(dAr, axis=2)  # (b,nc,q,h)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,h)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    final, prev = jax.lax.scan(scan_fn, initial_state.astype(jnp.float32), xs)
    prev = prev.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n) state entering each chunk

    decay_out = jnp.exp(cum)  # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr.astype(jnp.float32), prev, decay_out)
    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, final


def rmsnorm(
    x: jax.Array,  # (..., d)
    w: jax.Array,  # (d,)
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    br = block_rows
    while rows % br:
        br //= 2
    out = rmsnorm_pallas(
        x2, w, eps=eps, block_rows=max(br, 1), interpret=_default_interpret(interpret)
    )
    return out.reshape(shape)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Direct softmax attention with causal/sliding-window masking."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = jnp.logical_and(mask, kp <= qp)
    if window > 0:
        mask = jnp.logical_and(mask, kp > qp - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_attention_reference(
    q: jax.Array,  # (B, T, H, dk)
    k_pages: jax.Array,  # (n_pages, page_size, Hkv, dk)
    v_pages: jax.Array,  # (n_pages, page_size, Hkv, dv_store)
    page_table: jax.Array,  # (B, P) int32; entries >= n_pages = unallocated
    offsets: jax.Array,  # (B,)
    *,
    scale: float = 0.0,
    softcap: float = 0.0,
    v_width: int = 0,
) -> jax.Array:
    """Gather-then-softmax oracle for the paged flash kernel.

    Dense materialization of exactly what the kernel computes: pages are
    gathered through the (clamped) page table into a contiguous logical
    cache, unallocated pages and future positions are masked, and rows
    with zero attendable positions return zeros (matching the kernel's
    all-pages-skipped writeback).
    """
    n_pages, ps, hkv, dk = k_pages.shape
    b, T, h, _ = q.shape
    P = page_table.shape[1]
    g = h // hkv
    if not scale:
        scale = 1.0 / math.sqrt(dk)
    safe = jnp.minimum(page_table, n_pages - 1)
    k = k_pages[safe].reshape(b, P * ps, hkv, dk).astype(jnp.float32)
    v = v_pages[safe].reshape(b, P * ps, hkv, -1).astype(jnp.float32)
    if v_width:
        v = v[..., :v_width]
    qg = q.reshape(b, T, hkv, g, dk).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = offsets[:, None] + jnp.arange(T)[None, :]  # (b, T)
    kv_pos = jnp.arange(P * ps)[None, :]  # (1, P*ps)
    alloc = jnp.repeat(page_table < n_pages, ps, axis=1)  # (b, P*ps)
    mask = jnp.logical_and(
        kv_pos[:, None] <= q_pos[..., None], alloc[:, None, :]
    )  # (b, T, P*ps)
    mask_b = mask[:, None, None]  # (b,1,1,T,t)
    s = jnp.where(mask_b, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows/queries with nothing attendable: zeros, not a uniform average
    any_valid = jnp.any(mask_b, axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p, v)
    return out.reshape(b, T, h, -1).astype(q.dtype)


def ssd_reference(x, dt, A, B, C, chunk):
    """Full chunked-SSD oracle (shared with the model path)."""
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, A, B, C, chunk)


def ssd_chunk_reference(x, dA, dt, B, C):
    """Within-chunk term + per-chunk states (the kernel's exact contract).

    x (b,nc,q,h,p); dA/dt (b,nc,q,h); B/C (b,nc,q,n) ->
    (Y_diag (b,nc,q,h,p) fp32, states (b,nc,h,p,n) fp32)
    """
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=2)  # (b,nc,q,h)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,q,q,h)
    q = x.shape[2]
    tri = (jnp.arange(q)[None, :] <= jnp.arange(q)[:, None])[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", C.astype(jnp.float32), B.astype(jnp.float32))
    M = scores[..., None] * L * dt[:, :, None, :, :]
    y = jnp.einsum("bcijh,bcjhp->bcihp", M, x.astype(jnp.float32))
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dt
    s = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", B.astype(jnp.float32), w, x.astype(jnp.float32))
    return y, s


def rmsnorm_reference(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------- registry
# Op-name -> plain-jnp oracle for every public entry point in
# ``kernels.ops``.  dslint R6 checks this stays total: a kernel without
# a registered oracle (and a parity test exercising it) cannot ship.
# ``paged_verify`` shares ``paged_attention``'s oracle by design — the
# verify primitive IS the chunk-extend case (T = k + 1).
ORACLES = {
    "flash_attention": attention_reference,
    "paged_attention": paged_attention_reference,
    "paged_verify": paged_attention_reference,
    "ssd": ssd_reference,
    "rmsnorm": rmsnorm_reference,
}

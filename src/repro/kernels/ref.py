"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (BH, Sq, hd)
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Direct softmax attention with causal/sliding-window masking."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = jnp.logical_and(mask, kp <= qp)
    if window > 0:
        mask = jnp.logical_and(mask, kp > qp - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_reference(x, dt, A, B, C, chunk):
    """Full chunked-SSD oracle (shared with the model path)."""
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, A, B, C, chunk)


def ssd_chunk_reference(x, dA, dt, B, C):
    """Within-chunk term + per-chunk states (the kernel's exact contract).

    x (b,nc,q,h,p); dA/dt (b,nc,q,h); B/C (b,nc,q,n) ->
    (Y_diag (b,nc,q,h,p) fp32, states (b,nc,h,p,n) fp32)
    """
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=2)  # (b,nc,q,h)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,q,q,h)
    q = x.shape[2]
    tri = (jnp.arange(q)[None, :] <= jnp.arange(q)[:, None])[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", C.astype(jnp.float32), B.astype(jnp.float32))
    M = scores[..., None] * L * dt[:, :, None, :, :]
    y = jnp.einsum("bcijh,bcjhp->bcihp", M, x.astype(jnp.float32))
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dt
    s = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", B.astype(jnp.float32), w, x.astype(jnp.float32))
    return y, s


def rmsnorm_reference(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)

"""``dslint`` — the serving stack's invariant linter.

Nine PRs in, this repro's coordination disciplines (retry-wrapped
store/queue ops, durable-before-ack ordering, byte-determinism of
engine ticks, counter plumbing from ``EngineStats`` through
``snapshot()`` -> RESULTS -> bench -> docs) lived only in reviewers'
heads and scattered regression tests — and several past bugs (the PR 5
self-preemption live-lock, PR 8's unretried store ops, the truncated
npz blob crash) were exactly violations of those unwritten rules.
This package makes them machine-checkable: an AST walk over every file
under ``src/repro/`` enforcing ~7 codebase-specific rules, each
grounded in a real past bug class (see ``docs/analysis.md`` for the
catalog and the motivating bug behind each rule).

Run it::

    PYTHONPATH=src python -m repro.analysis            # full tree
    PYTHONPATH=src python -m repro.analysis --changed  # inner loop
    PYTHONPATH=src python -m repro.analysis --list-rules

Tier-1 runs the full tree via ``tests/test_analysis.py`` — a new PR
that drifts from any discipline fails the suite, not a review.

Suppression is explicit and audited:

- inline pragma: ``# dslint: disable=R1(reason)`` on the offending
  line or on the enclosing ``def``/``class`` header;
- the committed baseline (``baseline.json`` next to this file) for
  grandfathered findings, each entry carrying a written justification.

An empty reason or justification is itself a finding (rule R0), so
nothing can be silenced without saying why.
"""

from repro.analysis.engine import (  # noqa: F401
    Finding,
    Project,
    Report,
    run_analysis,
)
from repro.analysis.rules import ALL_RULES  # noqa: F401

"""dslint CLI: ``python -m repro.analysis [paths...] [options]``.

Exit status 0 when clean (pragma-suppressed and justified-baseline
findings do not fail the run), 1 on findings/errors/stale baseline.

Options:

    paths...            lint only these repo-relative files (module rules);
                        project rules still run against the full tree
    --changed           lint only files differing from HEAD (fast mode)
    --root DIR          repo root (default: auto-detected from this file)
    --baseline PATH     baseline file (default: the committed one)
    --update-baseline   re-baseline current findings; requires --justify
    --justify TEXT      written justification recorded in each new entry
    --list-rules        print the rule catalog and exit
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.engine import changed_files, run_analysis, update_baseline
from repro.analysis.rules import ALL_RULES


def _default_root() -> str:
    # src/repro/analysis/__main__.py -> repo root is three levels up from
    # the package, then above src/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="dslint: AST invariant linter for the repro codebase",
    )
    parser.add_argument("paths", nargs="*", help="repo-relative files to lint")
    parser.add_argument("--changed", action="store_true",
                        help="lint only files differing from HEAD")
    parser.add_argument("--root", default=_default_root())
    parser.add_argument("--baseline", default=None)
    parser.add_argument("--update-baseline", action="store_true")
    parser.add_argument("--justify", default="")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}  {rule.title}")
        return 0

    if args.update_baseline:
        try:
            update_baseline(
                args.root, justification=args.justify,
                baseline_path=args.baseline,
            )
        except ValueError as e:
            print(f"dslint: {e}", file=sys.stderr)
            return 2
        print("dslint: baseline updated")
        return 0

    paths = list(args.paths)
    if args.changed:
        paths += changed_files(args.root)
        if not paths:
            print("dslint: no changed files under src/repro/ — nothing to lint")
            return 0
    report = run_analysis(
        args.root, paths=paths or None, baseline_path=args.baseline
    )
    print(report.render())
    return 0 if (report.ok and not report.stale_baseline) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""R7 — inert-knob refusal.

Motivating gap (audit before PR 10): ``DSConfig`` carried fields
(``ebs_vol_size_gb``, ``sqs_dead_letter_queue``) that were validated
and documented but consumed by nothing — an operator tuning them got
silent no-ops.  A config field must be *consumed* somewhere under
``src/repro/`` outside ``core/config.py``, or *explicitly refused*: an
entry in ``config.py``'s ``INERT_PAPER_FIELDS`` dict (paper-fidelity
fields kept for CLI/doc parity, each with a written reason).

"Consumed" is a syntactic check, deliberately broad: the field name
appearing outside ``config.py`` as an attribute access (``cfg.field``),
a string literal (dict-driven plumbing), or a keyword argument.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding
from repro.analysis.rules.common import Rule

CONFIG_PATH = "src/repro/core/config.py"


def _dsconfig_fields(module):
    for node in ast.walk(module.tree):
        if isinstance(node, ast.ClassDef) and node.name == "DSConfig":
            return [
                stmt for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
                and not stmt.target.id.startswith("_")
            ]
    return []


def _inert_registry(module):
    """Keys of config.py's module-level ``INERT_PAPER_FIELDS`` dict,
    or None when the registry is absent."""
    for stmt in module.tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        else:
            continue
        names = {t.id for t in targets if isinstance(t, ast.Name)}
        if "INERT_PAPER_FIELDS" not in names or not isinstance(stmt.value, ast.Dict):
            continue
        keys = {}
        for k, v in zip(stmt.value.keys, stmt.value.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                reason = v.value if (
                    isinstance(v, ast.Constant) and isinstance(v.value, str)
                ) else ""
                keys[k.value] = reason
        return keys
    return None


def _consumers(project, field_name):
    """True if ``field_name`` is referenced outside core/config.py as an
    attribute access, a string literal, or a keyword argument."""
    for mod in project.modules.values():
        if mod.relpath == CONFIG_PATH or not mod.relpath.startswith("src/repro/"):
            continue
        if mod.relpath.startswith("src/repro/analysis/"):
            continue  # the linter's own sources don't count as consumers
        if field_name not in mod.source:
            continue  # cheap pre-filter before the AST pass
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr == field_name:
                return True
            if isinstance(node, ast.Constant) and node.value == field_name:
                return True
            if isinstance(node, ast.keyword) and node.arg == field_name:
                return True
    return False


class InertKnobRule(Rule):
    rule_id = "R7"
    title = ("every DSConfig field must be consumed somewhere in src/repro "
             "or explicitly refused in INERT_PAPER_FIELDS")

    def check_project(self, project):
        cfg_mod = project.module(CONFIG_PATH)
        if cfg_mod is None:
            return
        fields = _dsconfig_fields(cfg_mod)
        inert = _inert_registry(cfg_mod)
        inert_keys = set(inert or {})
        field_names = {f.target.id for f in fields}
        for f in fields:
            name = f.target.id
            if name in inert_keys:
                if not (inert or {}).get(name, "").strip():
                    yield cfg_mod.finding(
                        "R7", f,
                        f"INERT_PAPER_FIELDS[{name!r}] has no written reason "
                        "— the registry exists to record *why* a knob is "
                        "allowed to be inert",
                    )
                continue
            if not _consumers(project, name):
                yield cfg_mod.finding(
                    "R7", f,
                    f"DSConfig.{name} is consumed by nothing under "
                    "src/repro/ — an operator tuning it gets a silent "
                    "no-op; wire it up or add it to INERT_PAPER_FIELDS "
                    "with a reason",
                )
        # stale registry entries: refusing a field that no longer exists
        for name in sorted(inert_keys - field_names):
            yield Finding(
                rule="R7", path=CONFIG_PATH, line=1,
                message=(f"INERT_PAPER_FIELDS entry {name!r} names a field "
                         "that is no longer on DSConfig — drop it"),
                scope="INERT_PAPER_FIELDS", anchor=name,
            )

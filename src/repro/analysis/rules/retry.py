"""R1 — retry-discipline on the lease path.

Motivating bug (PR 8): chaos ``flaky_storage``/``flaky_queue`` windows
crashed serving leases because store/queue operations were called bare
— a single transient ``ConnectionError`` killed the worker, losing the
in-memory segment.  The fix wrapped every lease-path operation in
``_with_retries`` (capped content-keyed backoff); this rule keeps it
that way: in lease-role modules (``launch/serve.py``,
``serving/prefix_store.py``) every ``ObjectStore``/``DurableQueue``
method call must run under a retry wrapper (``_with_retries``,
``_retry_transient``) or inside ``AsyncPublisher`` (whose worker
retries every put).
"""

from __future__ import annotations

import ast

from repro.analysis.rules.common import (
    QUEUE_OPS,
    STORE_OPS,
    Rule,
    in_retry_context,
    is_queue_receiver,
    is_store_receiver,
    receiver_terminal,
)


class RetryDisciplineRule(Rule):
    rule_id = "R1"
    title = ("lease-path store/queue ops must flow through _with_retries/"
             "AsyncPublisher, never bare")

    def check_module(self, module, project):
        if "lease" not in module.roles:
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            recv, op = receiver_terminal(node)
            if not recv:
                continue
            kind = None
            if is_store_receiver(recv) and op in STORE_OPS:
                kind = "store"
            elif is_queue_receiver(recv) and op in QUEUE_OPS:
                kind = "queue"
            if kind is None:
                continue
            if in_retry_context(node):
                continue
            yield module.finding(
                "R1", node,
                f"bare {kind} op {recv}.{op}() on the lease path — a "
                "transient ConnectionError here kills the lease; wrap it "
                "in _with_retries(...) (or route puts through "
                "AsyncPublisher)",
            )

"""R2 — durable-before-ack ordering.

Motivating bug class (PR 4, re-affirmed in PR 8/9): a request message
deleted *before* its completion record / checkpoint / handoff marker is
durable in the object store cannot be resurfaced by the visibility
timeout — a worker crash in the gap silently loses the request.  The
serving lease's contract is therefore put-THEN-delete, everywhere.

The rule does per-function call-order analysis in lease/handler
modules: within one ordering region (a function body, or each loop
body — different loops process different message populations, so
cross-loop order is meaningless), a queue ack (``delete`` /
``delete_batch``) must not precede a durable store put (``put_json`` /
``put_bytes``) that appears later in the same region.  An ack with no
later put in its region guards nothing and is fine (e.g. acking a
redelivered, already-recorded request).
"""

from __future__ import annotations

import ast
from typing import List, Tuple

from repro.analysis.rules.common import (
    ACK_OPS,
    DURABLE_PUT_OPS,
    Rule,
    ancestors,
    is_queue_receiver,
    is_store_receiver,
    receiver_terminal,
)


def _region_of(node: ast.AST, func: ast.AST) -> ast.AST:
    """Innermost loop enclosing ``node`` within ``func`` (or ``func``)."""
    for anc in ancestors(node):
        if isinstance(anc, (ast.For, ast.While)):
            return anc
        if anc is func:
            break
    return func


class DurableBeforeAckRule(Rule):
    rule_id = "R2"
    title = ("a queue ack must not precede the durable store write it "
             "guards (put-then-delete)")

    def check_module(self, module, project):
        if not ({"lease", "handler"} & module.roles):
            return
        for func in ast.walk(module.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            # events: (region id, lineno, kind, label), in source order
            events: List[Tuple[int, int, str, str]] = []
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                # skip calls belonging to a nested def (it has its own pass)
                owner = next(
                    (a for a in ancestors(node)
                     if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))),
                    None,
                )
                if owner is not func:
                    continue
                recv, op = receiver_terminal(node)
                if is_queue_receiver(recv) and op in ACK_OPS:
                    kind = "ack"
                elif is_store_receiver(recv) and op in DURABLE_PUT_OPS:
                    kind = "put"
                else:
                    continue
                region = _region_of(node, func)
                events.append((id(region), node.lineno, kind, f"{recv}.{op}"))
            by_region = {}
            for rid, line, kind, label in sorted(events, key=lambda e: e[1]):
                by_region.setdefault(rid, []).append((line, kind, label))
            for seq in by_region.values():
                for i, (line, kind, label) in enumerate(seq):
                    if kind != "ack":
                        continue
                    later_put = next(
                        (lbl for _ln, k, lbl in seq[i + 1:] if k == "put"),
                        None,
                    )
                    if later_put is not None:
                        yield module.finding(
                            "R2", line,
                            f"queue ack {label}() precedes the durable "
                            f"{later_put}() below it — a crash in the gap "
                            "loses the request (the visibility timeout "
                            "cannot resurface a deleted message); write "
                            "durable state first, then ack",
                        )

"""R4 — counter-registry drift.

Motivating bugs: three times in PRs 6-9 a new ``EngineStats`` counter
was plumbed into some-but-not-all of its consumers — present in
``snapshot()`` but missing from the ``docs/serving.md`` counter tables,
or named in ``benchmarks/check_bench.py``'s schema under a stale name
after a rename — and the drift was only caught by a reviewer reading
diffs side by side.  The three registries can never silently diverge
again:

1. every public ``EngineStats`` field must be covered by
   ``snapshot()`` (the dynamic ``fields(self)`` comprehension covers
   all of them; an explicit-dict rewrite must name each one);
2. every public field must appear (backticked) in ``docs/serving.md``;
3. every key ``check_bench.py`` requires of a report must be a real
   ``EngineStats`` field, a ``snapshot()``-derived key, or declared in
   ``check_bench.DERIVED_KEYS`` (bench-level derived metrics) — a
   renamed counter fails here instead of silently passing a schema
   that no report can satisfy;
4. (absorbed from the standalone ``check_bench`` CLI) every scenario
   block in the bench schema must be referenced by a tier-1 smoke
   assertion in ``tests/test_bench_serving.py``, and a committed
   ``BENCH_serving.json`` must satisfy the schema.
"""

from __future__ import annotations

import ast
import importlib.util
import os
from typing import List, Optional, Set

from repro.analysis.engine import Finding
from repro.analysis.rules.common import Rule

TYPES_PATH = "src/repro/serving/types.py"
DOCS_PATH = "docs/serving.md"
CHECK_BENCH_PATH = os.path.join("benchmarks", "check_bench.py")
BENCH_TEST_PATH = os.path.join("tests", "test_bench_serving.py")
BENCH_REPORT_PATH = "BENCH_serving.json"


def _engine_stats_fields(module) -> List[ast.AnnAssign]:
    for node in ast.walk(module.tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineStats":
            return [
                stmt for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            ]
    return []


def _snapshot_func(module) -> Optional[ast.FunctionDef]:
    for node in ast.walk(module.tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineStats":
            for stmt in node.body:
                if isinstance(stmt, ast.FunctionDef) and stmt.name == "snapshot":
                    return stmt
    return None


def _snapshot_is_dynamic(snap: ast.FunctionDef) -> bool:
    """True when snapshot() iterates ``fields(self)`` — the dynamic form
    that covers every field by construction."""
    for node in ast.walk(snap):
        if isinstance(node, ast.Call):
            fn = node.func
            if (isinstance(fn, ast.Name) and fn.id == "fields") or (
                isinstance(fn, ast.Attribute) and fn.attr == "fields"
            ):
                return True
    return False


def _snapshot_names(snap: ast.FunctionDef) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(snap):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            names.add(node.value)
        if isinstance(node, ast.Attribute):
            names.add(node.attr)
    return names


def _load_check_bench(root: str):
    path = os.path.join(root, CHECK_BENCH_PATH)
    if not os.path.exists(path):
        return None
    spec = importlib.util.spec_from_file_location("_dslint_check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class CounterRegistryRule(Rule):
    rule_id = "R4"
    title = ("EngineStats fields, snapshot(), the docs counter tables and "
             "check_bench's schema must agree (no silent counter drift)")

    def check_project(self, project):
        types_mod = project.module(TYPES_PATH)
        if types_mod is None:
            return
        fields = _engine_stats_fields(types_mod)
        public = [f for f in fields if not f.target.id.startswith("_")]

        # 1. snapshot() coverage
        snap = _snapshot_func(types_mod)
        if snap is None:
            yield types_mod.finding(
                "R4", 1, "EngineStats has no snapshot() method — RESULTS/"
                "bench consumers read it")
        elif not _snapshot_is_dynamic(snap):
            named = _snapshot_names(snap)
            for f in public:
                if f.target.id not in named:
                    yield types_mod.finding(
                        "R4", f,
                        f"counter {f.target.id!r} is not covered by "
                        "snapshot() — it would silently vanish from "
                        "RESULTS.json and the bench report",
                    )

        # 2. docs coverage (backticked mention anywhere in serving.md)
        docs = project.read_text(DOCS_PATH)
        if docs is not None:
            for f in public:
                if f"`{f.target.id}`" not in docs:
                    yield types_mod.finding(
                        "R4", f,
                        f"counter {f.target.id!r} is in snapshot() but "
                        f"missing from the {DOCS_PATH} counter tables — "
                        "operators cannot interpret an undocumented "
                        "counter",
                    )

        # 3 + 4. check_bench schema cross-check and (absorbed) the
        # scenario<->test coverage + committed-report checks
        try:
            cb = _load_check_bench(project.root)
        except Exception as e:  # pragma: no cover - import failure is fatal drift
            yield types_mod.finding(
                "R4", 1, f"benchmarks/check_bench.py failed to load: {e}")
            return
        if cb is None:
            return
        field_names = {f.target.id for f in public}
        snapshot_derived = {"accepted_per_dispatch", "hydration_ticks"}
        derived = set(getattr(cb, "DERIVED_KEYS", ()))
        cb_rel = CHECK_BENCH_PATH.replace(os.sep, "/")
        for scenario, (_path, _engines, engine_keys, block_derived) in (
            getattr(cb, "SCENARIOS", {}) or {}
        ).items():
            for key in tuple(engine_keys) + tuple(block_derived):
                if key in field_names or key in snapshot_derived or key in derived:
                    continue
                yield Finding(
                    rule="R4", path=cb_rel, line=1,
                    message=(
                        f"scenario {scenario!r} requires key {key!r} which "
                        "is neither an EngineStats field, a snapshot()-"
                        "derived key, nor declared in DERIVED_KEYS — a "
                        "renamed/phantom counter"),
                    scope="SCENARIOS", anchor=f"{scenario}:{key}",
                )
        test_src = project.read_text(BENCH_TEST_PATH.replace(os.sep, "/"))
        if test_src is not None and hasattr(cb, "check_test_coverage"):
            for problem in cb.check_test_coverage(test_src):
                yield Finding(
                    rule="R4", path=cb_rel, line=1,
                    message=f"bench coverage: {problem}",
                    scope="coverage", anchor=problem,
                )
        report_text = project.read_text(BENCH_REPORT_PATH)
        if report_text is not None and hasattr(cb, "check_report"):
            import json as _json
            try:
                report = _json.loads(report_text)
            except ValueError:
                report = None
                yield Finding(
                    rule="R4", path=BENCH_REPORT_PATH, line=1,
                    message="committed BENCH_serving.json is not valid JSON",
                    scope="report", anchor="json",
                )
            if report is not None:
                for problem in cb.check_report(report):
                    yield Finding(
                        rule="R4", path=BENCH_REPORT_PATH, line=1,
                        message=f"bench report schema: {problem}",
                        scope="report", anchor=problem,
                    )

"""R5 — thread-shared state must be lock-guarded or ownership-declared.

Motivating bug (PR 8): ``AsyncPublisher`` originally mutated its
``_pending`` dedup map from both the caller thread (``publish``) and the
background worker thread without a lock; under load the map lost
entries and the publisher re-uploaded segments it had already shipped.
The fix guards every ``_pending`` touch with ``self._lock``.

The rule works per class: if a class starts a thread whose target is
one of its *own* methods (``threading.Thread(target=self._run, ...)``)
the attributes that method (transitively, via same-class method calls)
writes form the *worker-side* set; attributes written by the remaining
methods form the *caller-side* set.  Any attribute **written on both
sides** where at least one write is not under a ``with ...lock:`` block
is a finding.  Single-writer attributes (written by one side, read by
the other) pass: CPython attribute stores are atomic, and the repo's
convention is single-writer ownership with the owner declared in the
class docstring.

Suppress with ``# dslint: disable=R5(reason)`` on the offending write
(or the method header) when ownership is established another way —
e.g. a handoff happens-before relationship via ``queue.Queue`` or
``Thread.join``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.rules.common import (
    Rule,
    ancestors,
    dotted_name,
    is_lock_guarded,
    self_attr_target,
)


def _thread_targets(cls: ast.ClassDef) -> Set[str]:
    """Method names of ``cls`` used as ``Thread(target=self.<m>)``."""
    targets: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        fn = dotted_name(node.func)
        if not fn.endswith("Thread"):
            continue
        for kw in node.keywords:
            if kw.arg == "target":
                name = dotted_name(kw.value)
                if name.startswith("self."):
                    targets.add(name.split(".", 1)[1])
    return targets


def _methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {
        stmt.name: stmt
        for stmt in cls.body
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def _owned_by(node: ast.AST, methods: Dict[str, ast.FunctionDef]) -> Optional[str]:
    """Name of the class method whose body contains ``node``."""
    for anc in ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for name, m in methods.items():
                if m is anc:
                    return name
            return None
    return None


def _self_writes(func: ast.AST) -> List[ast.Attribute]:
    """``self.x`` attribute nodes that are write targets in ``func`` —
    assignment, augmented assignment, and in-place mutation through a
    method call (``self.x.append/pop/add/...``) or subscript store."""
    mutators = {
        "append", "extend", "add", "discard", "remove", "pop", "popleft",
        "appendleft", "clear", "update", "setdefault", "insert",
    }
    out: List[ast.Attribute] = []
    for node in ast.walk(func):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                # plain `self.x = ...` and `self.x[k] = ...`
                base = t.value if isinstance(t, ast.Subscript) else t
                if self_attr_target(base):
                    out.append(base)
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in mutators and self_attr_target(node.func.value):
                out.append(node.func.value)
        elif isinstance(node, (ast.Delete,)):
            for t in node.targets:
                base = t.value if isinstance(t, ast.Subscript) else t
                if isinstance(base, ast.Attribute) and self_attr_target(base):
                    out.append(base)
    return out


def _reachable(start: Set[str], methods: Dict[str, ast.FunctionDef]) -> Set[str]:
    """Methods transitively called from ``start`` via ``self.<m>()``."""
    seen = set(start)
    frontier = list(start)
    while frontier:
        name = frontier.pop()
        func = methods.get(name)
        if func is None:
            continue
        for node in ast.walk(func):
            if isinstance(node, ast.Call):
                callee = dotted_name(node.func)
                if callee.startswith("self."):
                    m = callee.split(".", 1)[1]
                    if m in methods and m not in seen:
                        seen.add(m)
                        frontier.append(m)
    return seen


class ThreadSharedStateRule(Rule):
    rule_id = "R5"
    title = ("attributes written from both a Thread target and the caller "
             "side must be lock-guarded (or ownership-declared via pragma)")

    def check_module(self, module, project):
        # lease modules run under ThreadRunner workers: a module-level
        # mutable container is reachable from every worker thread in the
        # process, so it must declare its ownership story (per-worker
        # keying, GIL-atomic single op, ...) via pragma or grow a lock
        if "lease" in module.roles:
            for stmt in module.tree.body:
                if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                    continue
                value = stmt.value
                if value is None or not isinstance(
                    value, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                            ast.ListComp, ast.SetComp)
                ):
                    continue
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                for t in targets:
                    if isinstance(t, ast.Name) and "lock" not in t.id.lower():
                        yield module.finding(
                            "R5", stmt,
                            f"module-level mutable container {t.id} in a "
                            "lease module is shared across worker threads "
                            "— declare its ownership/atomicity story with "
                            "# dslint: disable=R5(reason) or guard it with "
                            "a lock",
                        )
        for cls in ast.walk(module.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            starts = _thread_targets(cls)
            if not starts:
                continue
            methods = _methods(cls)
            worker_methods = _reachable(starts, methods)
            # __init__ runs before the thread starts: its writes are
            # publication, not contention
            caller_methods = {
                n for n in methods
                if n not in worker_methods and n != "__init__"
            }

            def side_writes(names: Set[str]) -> Dict[str, List[ast.Attribute]]:
                writes: Dict[str, List[ast.Attribute]] = {}
                for n in names:
                    for attr_node in _self_writes(methods[n]):
                        writes.setdefault(attr_node.attr, []).append(attr_node)
                return writes

            worker_writes = side_writes(worker_methods)
            caller_writes = side_writes(caller_methods)
            shared = set(worker_writes) & set(caller_writes)
            for attr in sorted(shared):
                if "lock" in attr or "mutex" in attr:
                    continue  # the lock object itself
                unguarded = [
                    n for n in worker_writes[attr] + caller_writes[attr]
                    if not is_lock_guarded(n)
                ]
                for node in unguarded:
                    owner = _owned_by(node, methods)
                    yield module.finding(
                        "R5", node,
                        f"attribute self.{attr} is written from both the "
                        f"{cls.name} thread target and the caller side, but "
                        f"this write (in {owner or '?'}) is not under a "
                        "lock — guard it with `with self._lock:` or declare "
                        "single-writer ownership with a pragma",
                    )

"""R6 — kernel-oracle parity.

Motivating gap (PR 7): ``paged_verify`` shipped as a public kernel
entry point with no dedicated parity test — it happened to delegate to
``paged_attention`` so nothing caught the hole, but a later rewrite of
the delegation would have gone untested.  Accelerated kernels are only
trustworthy against a plain-``jnp`` oracle.

For every public function in ``src/repro/kernels/ops.py`` (no leading
underscore, defined at module level) the rule requires:

1. a registered oracle: an entry in ``kernels/ref.py``'s ``ORACLES``
   dict mapping the op name to its reference implementation;
2. a parity test: the op name appears in ``tests/test_kernels.py``
   (any reference — the test imports and calls it).
"""

from __future__ import annotations

import ast
import os

from repro.analysis.engine import Finding
from repro.analysis.rules.common import Rule

OPS_PATH = "src/repro/kernels/ops.py"
REF_PATH = "src/repro/kernels/ref.py"
TESTS_PATH = os.path.join("tests", "test_kernels.py")


def _public_functions(module):
    for stmt in module.tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not stmt.name.startswith("_"):
                yield stmt


def _oracle_keys(module):
    """String keys of the module-level ``ORACLES = {...}`` dict."""
    for stmt in module.tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        else:
            continue
        names = {t.id for t in targets if isinstance(t, ast.Name)}
        if "ORACLES" not in names:
            continue
        if isinstance(stmt.value, ast.Dict):
            return {
                k.value for k in stmt.value.keys
                if isinstance(k, ast.Constant) and isinstance(k.value, str)
            }
    return None


class KernelOracleRule(Rule):
    rule_id = "R6"
    title = ("every public kernels/ops.py entry point needs an ORACLES "
             "registry entry in kernels/ref.py and a parity test")

    def check_project(self, project):
        ops_mod = project.module(OPS_PATH)
        if ops_mod is None:
            return
        ref_mod = project.module(REF_PATH)
        oracle_keys = _oracle_keys(ref_mod) if ref_mod is not None else None
        if ref_mod is not None and oracle_keys is None:
            yield Finding(
                rule="R6", path=REF_PATH, line=1,
                message=("kernels/ref.py has no module-level ORACLES dict — "
                         "the op-name -> reference-fn registry R6 checks "
                         "against"),
                scope="", anchor="ORACLES",
            )
            oracle_keys = set()
        tests_src = project.read_text(TESTS_PATH.replace(os.sep, "/"))
        for fn in _public_functions(ops_mod):
            if oracle_keys is not None and fn.name not in oracle_keys:
                yield ops_mod.finding(
                    "R6", fn,
                    f"kernel entry point {fn.name}() has no ORACLES entry in "
                    "kernels/ref.py — register its plain-jnp reference "
                    "implementation",
                )
            if tests_src is not None and fn.name not in tests_src:
                yield ops_mod.finding(
                    "R6", fn,
                    f"kernel entry point {fn.name}() is never referenced in "
                    "tests/test_kernels.py — add a parity test against its "
                    "oracle",
                )

"""dslint rule registry.

``ALL_RULES`` is the ordered list the engine runs by default.  Adding a
rule: write a module here with a ``Rule`` subclass, instantiate it in
``ALL_RULES``, document it in ``docs/analysis.md``, and add tripping +
passing fixtures under ``tests/fixtures/dslint/``.
"""

from __future__ import annotations

from repro.analysis.rules.common import Rule
from repro.analysis.rules.counters import CounterRegistryRule
from repro.analysis.rules.determinism import DeterminismRule
from repro.analysis.rules.durability import DurableBeforeAckRule
from repro.analysis.rules.kernels import KernelOracleRule
from repro.analysis.rules.knobs import InertKnobRule
from repro.analysis.rules.retry import RetryDisciplineRule
from repro.analysis.rules.threads import ThreadSharedStateRule

ALL_RULES = [
    RetryDisciplineRule(),
    DurableBeforeAckRule(),
    DeterminismRule(),
    CounterRegistryRule(),
    ThreadSharedStateRule(),
    KernelOracleRule(),
    InertKnobRule(),
]

__all__ = ["ALL_RULES", "Rule"]

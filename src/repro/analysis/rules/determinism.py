"""R3 — byte-determinism of engine-tick paths.

Motivating discipline (PR 1 onward, load-bearing since PR 5/7/8): the
bench gates and every recovery/chaos drill assert *byte-identical*
outputs across replays, preemption reruns, checkpoint resumes and
fleet A/B legs.  That only holds because engine ticks are pure
functions of (seeded streams, admission order): sampling uses
``(seed, stream, step)``-keyed draws, timing is counted in engine
ticks, and nothing on the tick path consults a wall clock or an
unseeded RNG.

The rule bans, in tick-role modules (``serving/engine.py``,
``scheduler.py``, ``sampling.py``, ``speculate.py``,
``cache_manager.py``, ``prefix_cache.py``):

- wall-clock / entropy calls: ``time.time``, ``time.monotonic``,
  ``time.perf_counter``, ``datetime.now``, ``os.urandom``,
  ``uuid.uuid4``;
- unseeded global RNGs: bare ``random.*`` and ``np.random.<draw>``
  (``np.random.default_rng(seed)`` and ``jax.random`` streams are
  fine — they are explicitly seeded);
- iteration over an unordered ``set`` (``for x in some_set``, or a
  comprehension over one): Python sets iterate in hash order, which
  varies with insertion history and ``PYTHONHASHSEED``.  Membership
  tests and ``len()`` are fine; wrap iteration in ``sorted(...)``.
"""

from __future__ import annotations

import ast
from typing import Set

from repro.analysis.rules.common import Rule, call_name, dotted_name

BANNED_CALLS = {
    "time.time": "wall clock",
    "time.monotonic": "wall clock",
    "time.perf_counter": "wall clock",
    "datetime.now": "wall clock",
    "datetime.datetime.now": "wall clock",
    "datetime.utcnow": "wall clock",
    "datetime.datetime.utcnow": "wall clock",
    "os.urandom": "entropy source",
    "uuid.uuid4": "entropy source",
    "uuid.uuid1": "entropy source",
}
# unseeded-global-RNG roots; np.random.default_rng(seed) is exempted
RNG_ROOTS = ("random.", "np.random.", "numpy.random.")
RNG_EXEMPT = {"np.random.default_rng", "numpy.random.default_rng",
              "random.Random"}


def _set_names(func: ast.AST) -> Set[str]:
    """Names bound to a set within ``func`` (literal, ``set()`` call,
    set comprehension), plus ``self.<attr>`` assigned a set anywhere in
    the module's classes (tracked by the caller via prefix ``self.``)."""
    names: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            value = node.value
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            if value is None:
                continue
            is_set = (
                isinstance(value, (ast.Set, ast.SetComp))
                or (isinstance(value, ast.Call) and call_name(value) == "set")
            )
            if not is_set:
                continue
            for t in targets:
                name = dotted_name(t)
                if name:
                    names.add(name)
    return names


class DeterminismRule(Rule):
    rule_id = "R3"
    title = ("no wall clock / unseeded RNG / unordered-set iteration on "
             "engine-tick paths (byte-identical replay is a bench gate)")

    def check_module(self, module, project):
        if "tick" not in module.roles:
            return
        set_names = _set_names(module.tree)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name in BANNED_CALLS:
                    yield module.finding(
                        "R3", node,
                        f"{name}() is a {BANNED_CALLS[name]} — engine ticks "
                        "must be pure functions of seeded streams and "
                        "admission order (use the injected clock / the "
                        "(seed, stream, step) sampling keys)",
                    )
                elif (
                    any(name.startswith(r) for r in RNG_ROOTS)
                    and name not in RNG_EXEMPT
                ):
                    yield module.finding(
                        "R3", node,
                        f"{name}() draws from an unseeded global RNG — "
                        "replay cannot reproduce it; use an explicitly "
                        "seeded generator",
                    )
            iters = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters = [node.iter]
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters = [g.iter for g in node.generators]
            for it in iters:
                if isinstance(it, ast.Call) and call_name(it) == "set":
                    yield module.finding(
                        "R3", it,
                        "iterating a set() directly — set order follows "
                        "PYTHONHASHSEED, not program state; wrap in "
                        "sorted(...)",
                    )
                elif dotted_name(it) in set_names:
                    yield module.finding(
                        "R3", it,
                        f"iterating set {dotted_name(it)!r} — unordered "
                        "iteration breaks byte-identical replay; wrap in "
                        "sorted(...)",
                    )

"""Shared AST helpers for the dslint rules."""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Tuple, Type


class Rule:
    """Base class: rules override one or both hooks."""

    rule_id: str = "R?"
    title: str = ""

    def check_module(self, module, project):  # noqa: ARG002 - interface
        return ()

    def check_project(self, project):  # noqa: ARG002 - interface
        return ()


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    """Walk parent links attached by the engine (innermost first)."""
    cur = getattr(node, "_dslint_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_dslint_parent", None)


def enclosing(node: ast.AST, *types: Type[ast.AST]) -> Optional[ast.AST]:
    for anc in ancestors(node):
        if isinstance(anc, types):
            return anc
    return None


def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, "" for anything else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def receiver_terminal(call: ast.Call) -> Tuple[str, str]:
    """For a method call ``recv.op(...)``: (terminal receiver name, op).

    The terminal name is the last attribute/name of the receiver chain
    (``ctx.store.put_json`` -> ("store", "put_json"); ``rq.delete`` ->
    ("rq", "delete")).  Non-method calls return ("", "")."""
    if not isinstance(call.func, ast.Attribute):
        return "", ""
    recv = call.func.value
    if isinstance(recv, ast.Attribute):
        return recv.attr, call.func.attr
    if isinstance(recv, ast.Name):
        return recv.id, call.func.attr
    return "", ""


def is_store_receiver(name: str) -> bool:
    return name == "store" or name.endswith("store")


def is_queue_receiver(name: str) -> bool:
    return name in ("rq", "dq", "queue") or name.endswith("queue")


STORE_OPS = frozenset({
    "put_bytes", "get_bytes", "put_json", "get_json",
    "list", "exists", "delete", "delete_prefix",
})
QUEUE_OPS = frozenset({
    "send", "send_batch", "receive", "receive_batch",
    "delete", "delete_batch", "release", "change_visibility",
    "redrive_dead_letters",
})
# acks make a message unrecoverable; durable puts are what must precede
ACK_OPS = frozenset({"delete", "delete_batch"})
DURABLE_PUT_OPS = frozenset({"put_json", "put_bytes"})

# wrappers that give a call transient-fault retry (the PR 8 discipline)
RETRY_WRAPPERS = frozenset({"_with_retries", "_retry_transient"})


def in_retry_context(call: ast.Call) -> bool:
    """True if ``call`` runs under a retry wrapper: lexically inside a
    ``_with_retries(...)`` / ``_retry_transient(...)`` argument, inside
    the wrapper's own definition, or inside ``AsyncPublisher`` (whose
    worker retries every put with capped content-keyed backoff)."""
    for anc in ancestors(call):
        if isinstance(anc, ast.Call):
            name = call_name(anc).rsplit(".", 1)[-1]
            if name in RETRY_WRAPPERS:
                return True
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if anc.name in RETRY_WRAPPERS:
                return True
        if isinstance(anc, ast.ClassDef) and anc.name == "AsyncPublisher":
            return True
    return False


def self_attr_target(node: ast.AST) -> Optional[str]:
    """``self.x`` in a store context -> "x"."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def is_lock_guarded(node: ast.AST) -> bool:
    """True when an ancestor ``with`` acquires something lock-like
    (``with self._lock:``, ``with lock:``, ``with self.mutex:``)."""
    for anc in ancestors(node):
        if isinstance(anc, ast.With):
            for item in anc.items:
                name = dotted_name(item.context_expr).lower()
                if "lock" in name or "mutex" in name:
                    return True
    return False

"""Rule engine: file walking, AST parsing, pragmas, baseline, reporting.

The engine is rule-agnostic.  A rule is an object with

- ``rule_id``    — ``"R1"`` ... (``"R0"`` is reserved for the engine's
  own pragma/baseline hygiene findings);
- ``title``      — one line for ``--list-rules``;
- ``check_module(module, project)`` — per-file findings (default: none);
- ``check_project(project)``        — cross-file findings (default: none).

Findings carry a *fingerprint* — a content hash of (rule, path,
enclosing scope, normalized source line) — deliberately excluding the
line number, so a committed baseline survives unrelated edits above
the finding.

Suppression:

- ``# dslint: disable=R1(reason)`` on the finding's own line or on the
  enclosing ``def``/``class`` header line.  Several rules may share one
  pragma: ``disable=R1(reason),R5(other reason)``.  A pragma with a
  missing/empty reason or an unknown rule id is itself an R0 finding.
- ``baseline.json`` (committed next to this package): fingerprint ->
  ``{"rule", "path", "message", "justification"}``.  Entries without a
  non-empty justification are R0 findings; entries whose finding no
  longer fires are reported as stale (fix: ``--update-baseline``).
"""

from __future__ import annotations

import ast
import hashlib
import json
import os
import re
import subprocess
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")

# module roles drive rule scoping (R1 lease-path, R3 tick-path, ...).
# Keyed by path relative to the repo root, forward slashes.
ROLE_BY_PATH: Dict[str, Tuple[str, ...]] = {
    "src/repro/launch/serve.py": ("lease",),
    "src/repro/serving/prefix_store.py": ("lease",),
    "src/repro/core/worker.py": ("handler",),
    "src/repro/serving/engine.py": ("tick",),
    "src/repro/serving/scheduler.py": ("tick",),
    "src/repro/serving/sampling.py": ("tick",),
    "src/repro/serving/speculate.py": ("tick",),
    "src/repro/serving/cache_manager.py": ("tick",),
    "src/repro/serving/prefix_cache.py": ("tick",),
}

# a fixture/test file can claim roles explicitly in its first lines:
#   # dslint-role: lease,tick
_ROLE_RE = re.compile(r"#\s*dslint-role:\s*([\w,\s-]+)")
_PRAGMA_RE = re.compile(r"#\s*dslint:\s*disable=(.*)$")
# one disable item: R<digits> optionally followed by (reason)
_ITEM_RE = re.compile(r"(R\d+)\s*(?:\(([^()]*)\))?")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # repo-relative, forward slashes
    line: int
    message: str
    # enclosing def/class qualname ("" at module level): part of the
    # fingerprint so identical lines in different functions stay distinct
    scope: str = ""
    # the normalized source line the finding anchors to (fingerprint input)
    anchor: str = ""

    @property
    def fingerprint(self) -> str:
        blob = "|".join((self.rule, self.path, self.scope, self.anchor))
        return hashlib.sha1(blob.encode("utf-8")).hexdigest()[:16]

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class ParsedModule:
    """One parsed source file plus the lookup maps rules need."""

    def __init__(self, root: str, relpath: str, source: str):
        self.root = root
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        # parent links (ast has none): rules climb these to find retry
        # wrappers, enclosing classes, loops, ...
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._dslint_parent = node  # type: ignore[attr-defined]
        self.roles: Set[str] = set(ROLE_BY_PATH.get(self.relpath, ()))
        for ln in self.lines[:5]:
            m = _ROLE_RE.search(ln)
            if m:
                self.roles |= {r.strip() for r in m.group(1).split(",") if r.strip()}
        # pragma map: 1-based line -> {rule_id -> reason-or-None}
        self.pragmas: Dict[int, Dict[str, Optional[str]]] = {}
        for i, ln in enumerate(self.lines, 1):
            m = _PRAGMA_RE.search(ln)
            if m:
                self.pragmas[i] = {
                    rid: (reason.strip() if reason is not None else None)
                    for rid, reason in _ITEM_RE.findall(m.group(1))
                }
        # scope intervals: (start, end, header_line, qualname) for every
        # def/class, innermost-last so lookups prefer the tightest scope
        self._scopes: List[Tuple[int, int, int, str]] = []
        self._collect_scopes(self.tree, ())
        self._scopes.sort(key=lambda s: (s[0], -s[1]))

    def _collect_scopes(self, node: ast.AST, qual: Tuple[str, ...]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                q = qual + (child.name,)
                end = getattr(child, "end_lineno", child.lineno) or child.lineno
                self._scopes.append((child.lineno, end, child.lineno, ".".join(q)))
                self._collect_scopes(child, q)
            else:
                self._collect_scopes(child, qual)

    # ------------------------------------------------------------ lookups
    def scope_of(self, line: int) -> str:
        """Innermost def/class qualname containing ``line`` ("" = module)."""
        best = ""
        for start, end, _hdr, qual in self._scopes:
            if start <= line <= end:
                best = qual
        return best

    def scope_headers(self, line: int) -> List[int]:
        """Header lines of every def/class enclosing ``line``, innermost
        last — the lines a pragma may sit on besides the finding's own."""
        return [hdr for start, end, hdr, _q in self._scopes if start <= line <= end]

    def anchor_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return " ".join(self.lines[line - 1].split())
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(
            rule=rule,
            path=self.relpath,
            line=int(line),
            message=message,
            scope=self.scope_of(int(line)),
            anchor=self.anchor_text(int(line)),
        )

    def pragma_for(self, line: int, rule: str) -> Optional[Tuple[int, Optional[str]]]:
        """The pragma suppressing ``rule`` at ``line``: checks the line
        itself, then enclosing def/class headers.  Returns (pragma line,
        reason) or None."""
        for cand in [line] + self.scope_headers(line):
            rules = self.pragmas.get(cand)
            if rules is not None and rule in rules:
                return cand, rules[rule]
        return None


class Project:
    """The parsed tree handed to rules.

    ``modules`` maps repo-relative path -> :class:`ParsedModule` for every
    lintable file.  ``root`` is the repo root: project rules locate their
    registries (``docs/serving.md``, ``benchmarks/check_bench.py``,
    ``tests/``) relative to it and must *skip quietly* when an anchor
    file is absent (fixture trees are minimal)."""

    def __init__(self, root: str, modules: Dict[str, ParsedModule]):
        self.root = root
        self.modules = modules
        self.errors: List[str] = []

    # convenience for rules ------------------------------------------------
    def module(self, relpath: str) -> Optional[ParsedModule]:
        return self.modules.get(relpath)

    def with_role(self, role: str) -> List[ParsedModule]:
        return [m for m in self.modules.values() if role in m.roles]

    def read_text(self, relpath: str) -> Optional[str]:
        path = os.path.join(self.root, relpath)
        try:
            with open(path, encoding="utf-8") as f:
                return f.read()
        except OSError:
            return None


def _iter_py_files(base: str) -> Iterable[str]:
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = sorted(
            d for d in dirnames if d not in ("__pycache__", ".git")
        )
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def load_project(
    root: str,
    paths: Optional[Sequence[str]] = None,
    *,
    src_prefix: str = os.path.join("src", "repro"),
) -> Project:
    """Parse every ``.py`` under ``root/src/repro`` (or just ``paths``,
    repo-relative).  Unparseable files become project errors, not crashes
    — a syntax error is pytest's job to report, not ours to mask."""
    root = os.path.abspath(root)
    files: List[str] = []
    if paths:
        files = [os.path.join(root, p) for p in paths]
    else:
        base = os.path.join(root, src_prefix)
        if os.path.isdir(base):
            files = list(_iter_py_files(base))
    modules: Dict[str, ParsedModule] = {}
    errors: List[str] = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        except OSError as e:
            errors.append(f"{rel}: unreadable ({e})")
            continue
        try:
            modules[rel] = ParsedModule(root, rel, source)
        except SyntaxError as e:
            errors.append(f"{rel}: syntax error at line {e.lineno}")
    project = Project(root, modules)
    project.errors = errors
    return project


def changed_files(root: str) -> List[str]:
    """Repo-relative ``src/repro/**.py`` files differing from HEAD
    (tracked changes + untracked), for ``--changed`` fast mode."""
    out: Set[str] = set()
    for args in (
        ["git", "diff", "--name-only", "HEAD", "--"],
        ["git", "ls-files", "--others", "--exclude-standard"],
    ):
        try:
            res = subprocess.run(
                args, cwd=root, capture_output=True, text=True, check=True
            )
        except (OSError, subprocess.CalledProcessError):
            return []  # not a git checkout: caller falls back to full run
        out |= {ln.strip() for ln in res.stdout.splitlines() if ln.strip()}
    return sorted(
        p for p in out
        if p.startswith("src/repro/") and p.endswith(".py")
        and os.path.exists(os.path.join(root, p))
    )


# ------------------------------------------------------------------ baseline
def load_baseline(path: str) -> Dict[str, Dict]:
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
    except OSError:
        return {}
    return data if isinstance(data, dict) else {}


def save_baseline(path: str, entries: Dict[str, Dict]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(entries, f, indent=2, sort_keys=True)
        f.write("\n")


@dataclass
class Report:
    """Outcome of one analysis run."""

    findings: List[Finding] = field(default_factory=list)  # unbaselined, unsuppressed
    suppressed: List[Tuple[Finding, str]] = field(default_factory=list)  # (finding, reason)
    baselined: List[Tuple[Finding, str]] = field(default_factory=list)  # (finding, justification)
    stale_baseline: List[str] = field(default_factory=list)  # fingerprints that no longer fire
    errors: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def render(self) -> str:
        out = [f.render() for f in self.findings]
        out += [f"[error] {e}" for e in self.errors]
        for fp in self.stale_baseline:
            out.append(
                f"[stale-baseline] {fp}: finding no longer fires — remove it "
                "(python -m repro.analysis --update-baseline)"
            )
        n = len(self.findings)
        out.append(
            f"dslint: {n} finding(s), {len(self.suppressed)} pragma-suppressed, "
            f"{len(self.baselined)} baselined"
            + ("" if not self.stale_baseline else
               f", {len(self.stale_baseline)} stale baseline entr(y/ies)")
        )
        return "\n".join(out)


def _pragma_hygiene(module: ParsedModule, known_rules: Set[str]) -> List[Finding]:
    """R0: malformed pragmas — unknown rule id, or an empty reason."""
    findings = []
    for line, rules in sorted(module.pragmas.items()):
        for rid, reason in sorted(rules.items()):
            if rid not in known_rules and rid != "R0":
                findings.append(module.finding(
                    "R0", line, f"pragma disables unknown rule {rid!r}"))
            if not reason:
                findings.append(module.finding(
                    "R0", line,
                    f"pragma for {rid} has no reason — write why: "
                    "# dslint: disable=Rx(reason)"))
    return findings


def run_analysis(
    root: str,
    *,
    paths: Optional[Sequence[str]] = None,
    rules: Optional[Sequence] = None,
    baseline_path: Optional[str] = None,
    project: Optional[Project] = None,
) -> Report:
    """Lint ``root`` and return a :class:`Report`.

    ``paths`` restricts *per-module* rules to those files; project-wide
    rules (counter drift, kernel parity, inert knobs) always run — they
    read a handful of registry files and are cheap."""
    if rules is None:
        from repro.analysis.rules import ALL_RULES
        rules = ALL_RULES
    if project is None:
        project = load_project(root, paths)
        if paths:
            # project rules need the registry modules even in --changed
            # mode: merge in the full tree for context, but only report
            # per-module findings for the selected paths
            full = load_project(root)
            for rel, mod in full.modules.items():
                project.modules.setdefault(rel, mod)
    selected = {p.replace(os.sep, "/") for p in paths} if paths else None

    known = {r.rule_id for r in rules}
    raw: List[Finding] = []
    for mod in project.modules.values():
        if selected is not None and mod.relpath not in selected:
            continue
        raw.extend(_pragma_hygiene(mod, known))
        for rule in rules:
            raw.extend(rule.check_module(mod, project))
    for rule in rules:
        raw.extend(rule.check_project(project))

    baseline = load_baseline(baseline_path or DEFAULT_BASELINE)
    report = Report(errors=list(project.errors))
    seen_fps: Set[str] = set()
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule, f.message)):
        seen_fps.add(f.fingerprint)
        mod = project.module(f.path)
        pragma = mod.pragma_for(f.line, f.rule) if mod is not None else None
        if f.rule != "R0" and pragma is not None:
            _line, reason = pragma
            report.suppressed.append((f, reason or ""))
            continue
        entry = baseline.get(f.fingerprint)
        if entry is not None:
            justification = str(entry.get("justification", "")).strip()
            if justification:
                report.baselined.append((f, justification))
                continue
            report.findings.append(Finding(
                rule="R0", path=f.path, line=f.line, scope=f.scope,
                anchor=f.anchor,
                message=(f"baseline entry {f.fingerprint} has no written "
                         f"justification (covers: {f.message})"),
            ))
            continue
        report.findings.append(f)
    if selected is None:
        # stale entries are only decidable on a full run: a --changed run
        # simply did not look where the baselined finding lives
        report.stale_baseline = sorted(set(baseline) - seen_fps)
    return report


def update_baseline(
    root: str,
    *,
    justification: str,
    baseline_path: Optional[str] = None,
) -> Report:
    """Re-baseline: current unbaselined findings are added with
    ``justification``; stale entries are dropped.  Refuses an empty
    justification — the baseline exists to *record* why."""
    if not justification.strip():
        raise ValueError(
            "refusing to baseline without a justification "
            "(--justify 'why this finding is acceptable')"
        )
    path = baseline_path or DEFAULT_BASELINE
    report = run_analysis(root, baseline_path=path)
    entries = load_baseline(path)
    for fp in report.stale_baseline:
        entries.pop(fp, None)
    for f in report.findings:
        if f.rule == "R0":
            continue  # hygiene findings are never baselinable
        entries[f.fingerprint] = {
            "rule": f.rule,
            "path": f.path,
            "message": f.message,
            "justification": justification.strip(),
        }
    save_baseline(path, entries)
    return report

"""Mamba2-1.3B [arXiv:2405.21060; unverified]: attention-free SSD."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("mamba2-1.3b")
def mamba2_1p3b() -> ArchConfig:
    return ArchConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=128,
        tie_embeddings=True,
        source="[arXiv:2405.21060; unverified]",
    )

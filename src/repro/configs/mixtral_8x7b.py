"""Mixtral-8x7B [arXiv:2401.04088; hf]: 8 experts top-2, SWA."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("mixtral-8x7b")
def mixtral_8x7b() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=0,  # every FFN is MoE
        vocab_size=32000,
        n_experts=8,
        top_k=2,
        moe_d_ff=14336,
        sliding_window=4096,
        activation="silu",
        rope_theta=1_000_000.0,
        source="[arXiv:2401.04088; hf]",
    )

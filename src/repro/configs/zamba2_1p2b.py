"""Zamba2-1.2B [arXiv:2411.15242; hf]: Mamba2 backbone + one shared
attention block (width 2*d_model) invoked every 6 layers with
per-invocation LoRA."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("zamba2-1.2b")
def zamba2_1p2b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_headdim=64,
        ssm_chunk=128,
        shared_attn_every=6,
        shared_attn_lora_rank=128,
        activation="gelu_gated",
        source="[arXiv:2411.15242; hf]",
    )

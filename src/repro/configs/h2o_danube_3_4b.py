"""H2O-Danube3-4B [arXiv:2401.16818; unverified]: llama+mistral mix, SWA."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("h2o-danube-3-4b")
def h2o_danube_3_4b() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        head_dim=120,
        sliding_window=4096,  # mistral-style SWA -> sub-quadratic
        activation="silu",
        rope_theta=10_000.0,
        source="[arXiv:2401.16818; unverified]",
    )

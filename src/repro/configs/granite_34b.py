"""Granite-34B-Code [arXiv:2405.04324; hf]: llama-style dense, MQA (kv=1)."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("granite-34b")
def granite_34b() -> ArchConfig:
    return ArchConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,  # MQA
        d_ff=24576,
        vocab_size=49152,
        # ungated GELU matches the published 34B total (gpt_bigcode-style
        # MLP); a gated MLP would give 47B.  See DESIGN.md.
        activation="gelu",
        rope_theta=10_000.0,
        source="[arXiv:2405.04324; hf]",
    )

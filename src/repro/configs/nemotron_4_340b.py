"""Nemotron-4-340B [arXiv:2402.16819; unverified]: dense GQA, squared-ReLU MLP."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("nemotron-4-340b")
def nemotron_4_340b() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        activation="relu2",  # squared ReLU, ungated
        rope_theta=10_000.0,
        source="[arXiv:2402.16819; unverified]",
    )

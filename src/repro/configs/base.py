"""Architecture configs and input-shape cells.

Every assigned architecture is a frozen :class:`ArchConfig`; the registry
maps ``--arch <id>`` to one.  Input shapes are the four assigned cells
(``train_4k``, ``prefill_32k``, ``decode_32k``, ``long_500k``);
:func:`cell_applicable` encodes the skip rules documented in DESIGN.md
§Arch-applicability (e.g. ``long_500k`` only for sub-quadratic archs).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

VOCAB_PAD_MULTIPLE = 2048  # lcm-safe for tp=16 and 128-lane tiling


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # -- attention ---------------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 = full attention
    attn_logit_softcap: float = 0.0

    # -- mlp ----------------------------------------------------------------
    activation: str = "silu"  # silu (gated) | gelu (gated) | relu2 (ungated)

    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    # layers that stay dense in a MoE model (deepseek-v2: first layer dense)
    first_k_dense: int = 0
    capacity_factor: float = 1.25

    # -- MLA (deepseek) ----------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_chunk: int = 128

    # -- hybrid (zamba2): shared attention block every k SSM layers -----------
    shared_attn_every: int = 0  # 0 = not hybrid
    shared_attn_lora_rank: int = 0

    # -- encoder-decoder (whisper) ----------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame embeddings fed to the encoder

    # -- vlm (internvl2): patch embeddings prepended to the text stream ----------
    n_vision_tokens: int = 0

    # -- misc ---------------------------------------------------------------------
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_position_embeddings: int = 0  # 0 = rope (unbounded); >0 = learned

    source: str = ""  # provenance tag "[arXiv:...; tier]"

    # ------------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, VOCAB_PAD_MULTIPLE)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def uses_subquadratic_attention(self) -> bool:
        """Can this arch run 500k-token decode? (DESIGN §Arch-applicability)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    # -- parameter counting (for MODEL_FLOPS and roofline) ------------------------
    def param_count(self) -> int:
        return _param_count(self)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def flops_per_token(self, seq_len: int, *, decode: bool = False) -> float:
        """Model FLOPs per token: 6N for train, 2N for a decode step, plus
        attention score/value terms (which 6N misses)."""
        n = self.active_param_count()
        mult = 2.0 if decode else 6.0
        flops = mult * n
        # attention O(S) term per token: 2*2*H*hd*S_kv (scores + values), x3 for bwd
        if self.family != "ssm":
            s_kv = seq_len
            if self.sliding_window:
                s_kv = min(seq_len, self.sliding_window)
            attn_layers = self.n_layers
            if self.shared_attn_every:
                attn_layers = self.n_layers // self.shared_attn_every
            h = self.n_heads
            hd = self.resolved_head_dim
            if self.use_mla:
                hd = self.nope_head_dim + self.rope_head_dim
            per_tok = 2 * 2 * h * hd * (s_kv if decode else s_kv / 2) * attn_layers
            flops += per_tok * (1.0 if decode else 3.0)
        return flops


def _param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    hd = cfg.resolved_head_dim
    total = V * D  # embed
    if not cfg.tie_embeddings:
        total += D * V  # lm_head

    def attn_params() -> int:
        if cfg.use_mla:
            p = D * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * (
                cfg.nope_head_dim + cfg.rope_head_dim
            )
            p += D * (cfg.kv_lora_rank + cfg.rope_head_dim)
            p += cfg.kv_lora_rank * cfg.n_heads * (cfg.nope_head_dim + cfg.v_head_dim)
            p += cfg.n_heads * cfg.v_head_dim * D
            return p
        q = D * cfg.n_heads * hd
        kv = 2 * D * cfg.n_kv_heads * hd
        o = cfg.n_heads * hd * D
        b = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd if cfg.qkv_bias else 0
        return q + kv + o + b

    def mlp_params(f: int) -> int:
        gated = cfg.activation in ("silu", "gelu_gated")
        return (3 if gated else 2) * D * f

    def ssm_params() -> int:
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
        in_proj = D * (2 * di + 2 * n + h)  # z, x, B, C, dt
        conv = (di + 2 * n) * cfg.ssm_conv
        out = di * D
        extra = 3 * h + di  # A_log, D, dt_bias, norm
        return in_proj + conv + out + extra

    per_layer = 0
    if cfg.family == "ssm":
        per_layer = ssm_params()
        total += cfg.n_layers * per_layer
    elif cfg.family == "hybrid":
        total += cfg.n_layers * ssm_params()
        n_inv = cfg.n_layers // max(cfg.shared_attn_every, 1)
        shared = attn_params() + mlp_params(F)
        total += shared  # weights are shared across invocations
        if cfg.shared_attn_lora_rank:
            r = cfg.shared_attn_lora_rank
            total += n_inv * (2 * D * r)  # per-invocation LoRA on wq
    elif cfg.family == "moe":
        dense_ff = mlp_params(F) if F else 0
        experts = cfg.n_experts * mlp_params(cfg.moe_d_ff) + D * cfg.n_experts
        shared = cfg.n_shared_experts * mlp_params(cfg.moe_d_ff)
        active_experts = cfg.top_k * mlp_params(cfg.moe_d_ff) + D * cfg.n_experts
        for layer in range(cfg.n_layers):
            per = attn_params()
            if layer < cfg.first_k_dense:
                per += dense_ff
            else:
                per += (active_experts if active_only else experts) + shared
            total += per
    else:  # dense / audio / vlm
        per_layer = attn_params() + mlp_params(F)
        total += cfg.n_layers * per_layer
        if cfg.is_encoder_decoder:
            # encoder layers + decoder cross-attention
            total += cfg.n_encoder_layers * (attn_params() + mlp_params(F))
            total += cfg.n_layers * attn_params()  # cross-attn per decoder layer
    return int(total)


# ---------------------------------------------------------------- input shapes
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
    # serving-engine hot paths (chunked prefill writes the decode cache in
    # one dispatch; ragged decode advances per-row positions [B] — the
    # continuous-batching step ServeEngine issues once per tick;
    # serve_paged lowers the same ragged decode against the PAGED cache:
    # a shared page pool half the dense reservation plus a page table)
    "serve_prefill_32k": ShapeSpec("serve_prefill_32k", 32_768, 32, "serve_prefill"),
    "serve_ragged_32k": ShapeSpec("serve_ragged_32k", 32_768, 128, "serve_decode"),
    "serve_paged_32k": ShapeSpec("serve_paged_32k", 32_768, 128, "serve_paged"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable cell?  DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.uses_subquadratic_attention:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    if shape.kind == "serve_prefill":
        # mirror Model.supports_fused_prefill + the rolling-cache gate
        if cfg.is_encoder_decoder or cfg.n_vision_tokens:
            return False, "serve_prefill skipped: side inputs (enc-dec/vlm)"
        if cfg.family == "moe":
            return False, "serve_prefill skipped: MoE capacity is batch-shaped"
        if cfg.sliding_window:
            return False, "serve_prefill skipped: rolling sliding-window cache"
    if shape.kind == "serve_paged":
        # mirror Model.supports_paged_cache
        if cfg.family in ("ssm", "hybrid"):
            return False, "serve_paged skipped: O(1) recurrent state, nothing to page"
        if cfg.is_encoder_decoder:
            return False, "serve_paged skipped: static enc-dec cross cache"
        if cfg.sliding_window:
            return False, "serve_paged skipped: rolling sliding-window cache"
    return True, ""


# ---------------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> ArchConfig:
    # import the config modules lazily so the registry is populated
    from repro import configs as _c  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: Dict = dict(
        n_layers=max(2, cfg.shared_attn_every or 0) * 2 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16 if cfg.n_heads else 0,
    )
    if cfg.family == "moe":
        small.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.use_mla:
        small.update(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                     nope_head_dim=16, v_head_dim=16, head_dim=0)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16, d_model=64)
    if cfg.shared_attn_every:
        small.update(shared_attn_every=2, n_layers=4,
                     shared_attn_lora_rank=min(cfg.shared_attn_lora_rank, 8))
    if cfg.is_encoder_decoder:
        small.update(n_encoder_layers=2, encoder_seq=32)
    if cfg.n_vision_tokens:
        small.update(n_vision_tokens=8)
    if cfg.sliding_window:
        small.update(sliding_window=32)
    if cfg.max_position_embeddings:
        small.update(max_position_embeddings=512)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

"""The examples' ~100M-parameter LM ("the Something" the DS control plane
distributes in quickstart/train examples)."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("ds-paper-100m")
def ds_paper_100m() -> ArchConfig:
    return ArchConfig(
        name="ds-paper-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=32768,
        activation="silu",
        source="[examples; synthetic]",
    )

"""Whisper-tiny [arXiv:2212.04356; unverified]: enc-dec; conv/audio frontend
is a STUB (input_specs provides precomputed frame embeddings)."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("whisper-tiny")
def whisper_tiny() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        activation="gelu",  # plain GELU MLP (not gated)
        norm="layernorm",
        is_encoder_decoder=True,
        n_encoder_layers=4,
        encoder_seq=1500,
        max_position_embeddings=32768,  # learned positions, sized for decode_32k
        tie_embeddings=True,
        source="[arXiv:2212.04356; unverified]",
    )

"""DeepSeek-V2-236B [arXiv:2405.04434; hf]: MLA (kv_lora=512) + 160 routed
experts top-6 + 2 shared experts; first layer dense."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("deepseek-v2-236b")
def deepseek_v2_236b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,  # the dense first layer's FFN
        vocab_size=102400,
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1536,
        first_k_dense=1,
        activation="silu",
        source="[arXiv:2405.04434; hf]",
    )

"""Architecture registry: import all config modules to populate it."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    ShapeSpec,
    cell_applicable,
    get_arch,
    list_archs,
    reduced,
)
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    ds_paper_100m,
    granite_34b,
    h2o_danube_3_4b,
    internvl2_1b,
    mamba2_1p3b,
    mixtral_8x7b,
    nemotron_4_340b,
    qwen2_72b,
    whisper_tiny,
    zamba2_1p2b,
)

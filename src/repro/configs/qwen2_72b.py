"""Qwen2-72B [arXiv:2407.10671; hf]: dense GQA with QKV bias."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("qwen2-72b")
def qwen2_72b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-72b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        activation="silu",
        rope_theta=1_000_000.0,
        source="[arXiv:2407.10671; hf]",
    )

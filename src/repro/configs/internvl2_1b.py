"""InternVL2-1B [arXiv:2404.16821; hf]: LLM backbone only; the InternViT
frontend is a STUB (input_specs provides precomputed patch embeddings,
256 tokens prepended to the text stream)."""
from repro.configs.base import ArchConfig, register_arch


@register_arch("internvl2-1b")
def internvl2_1b() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        qkv_bias=True,
        n_vision_tokens=256,
        activation="silu",
        rope_theta=1_000_000.0,
        source="[arXiv:2404.16821; hf]",
    )

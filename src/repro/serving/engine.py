"""Serving executor: the device-dispatch layer of the three-layer
serving tier.

The serving engine is split along explicit seams (``docs/serving.md``
has the architecture diagram):

- :class:`repro.serving.scheduler.RequestScheduler` — admission queue,
  continuous batching (freed rows refilled every tick, ``drain``
  baseline policy, per-tick prefill token budget), preemption/requeue
  policy, queue-wait/TTFT accounting;
- :class:`repro.serving.cache_manager.KVCacheManager` — dense or paged
  device cache, refcounted free-list allocator, copy-on-write, adaptive
  pool sizing, radix prefix cache, optional cross-host prefix store;
- :class:`ServeEngine` (this module) — owns ONLY the jitted dispatches
  and sampling: it asks the scheduler what to run, asks the cache
  manager to back the positions it will write, dispatches, and feeds
  accepted tokens back to the scheduler.

Hot-path structure (this is the whole point — throughput limited by the
hardware, not by dispatch count):

- **decode**: ONE jitted dispatch per tick for any mix of slot positions.
  ``Model.decode_step`` takes a per-row position vector ``[B]``, so rows
  at different depths advance together; the seed engine's one-dispatch-
  per-distinct-position loop (up to B sequential device calls per token)
  is retained only as ``dispatch_mode="grouped"`` for benchmarking.
- **prefill**: prompts are ingested through ``Model.prefill_chunk`` in
  ``prefill_chunk``-token slices — the KV/SSM cache for a whole chunk is
  written in one dispatch instead of token-at-a-time through the decode
  path.  Architectures without fused-prefill support (enc-dec, VLM, MoE
  capacity routing, rolling sliding-window caches) fall back to decode-
  path ingestion, still at one dispatch per tick.
- **sampling**: greedy/temperature sampling runs on-device inside the
  same dispatch (``repro.serving.sampling``); only ``B`` token ids cross
  the host boundary per tick instead of ``(B, vocab)`` logits.
  ``sample_on_device=False`` restores the host path (numerically
  stable: max-subtracted softmax).
- **stop tokens**: the fused dispatches return a done mask computed on
  device (``repro.serving.sampling.done_mask``); the host finalizes rows
  straight off the mask, and finished rows are parked (pages freed)
  before the next tick's dispatch.

Cache behaviour (paged pool, copy-on-write, shared prefixes, adaptive
sizing) is documented on :class:`KVCacheManager`; scheduling behaviour
(continuous batching, budgets, preemption) on :class:`RequestScheduler`.

Dispatch accounting: ``decode_dispatches`` / ``prefill_dispatches`` /
``dispatches`` (their sum) and ``tokens_emitted`` /
``prompt_tokens_ingested`` feed ``benchmarks/bench_serving.py``'s
dispatches-per-token metric.  ``steps_executed`` keeps its seed meaning
(number of jitted decode calls).  All counters live in one shared
:class:`repro.serving.types.EngineStats` block (``engine.stats``); the
flat attribute aliases below (``engine.tokens_emitted`` and friends)
are kept as the stable public surface.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models import Model
from repro.serving.cache_manager import KVCacheManager
from repro.serving.prefix_store import PrefixStore
from repro.serving.sampling import (
    make_decode_step,
    make_prefill_step,
    make_verify_step,
)
from repro.serving.scheduler import RequestScheduler
from repro.serving.speculate import DraftProposer, NgramProposer
from repro.serving.types import EngineStats, Request, Slot

__all__ = ["Request", "ServeEngine", "Slot"]

# back-compat alias: _Slot predates the layer split
_Slot = Slot


def _jit_cached(model: Model, key: tuple, builder: Callable) -> Callable:
    """Memoize a jitted dispatch on the *model* instance.

    The jit targets close over (model, seed, sample_on_device) only —
    params are call arguments — so every engine built on the same model
    with the same key can share one compiled function.  Elastic serving
    rebuilds engines after every lease takeover/revocation; without this
    each rebuild would retrace and recompile the same program."""
    memo = model.__dict__.setdefault("_jit_memo", {})
    if key not in memo:
        memo[key] = jax.jit(builder())
    return memo[key]


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
        heartbeat: Callable[[], None] = lambda: None,
        prefill_chunk: int = 16,
        dispatch_mode: str = "fused",
        sample_on_device: bool = True,
        cache_mode: str = "dense",
        page_size: int = 16,
        total_pages: Optional[int] = None,
        prefix_cache: bool = True,
        prefix_match: str = "token",
        prefix_store: Optional[PrefixStore] = None,
        refill_policy: str = "continuous",
        prefill_token_budget: Optional[int] = None,
        worker_role: str = "unified",
        speculative: str = "off",
        spec_k: int = 4,
        draft_model: Optional[Model] = None,
        draft_params=None,
    ):
        if dispatch_mode not in ("fused", "grouped"):
            raise ValueError(f"dispatch_mode must be fused|grouped, got {dispatch_mode!r}")
        if cache_mode not in ("dense", "paged"):
            raise ValueError(f"cache_mode must be dense|paged, got {cache_mode!r}")
        if cache_mode == "paged" and not model.supports_paged_cache:
            raise ValueError(
                "cache_mode='paged' unsupported for arch "
                f"{model.cfg.name!r} (no pageable KV cache)"
            )
        if prefix_store is not None and (cache_mode != "paged" or not prefix_cache):
            # same refuse-inert-knob policy as prefill_token_budget below:
            # the store publishes/hydrates through the radix cache over
            # paged pool pages, so without both it can never move a byte
            raise ValueError(
                "prefix_store requires cache_mode='paged' with "
                "prefix_cache=True; it would be silently inert here"
            )
        if worker_role not in ("unified", "prefill", "decode"):
            raise ValueError(
                f"worker_role must be unified|prefill|decode, got {worker_role!r}"
            )
        if worker_role != "unified" and prefix_store is None:
            # the handoff travels THROUGH the prefix store: a prefill
            # worker publishes the prompt's chained pages there and a
            # decode worker demand-hydrates them back.  Without a store
            # the roles would be silently inert (prefill work unreachable)
            raise ValueError(
                "worker_role='prefill'/'decode' requires a prefix_store "
                "(the KV handoff is storage-mediated); it would be "
                "silently inert here"
            )
        if dispatch_mode == "grouped" and model.cfg.family in ("ssm", "hybrid"):
            # per-group re-dispatch re-advances recurrent state every extra
            # call per tick (KV writes are idempotent, recurrences are not):
            # grouped output would be silently wrong, so refuse up front
            raise ValueError(
                "dispatch_mode='grouped' corrupts recurrent SSM/hybrid state; "
                "use the fused engine for family "
                f"{model.cfg.family!r}"
            )
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.heartbeat = heartbeat
        self.prefill_chunk = int(prefill_chunk)
        self.dispatch_mode = dispatch_mode
        self.sample_on_device = sample_on_device
        self.cache_mode = cache_mode

        # the three layers share one counter block and are cross-wired at
        # exactly two points: admission (scheduler -> cache: reset +
        # stitch) and pool-pressure preemption (cache -> scheduler)
        self.stats = EngineStats()
        self.cache_mgr = KVCacheManager(
            model,
            max_batch=max_batch,
            max_len=max_len,
            stats=self.stats,
            cache_mode=cache_mode,
            page_size=page_size,
            total_pages=total_pages,
            prefix_cache=prefix_cache,
            prefix_match=prefix_match,
            prefix_store=prefix_store,
        )
        self.worker_role = worker_role
        self.scheduler = RequestScheduler(
            max_batch,
            self.stats,
            refill_policy=refill_policy,
            prefill_token_budget=prefill_token_budget,
            role=worker_role,
        )
        self.scheduler.cache = self.cache_mgr
        self.cache_mgr.preempt_for = self.scheduler.preempt_for
        # the yield seam: the allocator requeues the youngest (requesting)
        # row only after its allocation loop unwound; skip when the slot
        # was already emptied by a direct preemption
        self.cache_mgr.preempt_row = (
            lambda row: self.scheduler.preempt(row)
            if self.scheduler.slots[row].req is not None else None
        )

        self.rng = np.random.default_rng(rng_seed)
        self._rng_seed = rng_seed
        self._decode = _jit_cached(
            model, ("decode", rng_seed, sample_on_device),
            lambda: make_decode_step(model, rng_seed, sample_on_device),
        )
        self._use_prefill = (
            dispatch_mode == "fused"
            and self.prefill_chunk > 0
            and model.supports_fused_prefill
            and not self.cache_mgr.cache_is_rolling()
        )
        self._prefill = (
            _jit_cached(
                model, ("prefill", rng_seed, sample_on_device),
                lambda: make_prefill_step(model, rng_seed, sample_on_device),
            )
            if self._use_prefill
            else None
        )
        if worker_role == "prefill" and not self._use_prefill:
            # a prefill-role worker never runs a decode tick, so prompts
            # MUST ingest through the chunked-prefill path; without it
            # every admitted request would sit in its slot forever
            raise ValueError(
                "worker_role='prefill' requires the fused chunked-prefill "
                "path (dispatch_mode='fused', prefill_chunk > 0, a "
                "prefill-capable non-rolling arch)"
            )
        if worker_role == "prefill" and speculative != "off":
            raise ValueError(
                "speculative decoding never runs on a prefill-role worker "
                "(it has no decode ticks); it would be silently inert here"
            )
        self.speculative = speculative
        self.spec_k = int(spec_k)
        self.proposer = None
        self._verify = None
        if speculative not in ("off", "ngram", "draft"):
            raise ValueError(
                f"speculative must be off|ngram|draft, got {speculative!r}"
            )
        if speculative == "off" and (draft_model is not None or draft_params is not None):
            raise ValueError(
                "draft_model/draft_params require speculative='draft'; they "
                "would be silently inert here"
            )
        if speculative != "off":
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            if not sample_on_device:
                raise ValueError(
                    "speculative decoding verifies and accepts on device; "
                    "sample_on_device=False would make the verify dispatch "
                    "round-trip (B, k+1, vocab) logits — unsupported"
                )
            if model.cfg.family in ("ssm", "hybrid"):
                # KV rollback is dropping pages past the frontier; a
                # recurrent state advanced by rejected tokens cannot be
                # rewound without checkpointing every position
                raise ValueError(
                    "speculative decoding cannot roll back recurrent "
                    f"(family {model.cfg.family!r}) state; rejected draft "
                    "tokens would corrupt the recurrence"
                )
            if not self._use_prefill:
                raise ValueError(
                    "speculative decoding verifies k+1 positions through the "
                    "fused chunk-extend path (dispatch_mode='fused', "
                    "prefill_chunk > 0, fused-prefill-capable arch, "
                    "non-rolling cache); it cannot run here"
                )
            self._verify = _jit_cached(
                model, ("verify", rng_seed),
                lambda: make_verify_step(model, rng_seed),
            )
            if speculative == "ngram":
                self.proposer = NgramProposer()
            else:
                if draft_model is None or draft_params is None:
                    raise ValueError(
                        "speculative='draft' needs draft_model and draft_params"
                    )
                self.proposer = DraftProposer(
                    draft_model, draft_params,
                    max_batch=max_batch, max_len=max_len, spec_k=self.spec_k,
                    page_size=page_size, stats=self.stats,
                )
        if prefill_token_budget is not None:
            # a finite budget holds rows mid-prefill across decode ticks.
            # For recurrent state that is corruption, not a schedule: the
            # batch-wide decode dispatch advances EVERY row's recurrence,
            # including the held row's, with its garbage token (KV writes
            # are idempotent, recurrences are not).  And without the
            # fused prefill path the knob would be silently inert —
            # refuse both up front rather than mislead.
            if model.cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    "prefill_token_budget is unsupported for recurrent "
                    f"(family {model.cfg.family!r}) models: a mid-prefill "
                    "row's recurrence would be advanced by the decode "
                    "dispatch it sits out"
                )
            if not self._use_prefill:
                raise ValueError(
                    "prefill_token_budget requires the fused prefill path "
                    "(dispatch_mode='fused', prefill_chunk > 0, fused-"
                    "prefill-capable arch); it would be silently inert here"
                )

    # ---------------------------------------------- layer-delegation surface
    # The flat attribute API predates the layer split and is the stable
    # public surface (tests, benchmarks, payloads); everything below is
    # a view onto the layers, writable where the benchmark re-baselines.
    @property
    def cache(self):
        return self.cache_mgr.cache

    @cache.setter
    def cache(self, value):
        self.cache_mgr.cache = value

    @property
    def prefix(self):
        return self.cache_mgr.prefix

    @property
    def pending(self) -> List[Request]:
        return self.scheduler.pending

    @property
    def finished(self) -> List[Request]:
        return self.scheduler.finished

    @property
    def slots(self) -> List[Slot]:
        return self.scheduler.slots

    @property
    def peak_cache_bytes(self) -> int:
        return self.cache_mgr.peak_cache_bytes

    def snapshot(self) -> Dict:
        """Full counter + timing snapshot (what the ``distributed-serve``
        payload publishes next to the completions)."""
        snap = self.stats.snapshot()
        snap["peak_cache_bytes"] = self.peak_cache_bytes
        snap["timing"] = self.scheduler.timing()
        if self.cache_mode == "paged":
            snap["total_pages"] = self.cache_mgr.n_pages
            snap["page_size"] = self.cache_mgr.page_size
        return snap

    # ------------------------------------------------------------- intake
    def submit(self, reqs: List[Request]) -> None:
        self.scheduler.submit(reqs)
        # adaptive pool sizing sees the queue depth at submit (the caller
        # no longer guesses total_pages)
        self.cache_mgr.on_submit(self.scheduler.pending)

    # ------------------------------------- work-preserving recovery seam
    def checkpoint_slot(self, row: int) -> Optional[Dict]:
        """Capture a resumable generation checkpoint for an active slot.

        Called by a draining lease BEFORE it preempts the row, while the
        slot's KV pages are still resident.  Publishes the pages covering
        ``prompt + output[:-1]`` (everything the cache actually holds —
        the last emitted token's KV has not been written yet) through the
        prefix store, including the sub-page tail under an extended
        content key, and returns a plain-dict record the caller persists
        durably.  Returns ``None`` when there is nothing worth saving
        (empty row, prompt still ingesting, or no tokens emitted yet —
        full replay costs the same as a resume there)."""
        slot = self.scheduler.slots[row]
        req = slot.req
        if (req is None or slot.remaining_prompt
                or len(req.output) <= req.resume_base):
            return None
        # a request that is itself a resume carries resume_base pre-seeded
        # output tokens duplicated in its extended prompt — the record
        # always stores the ORIGINAL prompt and the FULL output, so
        # chained resumes never double-extend
        base = len(req.prompt) - req.resume_base
        resident = list(req.prompt[:base]) + req.output[:-1]
        self.cache_mgr.publish_generation(row, resident)
        self.stats.checkpoints_published += 1
        return {
            "uid": req.uid,
            "prompt": list(req.prompt[:base]),
            "output": list(req.output),
            "sample_stream": int(req.sample_stream),
            "max_new_tokens": int(req.max_new_tokens),
            "temperature": float(req.temperature),
            "stop_token": req.stop_token,
        }

    def submit_resume(self, ckpt: Dict) -> Request:
        """Admit a checkpointed generation for byte-identical continuation.

        The resumed request re-enters through the NORMAL admission path
        with an *extended prompt* of ``prompt + output[:-1]`` and its
        output pre-seeded to ``output[:-1]``: the prefix stitch gets a
        guaranteed full-chunk hit over tokens the dying worker published
        (sub-page tail included), and the prefill-completion sample at
        the frontier re-derives ``output[-1]`` from the same stream key
        ``(stream, len(output)-1)`` the original emission used — so the
        final output is token-for-token identical to an uninterrupted
        run, and only the frontier token is ever re-decoded.  A partial
        (or zero) store hit degrades gracefully: the un-hit extended-
        prompt tokens are chunk-prefilled, writing the same KV bytes."""
        output = [int(t) for t in ckpt["output"]]
        req = Request(
            uid=ckpt["uid"],
            prompt=[int(t) for t in ckpt["prompt"]] + output[:-1],
            max_new_tokens=int(ckpt["max_new_tokens"]),
            temperature=float(ckpt["temperature"]),
            stop_token=ckpt.get("stop_token"),
        )
        req.output = output[:-1]
        req.resume_base = len(output) - 1
        req.sample_stream = int(ckpt["sample_stream"])
        self.scheduler.submit_resume(req)
        self.cache_mgr.on_submit(self.scheduler.pending)
        self.stats.checkpoint_resumes += 1
        self.stats.tokens_recovered += len(output) - 1
        return req

    # -------------------------------------- disaggregated prefill/decode
    def submit_handoff(self, rec: Dict) -> Request:
        """Admit a prefill worker's sealed handoff record on a decode
        worker.

        A handoff record is the checkpoint format with ``output == []``:
        the prompt was ingested and its full KV chain (full pages plus
        the sub-page tail under an extended content key) published by a
        prefill-role worker, but nothing has been decoded yet — so it
        cannot go through :meth:`submit_resume` (there is no emitted
        frontier token to re-derive).  Admission flags the request as a
        handoff, which routes the prefix stitch through the
        demand-driven hydration path: the cache manager fetches exactly
        the chained pages (pinned against eviction while in flight)
        instead of stopping at free-pool pressure, and counts a fallback
        if the store cannot cover the prompt (the slot then replays
        through the normal chunk-prefill ladder, byte-identically).  The
        first sample draws from the record's preserved stream at step 0,
        so output matches a monolithic worker token-for-token."""
        req = Request(
            uid=rec["uid"],
            prompt=[int(t) for t in rec["prompt"]],
            max_new_tokens=int(rec["max_new_tokens"]),
            temperature=float(rec["temperature"]),
            stop_token=rec.get("stop_token"),
        )
        req.sample_stream = int(rec["sample_stream"])
        req.handoff = True
        self.scheduler.submit_handoff(req)
        self.cache_mgr.on_submit(self.scheduler.pending)
        self.stats.handoffs_admitted += 1
        return req

    # ------------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine tick.

        The scheduler admits queued requests into freed rows (continuous
        batching), then: fused mode ingests pending prompt chunks
        (>= chunk-size tokens per prefill dispatch, bounded by the
        scheduler's per-tick prefill token budget) and advances every
        decode-ready slot one token in a SINGLE decode dispatch
        regardless of position mix.  Grouped mode reproduces the seed's
        per-position-group dispatching (with its cross-row KV corruption
        fixed) for comparison.  NOTE: grouped dispatching is inherently
        wrong for recurrent (SSM / hybrid) state — every extra per-tick
        dispatch re-advances all rows' recurrences (KV writes are
        idempotent, recurrences are not).  That unfixable property is
        part of why the fused path exists; use grouped mode only on
        attention-family models.
        """
        self.scheduler.begin_tick()
        if not self.scheduler.has_active():
            return 0
        emitted = 0
        if self._use_prefill:
            emitted += self._ingest_prompts()
        if self.worker_role == "prefill":
            # prefill-role tick: ingest only.  Each prompt finishes at
            # ingest completion (published + handed off, zero tokens
            # sampled) so a decode dispatch here could only be a no-op
            if os.environ.get("DS_DEBUG_INVARIANTS") == "1":
                self.cache_mgr.check_invariants()
            return emitted
        if self.dispatch_mode == "grouped":
            emitted += self._decode_tick_grouped()
        elif self.speculative != "off":
            emitted += self._decode_tick_spec()
        else:
            emitted += self._decode_tick_fused()
        if os.environ.get("DS_DEBUG_INVARIANTS") == "1":
            self.cache_mgr.check_invariants()
        return emitted

    # -- prompt ingestion (fused chunked prefill) ---------------------------
    def _ingest_prompts(self) -> int:
        emitted = 0
        B, C = self.max_batch, self.prefill_chunk
        slots = self.scheduler.slots
        budget = self.scheduler.prefill_token_budget
        left = budget  # None = unbounded: drain every prompt this tick
        while True:
            # plan this dispatch under the remaining tick budget: per-row
            # token counts fixed BEFORE the reservation pass below
            plan: Dict[int, int] = {}
            prefilling = [
                i for i, s in enumerate(slots)
                if s.req is not None and s.remaining_prompt
            ]
            if not prefilling or (left is not None and left <= 0):
                return emitted
            if left is None:
                for i in prefilling:
                    plan[i] = min(C, len(slots[i].remaining_prompt))
            else:
                # fair-share the remaining budget across prefilling rows
                # (ceil of an even split each), rotating the head row by
                # tick so a budget smaller than the row count cannot
                # pin-starve the same rows forever — lowest-index-first
                # would hold a short prompt hostage behind a long one
                start = self.scheduler.tick % len(prefilling)
                order = prefilling[start:] + prefilling[:start]
                for idx, i in enumerate(order):
                    share = -(-left // (len(order) - idx))
                    n = min(C, len(slots[i].remaining_prompt), share)
                    if n > 0:
                        plan[i] = n
                        left -= n
            if not plan:
                return emitted
            if self.cache_mode == "paged":
                # reservation pass BEFORE building dispatch inputs: CoW /
                # eviction / preemption all mutate slot state, and a later
                # row's allocation may preempt (or yield) an earlier one —
                # the rows list below is computed only after every
                # survivor holds pages.  A dropped row's slot.req is
                # None: yielded and preempted rows alike are requeued at
                # the clean seam and rerun byte-identically
                for i, n in plan.items():
                    s = slots[i]
                    if s.req is not None and s.remaining_prompt:
                        self.cache_mgr.ensure_pages(i, s.pos + n,
                                                    write_start=s.pos)
            rows = [
                i for i in plan
                if slots[i].req is not None and slots[i].remaining_prompt
            ]
            if left is not None:
                # refund tokens planned for rows the reservation pass
                # dropped (preempted/yielded): the tick budget promises
                # tokens INGESTED, not tokens planned
                left += sum(plan[i] for i in plan if i not in rows)
            if not rows:
                return emitted
            tokens = np.zeros((B, C), np.int32)
            offsets = np.zeros((B,), np.int32)
            lengths = np.zeros((B,), np.int32)
            temps = np.zeros((B,), np.float32)
            streams = np.zeros((B,), np.int32)
            steps = np.zeros((B,), np.int32)
            stops = np.full((B,), -1, np.int32)
            max_news = np.full((B,), 1 << 30, np.int32)
            for i in rows:
                slot = slots[i]
                n = min(plan[i], len(slot.remaining_prompt))
                tokens[i, :n] = slot.remaining_prompt[:n]
                offsets[i] = slot.pos
                lengths[i] = n
                temps[i] = slot.req.temperature
                streams[i] = slot.req.sample_stream
                # a checkpoint resume admits with pre-seeded output, so
                # the prefill-completion sample/done mask must start at
                # the emission count, not 0 (no-op for fresh requests)
                steps[i] = len(slot.req.output)
                if slot.req.stop_token is not None:
                    stops[i] = slot.req.stop_token
                max_news[i] = slot.req.max_new_tokens
            self.cache_mgr.push_table()
            if self.sample_on_device:
                nxt, done, self.cache_mgr.cache = self._prefill(
                    self.params, self.cache_mgr.cache, tokens, offsets, lengths,
                    temps, streams, steps, stops, max_news,
                )
                nxt, done, lg = np.asarray(nxt), np.asarray(done), None
            else:
                logits, self.cache_mgr.cache = self._prefill(
                    self.params, self.cache_mgr.cache, tokens, offsets, lengths
                )
                nxt, done, lg = None, None, np.asarray(logits)
            self.stats.prefill_dispatches += 1
            self.stats.dispatches += 1
            self.heartbeat()
            for i in rows:
                slot = slots[i]
                n = min(plan[i], len(slot.remaining_prompt))
                del slot.remaining_prompt[:n]
                slot.pos += n
                self.stats.prompt_tokens_ingested += n
                if not slot.remaining_prompt:
                    # prompt fully resident: publish its full pages to the
                    # prefix cache BEFORE accept (which may finish the row
                    # and drop its references)
                    self.cache_mgr.prefix_insert(i, slot.req.prompt)
                    if self.worker_role == "prefill":
                        # disaggregated prefill: the full prompt's KV —
                        # full pages plus the sub-page tail under its
                        # extended content key — is published while the
                        # row still holds its pages, then the request
                        # finishes WITHOUT sampling.  Sampling streams
                        # are (seed, stream, step)-keyed, so skipping the
                        # draw consumes no state: the decode worker's
                        # frontier sample at (stream, 0) is the same
                        # token a monolith would have emitted here
                        self.cache_mgr.publish_generation(i, slot.req.prompt)
                        self.cache_mgr.ensure_chain_published(i, slot.req.prompt)
                        self.scheduler.finish(i)
                        continue
                    # the chunk's last-token logits seed generation
                    tok = (
                        int(nxt[i])
                        if nxt is not None
                        else self._host_sample(
                            lg[i], slot.req.temperature,
                            stream=slot.req.sample_stream,
                            step=len(slot.req.output),
                        )
                    )
                    self._accept_token(i, tok, bool(done[i]) if done is not None else None)
                    emitted += 1

    # -- decode -------------------------------------------------------------
    def _build_decode_inputs(self):
        B = self.max_batch
        slots = self.scheduler.slots
        if self.cache_mode == "paged":
            # reservation pass first (see _ingest_prompts): allocation may
            # CoW a shared page or preempt/yield a slot, so inputs are
            # built only from the rows that still hold their pages
            # afterwards (a False return means the row was requeued —
            # slot.req is None and its released table row is all OOB
            # sentinel, so the batch-wide scatter at its stale position
            # is dropped on device).  Rows held mid-prefill by the tick
            # budget are covered too: the batch-wide dispatch still
            # writes (garbage) KV at their pos through their LIVE page
            # table, so a shared prefix page in that position must be
            # privatized first — the row itself overwrites the position
            # when its prefill resumes
            for i, s in enumerate(slots):
                if s.req is not None:
                    self.cache_mgr.ensure_pages(i, s.pos + 1, write_start=s.pos)
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        streams = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        stops = np.full((B,), -1, np.int32)
        max_news = np.full((B,), 1 << 30, np.int32)
        active = []
        for i, slot in enumerate(slots):
            # parked rows keep their stale pos: dense mode confines the
            # write to their own (dead) row, which is zeroed at refill;
            # paged mode drops it on the OOB page-table sentinel
            pos[i] = slot.pos
            if slot.req is None or self._mid_prefill(slot):
                continue
            active.append(i)
            if slot.remaining_prompt:  # decode-path ingestion fallback
                tokens[i, 0] = slot.remaining_prompt[0]
            elif slot.req.output:
                tokens[i, 0] = slot.req.output[-1]
            else:
                tokens[i, 0] = slot.req.prompt[-1]
            temps[i] = slot.req.temperature
            streams[i] = slot.req.sample_stream
            steps[i] = len(slot.req.output)
            if slot.req.stop_token is not None:
                stops[i] = slot.req.stop_token
            max_news[i] = slot.req.max_new_tokens
        return active, tokens, pos, temps, streams, steps, stops, max_news

    def _mid_prefill(self, slot: Slot) -> bool:
        """Under a finite prefill budget a fused-prefill row can reach the
        decode tick with prompt tokens still pending; it sits the decode
        out and resumes chunked prefill next tick.  (Without fused
        prefill, remaining_prompt rows ARE the decode-path ingestion.)"""
        return bool(self._use_prefill and slot.remaining_prompt)

    def _decode_dispatch(
        self, tokens, pos, temps, streams, steps, stops, max_news
    ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray], Optional[np.ndarray]]:
        self.cache_mgr.push_table()
        if self.sample_on_device:
            nxt, done, self.cache_mgr.cache = self._decode(
                self.params, self.cache_mgr.cache, tokens, pos, temps, streams,
                steps, stops, max_news,
            )
            out = (np.asarray(nxt), np.asarray(done), None)
        else:
            logits, self.cache_mgr.cache = self._decode(
                self.params, self.cache_mgr.cache, tokens, pos
            )
            out = (None, None, np.asarray(logits))
        self.stats.decode_dispatches += 1
        self.stats.steps_executed += 1
        self.stats.dispatches += 1
        self.heartbeat()
        return out

    def _advance_rows(self, rows, nxt, done, lg) -> int:
        emitted = 0
        slots = self.scheduler.slots
        for i in rows:
            slot = slots[i]
            slot.pos += 1
            if slot.remaining_prompt:
                slot.remaining_prompt.pop(0)
                self.stats.prompt_tokens_ingested += 1
                if slot.remaining_prompt:
                    continue  # still ingesting the prompt
                # decode-path ingestion just wrote the last prompt token:
                # publish the prompt's full pages (MoE/MLA archs reach the
                # prefix cache through this path)
                self.cache_mgr.prefix_insert(i, slot.req.prompt)
            tok = (
                int(nxt[i])
                if nxt is not None
                else self._host_sample(
                    lg[i], slot.req.temperature,
                    stream=slot.req.sample_stream, step=len(slot.req.output),
                )
            )
            self._accept_token(i, tok, bool(done[i]) if done is not None else None)
            emitted += 1
        return emitted

    def _decode_tick_fused(self) -> int:
        active, *inputs = self._build_decode_inputs()
        if not active:
            return 0
        nxt, done, lg = self._decode_dispatch(*inputs)
        return self._advance_rows(active, nxt, done, lg)

    def _decode_tick_spec(self) -> int:
        """Speculative decode tick: propose up to ``spec_k`` draft tokens
        per decode-ready slot, verify all drafts plus the bonus position
        in ONE fused chunk-extend dispatch through the page table, accept
        the longest consistent run, and roll rejected positions back.

        Byte parity with :meth:`_decode_tick_fused` is structural, not
        statistical: the verify step samples position ``t`` from the
        stream key ``(stream, len(output) + t)`` — the exact key the
        non-speculative engine would use for that token — and emission
        truncates at the first per-position done, so a request's output
        is identical token-for-token no matter how many drafts were
        proposed or accepted.  Speculation only changes how many tokens
        land per dispatch (``accepted_per_dispatch``).  Rows whose
        proposer returns nothing degrade to plain one-token decode
        inside the same dispatch."""
        B, T = self.max_batch, self.spec_k + 1
        slots = self.scheduler.slots
        ready = [
            i for i, s in enumerate(slots)
            if s.req is not None and not s.remaining_prompt
        ]
        if not ready:
            return 0
        hists = {i: slots[i].req.prompt + slots[i].req.output for i in ready}
        drafts = self.proposer.propose(ready, hists, self.spec_k)
        plan: Dict[int, List[int]] = {}
        for i in ready:
            # cap drafts so the slot can never advance past the max_len-1
            # truncation point the non-speculative engine finishes at
            room = self.max_len - 2 - slots[i].pos
            plan[i] = list(drafts.get(i, []))[:max(0, room)]
        if self.cache_mode == "paged":
            # reservation pass first (see _build_decode_inputs): the
            # verify dispatch writes pos .. pos+len(drafts) per row, and
            # a later row's allocation may preempt an earlier one.  Only
            # the base position (what plain decode would write) carries
            # full recovery semantics; draft positions are best-effort
            # and shrink the plan under pool pressure instead of
            # preempting or raising — speculation must never OOM a
            # workload the non-speculative engine serves
            for i in ready:
                s = slots[i]
                if s.req is not None:
                    got = self.cache_mgr.reserve_speculative(
                        i, s.pos + 1, s.pos + 1 + len(plan[i]),
                        write_start=s.pos,
                    )
                    if got is not None:
                        plan[i] = plan[i][:max(0, got - (s.pos + 1))]
        live = [i for i in ready if slots[i].req is not None]
        if not live:
            return 0
        tokens = np.zeros((B, T), np.int32)
        offsets = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        streams = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        stops = np.full((B,), -1, np.int32)
        max_news = np.full((B,), 1 << 30, np.int32)
        for i in live:
            s = slots[i]
            d = plan[i]
            tokens[i, 0] = s.req.output[-1] if s.req.output else s.req.prompt[-1]
            tokens[i, 1:1 + len(d)] = d
            offsets[i] = s.pos
            lengths[i] = 1 + len(d)
            temps[i] = s.req.temperature
            streams[i] = s.req.sample_stream
            steps[i] = len(s.req.output)
            if s.req.stop_token is not None:
                stops[i] = s.req.stop_token
            max_news[i] = s.req.max_new_tokens
            self.stats.draft_tokens_proposed += len(d)
        self.cache_mgr.push_table()
        tgt, n_emit, done, self.cache_mgr.cache = self._verify(
            self.params, self.cache_mgr.cache, tokens, offsets, lengths,
            temps, streams, steps, stops, max_news,
        )
        tgt, n_emit, done = np.asarray(tgt), np.asarray(n_emit), np.asarray(done)
        self.stats.decode_dispatches += 1
        self.stats.steps_executed += 1
        self.stats.dispatches += 1
        self.stats.spec_dispatches += 1
        self.heartbeat()
        emitted = 0
        for i in live:
            s = slots[i]
            n = int(n_emit[i])
            new_pos = s.pos + n
            if n < int(lengths[i]):
                # rejected positions: rewind the write frontier; trailing
                # whole pages go back to the pool (CoW rollback), stale KV
                # inside the kept page sits past the frontier (masked)
                self.cache_mgr.rewind_slot(i, new_pos)
            s.pos = new_pos
            self.stats.draft_tokens_accepted += n - 1
            self.stats.spec_tokens_emitted += n
            fin = bool(done[i]) or new_pos >= self.max_len - 1
            for t in range(n):
                s.req.output.append(int(tgt[i, t]))
                self.stats.tokens_emitted += 1
                self.scheduler.on_token(i)
            emitted += n
            if fin:
                self.scheduler.finish(i)
                self.proposer.release(i)
        return emitted

    def _decode_tick_grouped(self) -> int:
        """Seed-style dispatching: one jitted call per distinct slot
        position.  Every call carries the full per-row position vector, so
        cache writes are correct and idempotent across the tick's calls
        (the seed's scalar-pos variant overwrote OTHER rows' histories);
        only the group's rows consume their call's outputs."""
        active, *inputs = self._build_decode_inputs()
        if not active:
            return 0
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(self.scheduler.slots[i].pos, []).append(i)
        emitted = 0
        for _, rows in sorted(groups.items()):
            nxt, done, lg = self._decode_dispatch(*inputs)
            emitted += self._advance_rows(rows, nxt, done, lg)
        return emitted

    # -- bookkeeping ---------------------------------------------------------
    def _accept_token(self, row: int, tok: int, done: Optional[bool] = None) -> None:
        slot = self.scheduler.slots[row]
        slot.req.output.append(tok)
        self.stats.tokens_emitted += 1
        self.scheduler.on_token(row)
        if done is None:
            # host fallback (sample_on_device=False): re-derive the mask
            done = len(slot.req.output) >= slot.req.max_new_tokens or (
                slot.req.stop_token is not None and tok == slot.req.stop_token
            )
        if done or slot.pos >= self.max_len - 1:
            self.scheduler.finish(row)

    def _host_sample(
        self,
        lg_row: np.ndarray,
        temperature: float,
        stream: Optional[int] = None,
        step: Optional[int] = None,
    ) -> int:
        """Host fallback sampler (``sample_on_device=False``): greedy or
        max-subtracted softmax — ``np.exp(lg / T)`` on raw logits overflows
        for large-magnitude logits.

        When the caller passes the request's ``(stream, step)``, the draw
        comes from an rng keyed on ``(seed, stream, step)`` — like the
        on-device path, independent of scheduling, slot assignment, and
        preemption replays.  Without them (direct/debug calls) it falls
        back to the engine-level rng."""
        lg = np.asarray(lg_row, np.float64)
        if temperature <= 0:
            return int(np.argmax(lg))
        z = (lg - lg.max()) / temperature
        p = np.exp(z)
        p /= p.sum()
        rng = (
            np.random.default_rng((self._rng_seed, stream, step))
            if stream is not None
            else self.rng
        )
        return int(rng.choice(len(p), p=p))

    def run_to_completion(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (
            self.scheduler.pending or self.scheduler.has_active()
        ) and steps < max_steps:
            self.step()
            steps += 1
        # drain seam: background prefix-store publishes must be durable
        # before callers compare counters or hand pages to another engine
        self.cache_mgr.flush_store()
        return self.scheduler.finished


def _stats_alias(name: str) -> property:
    """Read/write view of one EngineStats counter on the engine."""
    return property(
        lambda self: getattr(self.stats, name),
        lambda self, value: setattr(self.stats, name, value),
    )


def _cache_alias(name: str) -> property:
    return property(lambda self: getattr(self.cache_mgr, name))


for _name in (
    "steps_executed", "decode_dispatches", "prefill_dispatches", "dispatches",
    "tokens_emitted", "prompt_tokens_ingested",
    "pages_in_use", "peak_pages", "page_allocs", "page_bytes",
    "dense_cache_bytes",
    "prefix_hit_tokens", "prompt_tokens_skipped", "pages_shared_peak",
    "prefix_hit_tokens_partial", "cow_partial_stitches",
    "cow_copies", "prefix_evictions", "preemptions", "tokens_discarded",
    "prefix_store_pages_published", "prefix_store_pages_hydrated",
    "prefix_store_tokens_hydrated",
    "spec_dispatches", "draft_dispatches",
    "draft_tokens_proposed", "draft_tokens_accepted", "spec_tokens_emitted",
    "revocation_notices", "drain_requeued_requests", "requests_resumed",
    "lease_slices", "lease_resumes",
    "checkpoints_published", "checkpoint_resumes", "tokens_recovered",
    "checkpoint_fallbacks", "decode_tokens_discarded",
    "publish_retries", "prefix_store_hash_mismatches",
    "hydration_fetch_ops", "prefix_store_bytes_fetched", "publish_dedup_hits",
    "handoffs_published", "handoffs_admitted", "handoff_fallbacks",
    "handoff_seal_rejects",
):
    setattr(ServeEngine, _name, _stats_alias(_name))
for _name in (
    "page_size", "n_pages", "pages_per_slot",
    "_free_pages", "_page_refs", "_slot_pages", "_table",
):
    setattr(ServeEngine, _name, _cache_alias(_name))
del _name

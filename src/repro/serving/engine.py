"""Serving engine: continuous batching, fused chunked prefill, and
single-dispatch vectorized decode.

Slot-based continuous batching (vLLM-style at miniature scale): a fixed
pool of ``max_batch`` slots, each holding one request's cache position;
finished slots are refilled from the pending queue every step, so the
batch stays full under ragged request lengths.

Hot-path structure (this is the whole point — throughput limited by the
hardware, not by dispatch count):

- **decode**: ONE jitted dispatch per tick for any mix of slot positions.
  ``Model.decode_step`` takes a per-row position vector ``[B]``, so rows
  at different depths advance together; the seed engine's one-dispatch-
  per-distinct-position loop (up to B sequential device calls per token)
  is retained only as ``dispatch_mode="grouped"`` for benchmarking.
- **prefill**: prompts are ingested through ``Model.prefill_chunk`` in
  ``prefill_chunk``-token slices — the KV/SSM cache for a whole chunk is
  written in one dispatch instead of token-at-a-time through the decode
  path.  Architectures without fused-prefill support (enc-dec, VLM, MoE
  capacity routing, rolling sliding-window caches) fall back to decode-
  path ingestion, still at one dispatch per tick.
- **sampling**: greedy/temperature sampling runs on-device inside the
  same dispatch (``repro.serving.sampling``); only ``B`` token ids cross
  the host boundary per tick instead of ``(B, vocab)`` logits.
  ``sample_on_device=False`` restores the host path (now numerically
  stable: max-subtracted softmax).

- **cache**: ``cache_mode="paged"`` replaces the dense per-slot
  ``max_len`` reservation with a shared pool of fixed-size KV pages and
  a per-slot page table.  The engine owns the allocator: pages are
  claimed *as positions are written* (allocate-on-write, ahead of each
  dispatch) and returned to the free list the moment a request finishes,
  so cache memory tracks tokens actually resident instead of the
  worst-case ``max_batch * max_len`` reservation.  Freed slots' table
  entries hold an out-of-bounds sentinel, so a parked row's (stale)
  write is dropped on device rather than corrupting a page that has been
  re-issued to another slot.  ``peak_pages`` / ``peak_cache_bytes``
  record the high-water mark the benchmark compares against the dense
  reservation.
- **stop tokens**: requests may carry a ``stop_token``; the fused
  dispatches return a done mask computed on device
  (``repro.serving.sampling.done_mask``), so the host finalizes rows
  straight off the mask instead of re-deriving the stop condition, and
  finished rows are parked (pages freed) before the next tick's
  dispatch.
- **shared prefixes**: with ``prefix_cache=True`` (paged mode default)
  a host-side radix cache (``repro.serving.prefix_cache``) indexes
  completed prompts' full KV pages by their token chunks.  Admission
  matches each new prompt against the cache and *stitches* the hit into
  the slot's page table — the matched pages are referenced (refcount
  bumped), not recomputed, and prefill resumes from the first divergent
  chunk.  The allocator is refcount-aware: a page is freed only when its
  last reference (slots + cache) drops, a slot about to write a page
  someone else still references gets a private copy first
  (copy-on-write), and when the pool runs dry the engine evicts LRU
  unreferenced cached prefixes, then preempts the youngest active slot
  (its request is requeued and, thanks to the deterministic sampling
  streams, regenerates byte-identical output) before giving up.

Dispatch accounting: ``decode_dispatches`` / ``prefill_dispatches`` /
``dispatches`` (their sum) and ``tokens_emitted`` /
``prompt_tokens_ingested`` feed ``benchmarks/bench_serving.py``'s
dispatches-per-token metric.  ``steps_executed`` keeps its seed meaning
(number of jitted decode calls).
"""

from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models import Model
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import make_decode_step, make_prefill_step

_LOG = logging.getLogger(__name__)


@dataclass
class Request:
    uid: str
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    # emitting this token id finishes the request (it is kept in the
    # output); None disables.  Checked on device via the fused done mask.
    stop_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    done: bool = False
    # per-request sampling stream id (assigned at submit; scheduling- and
    # slot-independent so fused and grouped modes draw identical samples)
    sample_stream: int = field(default=0, compare=False, repr=False)


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next cache position to write
    remaining_prompt: List[int] = field(default_factory=list)
    # admission order (monotonic): preemption picks the youngest = max seq
    seq: int = -1
    # prefix-cache stitch accounting for THIS admission (rolled back if
    # the slot is preempted, so counters never double-count a rerun)
    hit_tokens: int = 0
    skipped_tokens: int = 0


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
        heartbeat: Callable[[], None] = lambda: None,
        prefill_chunk: int = 16,
        dispatch_mode: str = "fused",
        sample_on_device: bool = True,
        cache_mode: str = "dense",
        page_size: int = 16,
        total_pages: Optional[int] = None,
        prefix_cache: bool = True,
    ):
        if dispatch_mode not in ("fused", "grouped"):
            raise ValueError(f"dispatch_mode must be fused|grouped, got {dispatch_mode!r}")
        if cache_mode not in ("dense", "paged"):
            raise ValueError(f"cache_mode must be dense|paged, got {cache_mode!r}")
        if cache_mode == "paged" and not model.supports_paged_cache:
            raise ValueError(
                "cache_mode='paged' unsupported for arch "
                f"{model.cfg.name!r} (no pageable KV cache)"
            )
        if dispatch_mode == "grouped" and model.cfg.family in ("ssm", "hybrid"):
            # per-group re-dispatch re-advances recurrent state every extra
            # call per tick (KV writes are idempotent, recurrences are not):
            # grouped output would be silently wrong, so refuse up front
            raise ValueError(
                "dispatch_mode='grouped' corrupts recurrent SSM/hybrid state; "
                "use the fused engine for family "
                f"{model.cfg.family!r}"
            )
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.heartbeat = heartbeat
        self.prefill_chunk = int(prefill_chunk)
        self.dispatch_mode = dispatch_mode
        self.sample_on_device = sample_on_device
        self.cache_mode = cache_mode
        self.page_size = int(page_size)
        if cache_mode == "paged":
            self.pages_per_slot = -(-max_len // self.page_size)
            self.prefix = PrefixCache(self.page_size) if prefix_cache else None
            self.pages_in_use = 0
            self.peak_pages = 0
            self.page_allocs = 0  # lifetime allocations (> n_pages => reuse)
            # prefix-sharing / recovery accounting
            self.prefix_hit_tokens = 0  # prompt tokens found in the cache
            self.prompt_tokens_skipped = 0  # of those, never dispatched
            self.pages_shared_peak = 0  # max pages with refcount > 1
            self.cow_copies = 0
            self.prefix_evictions = 0
            self.preemptions = 0
            self.tokens_discarded = 0  # preempted work (re-earned on rerun)
            self._shared_pages = 0  # pages with refcount > 1, kept O(1)
            self.page_bytes = 0
            self.dense_cache_bytes = 0
            self._adaptive = not total_pages
            if total_pages:
                self._init_paged_pool(int(total_pages))
            else:
                # sized adaptively from queue depth at first submit (and
                # grown, up to the dense reservation, on later submits)
                self.n_pages: Optional[int] = None
                self.cache = None
        else:
            self.prefix = None
            self.cache = model.init_cache(max_batch, max_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.pending: List[Request] = []
        self.finished: List[Request] = []
        self.rng = np.random.default_rng(rng_seed)
        self._rng_seed = rng_seed
        self._n_submitted = 0
        self._admit_seq = 0
        self._decode = jax.jit(make_decode_step(model, rng_seed, sample_on_device))
        self._use_prefill = (
            dispatch_mode == "fused"
            and self.prefill_chunk > 0
            and model.supports_fused_prefill
            and not self._cache_is_rolling()
        )
        self._prefill = (
            jax.jit(make_prefill_step(model, rng_seed, sample_on_device))
            if self._use_prefill
            else None
        )
        # dispatch accounting
        self.steps_executed = 0  # jitted decode calls (seed-compatible name)
        self.decode_dispatches = 0
        self.prefill_dispatches = 0
        self.dispatches = 0
        self.tokens_emitted = 0
        self.prompt_tokens_ingested = 0

    def _cache_is_rolling(self) -> bool:
        """Sliding-window KV caches wrap writes mod t; right-padded prefill
        chunks could then alias still-visible slots — decode-path ingest.
        (Paged caches are never rolling; an adaptively-sized pool may not
        exist yet, which is fine for this check.)"""
        k = self.cache.get("k") if isinstance(self.cache, dict) else None
        return k is not None and k.shape[2] < self.max_len

    def _init_paged_pool(self, total_pages: Optional[int]) -> None:
        """Create the device page pool and the host-side allocator state.

        ``total_pages=None`` sizes the pool adaptively from the queue at
        first submit: enough pages for the ``min(max_batch, queue depth)``
        largest queued requests (prompt + new-token budget, in whole
        pages) plus one request's worth of headroom for retained cached
        prefixes, clamped between one request and the dense reservation.
        """
        dense_pages = self.max_batch * self.pages_per_slot
        if total_pages is None:
            total_pages = self._adaptive_pages()
            _LOG.info(
                "paged pool sized adaptively: %d pages of %d tokens "
                "(queue depth %d, max_batch %d, dense reservation %d pages)",
                total_pages, self.page_size, len(self.pending), self.max_batch,
                dense_pages,
            )
        self.n_pages = int(total_pages)
        self.cache = self.model.init_cache(
            self.max_batch, self.max_len,
            paged=True, page_size=self.page_size, n_pages=self.n_pages,
        )
        # host-side allocator: free list + per-page refcounts + per-slot
        # page lists + the numpy shadow of the device page table (OOB
        # sentinel = unbacked)
        self._free_pages = list(range(self.n_pages))
        self._page_refs = [0] * self.n_pages
        self._slot_pages: List[List[int]] = [[] for _ in range(self.max_batch)]
        self._table = np.full(
            (self.max_batch, self.pages_per_slot), self.n_pages, np.int32
        )
        self._table_dirty = True
        # bytes of ONE page across every layer and pool leaf (k+v, or
        # the MLA latent pool) — peak_cache_bytes = peak_pages * this
        self.page_bytes = sum(
            leaf.size * leaf.dtype.itemsize // self.n_pages
            for name, leaf in self.cache.items()
            if name.endswith("_pages")
        )
        self.dense_cache_bytes = dense_pages * self.page_bytes

    def _adaptive_pages(self) -> int:
        """Pool size for the current queue: pages for the
        ``min(max_batch, queue depth)`` largest queued requests (prompt +
        new-token budget, whole pages) + one request of headroom for
        retained prefixes + pages already resident, clamped between one
        request and the dense reservation."""
        ps = self.page_size
        dense_pages = self.max_batch * self.pages_per_slot
        demands = [
            min(self.pages_per_slot, -(-(len(r.prompt) + r.max_new_tokens) // ps))
            for r in self.pending
        ] or [self.pages_per_slot]
        per_req = max(demands)
        conc = max(1, min(self.max_batch, len(self.pending)))
        want = sum(sorted(demands)[-conc:]) + per_req + self.pages_in_use
        return max(per_req, min(dense_pages, want))

    def _grow_pool(self, new_n: int) -> None:
        """Extend an adaptively-sized pool in place (later submits may
        queue larger requests than the first sizing saw).  Existing pages
        keep their ids; the OOB sentinel moves from old to new ``n_pages``
        in the table shadow and is re-pushed before the next dispatch.
        Growing changes the pool leaves' shapes, so the next dispatch
        retraces the jitted step — the submit path grows in geometric
        steps to bound how often that compile cliff is paid."""
        import jax.numpy as jnp

        old = self.n_pages
        for name, leaf in self.cache.items():
            if name.endswith("_pages"):
                pad = jnp.zeros(
                    leaf.shape[:1] + (new_n - old,) + leaf.shape[2:], leaf.dtype
                )
                self.cache[name] = jnp.concatenate([leaf, pad], axis=1)
        self.n_pages = new_n
        self._free_pages.extend(range(old, new_n))
        self._page_refs.extend([0] * (new_n - old))
        self._table[self._table == old] = new_n
        self._table_dirty = True
        _LOG.info(
            "paged pool grown adaptively: %d -> %d pages (queue depth %d)",
            old, new_n, len(self.pending),
        )

    # ------------------------------------------------------- page allocator
    @property
    def peak_cache_bytes(self) -> int:
        """High-water cache footprint: pages actually resident (paged) or
        the full dense reservation."""
        if self.cache_mode != "paged":
            return sum(
                leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(self.cache)
            )
        return self.peak_pages * self.page_bytes

    def _incref(self, pid: int) -> None:
        """Add a reference (stitch / cache adoption), tracking the shared
        high-water mark at the 1 -> 2 transition."""
        self._page_refs[pid] += 1
        if self._page_refs[pid] == 2:
            self._shared_pages += 1
            if self._shared_pages > self.pages_shared_peak:
                self.pages_shared_peak = self._shared_pages

    def _decref(self, pid: int) -> None:
        """Drop one reference; the page returns to the free list only when
        its last holder (slot or prefix cache) lets go."""
        self._page_refs[pid] -= 1
        if self._page_refs[pid] < 0:  # allocator invariant
            raise AssertionError(f"page {pid} refcount went negative")
        if self._page_refs[pid] == 1:
            self._shared_pages -= 1
        elif self._page_refs[pid] == 0:
            self._free_pages.append(pid)  # LIFO: reuse hot pages
            self.pages_in_use -= 1

    def _alloc_page(self, row: int) -> Optional[int]:
        """Claim a free page for ``row`` (refcount 1).

        On exhaustion, recover in escalating order: evict LRU cached
        prefixes nobody maps, then preempt the youngest active slot
        (requeueing its request — deterministic sampling streams make the
        rerun byte-identical).  If the youngest is ``row`` itself it is
        parked in favor of older slots and ``None`` is returned; the
        caller must drop the row from this tick.  Raises only when a
        lone request cannot fit in the entire pool.
        """
        while not self._free_pages:
            if self.prefix is not None:
                evicted = self.prefix.evict(1, lambda p: self._page_refs[p])
                if evicted:
                    for pid in evicted:
                        self._decref(pid)  # cache ownership -> free list
                    self.prefix_evictions += len(evicted)
                    continue
            victim = None
            for i, s in enumerate(self.slots):
                if s.req is not None and (victim is None or s.seq > self.slots[victim].seq):
                    victim = i
            others_active = any(
                s.req is not None for j, s in enumerate(self.slots) if j != row
            )
            if victim is None or (victim == row and not others_active):
                raise RuntimeError(
                    f"paged KV pool exhausted ({self.n_pages} pages of "
                    f"{self.page_size} tokens) with nothing evictable or "
                    "preemptable; raise total_pages or lower request length"
                )
            self._preempt(victim)
            if victim == row:
                return None
        pid = self._free_pages.pop()
        self._page_refs[pid] = 1
        self.pages_in_use += 1
        self.page_allocs += 1
        return pid

    def _copy_page(self, src: int, dst: int) -> None:
        """Copy-on-write: duplicate one physical page across every layer
        and pool leaf (one device op per leaf, outside the jitted step)."""
        for name, leaf in self.cache.items():
            if name.endswith("_pages"):
                self.cache[name] = leaf.at[:, dst].set(leaf[:, src])

    def _ensure_pages(
        self, row: int, n_tokens: int, write_start: Optional[int] = None
    ) -> bool:
        """Back row ``row``'s first ``n_tokens`` positions with physical
        pages (allocate-on-write, called ahead of every dispatch that will
        write those positions).

        ``write_start`` marks the first position the coming dispatch will
        write: any page in the write range that another holder (a sharing
        slot or the prefix cache) still references is copied to a private
        page first, so shared pages are immutable once published.  Returns
        False if ``row`` itself was preempted while recovering pool space
        (the caller must drop the row from this tick's dispatch).
        """
        need = -(-n_tokens // self.page_size)
        if need > self.pages_per_slot:
            raise ValueError(
                f"request needs {n_tokens} cache positions but max_len="
                f"{self.max_len} caps a slot at {self.pages_per_slot} pages "
                f"of {self.page_size} tokens"
            )
        pages = self._slot_pages[row]
        shortfall = (need - len(pages)) - len(self._free_pages)
        if write_start is not None:
            # the CoW pass below will also allocate one page per shared
            # page in the write range — count those into the bulk reclaim
            shortfall += sum(
                1
                for j in range(min(write_start // self.page_size, len(pages)),
                               min(need, len(pages)))
                if self._page_refs[pages[j]] > 1
            )
        if shortfall > 0 and self.prefix is not None:
            # bulk pre-eviction: reclaim the whole shortfall in one radix
            # pass instead of one tree walk per page inside _alloc_page
            evicted = self.prefix.evict(shortfall, lambda p: self._page_refs[p])
            for pid in evicted:
                self._decref(pid)
            self.prefix_evictions += len(evicted)
        while len(pages) < need:
            pid = self._alloc_page(row)
            if pid is None:
                return False
            self._table[row, len(pages)] = pid
            pages.append(pid)
            self._table_dirty = True
        if write_start is not None:
            for j in range(write_start // self.page_size, need):
                old = pages[j]
                if self._page_refs[old] > 1:  # shared: copy before write
                    new = self._alloc_page(row)
                    if new is None:
                        return False
                    self._copy_page(old, new)
                    self._decref(old)  # still >= 1: another slot / the cache
                    pages[j] = new
                    self._table[row, j] = new
                    self._table_dirty = True
                    self.cow_copies += 1
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return True

    def _release_slot_pages(self, row: int) -> None:
        """Drop the slot's references (free-on-finish for private pages;
        shared/cached pages stay resident) and reset its table row to the
        OOB sentinel so stale writes become no-ops."""
        pages = self._slot_pages[row]
        if not pages:
            return
        for pid in reversed(pages):
            self._decref(pid)
        self._slot_pages[row] = []
        self._table[row, :] = self.n_pages
        self._table_dirty = True

    def _preempt(self, row: int) -> None:
        """Pool-pressure recovery: release the slot and requeue its request
        at the queue front.  Any generated tokens are discarded — the
        per-request sampling stream replays them identically on rerun.

        Delivery counters are rolled back to what the rerun will re-earn
        (the discarded work lands in ``tokens_discarded`` instead), so
        ``tokens_emitted`` always equals tokens actually delivered and the
        paged-vs-dense parity gates stay exact across preemptions."""
        slot = self.slots[row]
        req = slot.req
        self._release_slot_pages(row)
        emitted = len(req.output)
        ingested = min(slot.pos, len(req.prompt)) - slot.skipped_tokens
        self.tokens_emitted -= emitted
        self.prompt_tokens_ingested -= ingested
        self.tokens_discarded += emitted + ingested
        self.prefix_hit_tokens -= slot.hit_tokens
        self.prompt_tokens_skipped -= slot.skipped_tokens
        req.output = []
        req.done = False
        slot.req = None
        slot.pos = 0
        slot.remaining_prompt = []
        slot.hit_tokens = 0
        slot.skipped_tokens = 0
        self.pending.insert(0, req)
        self.preemptions += 1

    # --------------------------------------------------------- prefix cache
    def _stitch_prefix(self, row: int) -> None:
        """Admission-time prefix reuse: map the longest cached prefix of
        the new request's prompt straight into its page table and skip
        prefill for those tokens.  At least one prompt token is always
        held back and re-dispatched — its logits seed generation — so a
        full-prompt hit re-writes one position inside the last shared
        page, which copy-on-write then privatizes."""
        slot = self.slots[row]
        prompt = slot.req.prompt
        path = self.prefix.match(prompt)[: self.pages_per_slot]
        matched = len(path) * self.page_size
        eff = min(matched, len(prompt) - 1)
        if eff <= 0:
            return
        pages = self._slot_pages[row]
        for j, node in enumerate(path):
            self._incref(node.page)
            self._table[row, j] = node.page
            pages.append(node.page)
        self._table_dirty = True
        slot.pos = eff
        slot.remaining_prompt = list(prompt[eff:])
        slot.hit_tokens = matched
        slot.skipped_tokens = eff
        self.prefix_hit_tokens += matched
        self.prompt_tokens_skipped += eff

    def _prefix_insert(self, row: int) -> None:
        """Publish a freshly-ingested prompt's full pages to the radix
        cache (called the moment the prompt is fully resident, before the
        row can finish and release them).  Chunks already cached keep the
        cache's page; only newly adopted pages gain the cache's ref."""
        if self.prefix is None:
            return
        slot = self.slots[row]
        prompt = slot.req.prompt
        n_full = min(len(prompt) // self.page_size, len(self._slot_pages[row]))
        if n_full == 0:
            return
        adopted = self.prefix.insert(prompt, self._slot_pages[row][:n_full])
        for pid in adopted:
            self._incref(pid)

    def _push_table(self) -> None:
        """Sync the host page table to the device cache before a dispatch."""
        if self.cache_mode == "paged" and self._table_dirty:
            import jax.numpy as jnp

            self.cache["page_table"] = jnp.asarray(self._table)
            self._table_dirty = False

    # ------------------------------------------------------------- intake
    def submit(self, reqs: List[Request]) -> None:
        for r in reqs:
            r.sample_stream = self._n_submitted
            self._n_submitted += 1
        self.pending.extend(reqs)
        if self.cache_mode == "paged" and self._adaptive and self.pending:
            # adaptive pool sizing deferred to first (non-empty) submit so
            # the queue depth is known (satellite: the caller no longer
            # guesses); later submits can only GROW the pool, up to the
            # dense reservation — never strand a bigger-than-pool request
            if self.cache is None:
                self._init_paged_pool(None)
            else:
                want = self._adaptive_pages()
                if want > self.n_pages:
                    # geometric step (>= 1.5x) so a stream of growing jobs
                    # pays O(log) recompiles, not one per submit
                    dense_pages = self.max_batch * self.pages_per_slot
                    self._grow_pool(
                        min(dense_pages,
                            max(want, self.n_pages + -(-self.n_pages // 2)))
                    )

    def _refill(self) -> None:
        for row, slot in enumerate(self.slots):
            if slot.req is None and self.pending:
                req = self.pending.pop(0)
                slot.req = req
                slot.pos = 0
                slot.seq = self._admit_seq
                self._admit_seq += 1
                slot.remaining_prompt = list(req.prompt)
                slot.hit_tokens = 0
                slot.skipped_tokens = 0
                # row identity comes from ENUMERATION — _Slot is a value-
                # comparing dataclass, so slots.index(slot) can return a
                # different-but-equal slot and zero the wrong row
                self._reset_row(row)
                if self.prefix is not None:
                    self._stitch_prefix(row)

    def _reset_row(self, row: int) -> None:
        if self.cache_mode == "paged":
            # nothing to zero: the row's pages went back to the free list
            # at finish, its table row is the OOB sentinel, and stale data
            # inside a re-issued page sits past the new owner's write
            # frontier where the causal mask excludes it
            return
        import jax.numpy as jnp

        def zero_row(x):
            if x.ndim >= 2 and x.shape[1] == self.max_batch:
                return x.at[:, row].set(jnp.zeros_like(x[:, row]))
            return x

        self.cache = jax.tree.map(zero_row, self.cache)

    # ------------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine tick.

        Fused mode: pending prompt chunks are ingested first (>= chunk-size
        tokens per prefill dispatch), then every generating slot advances
        one token in a SINGLE decode dispatch regardless of position mix.
        Grouped mode reproduces the seed's per-position-group dispatching
        (with its cross-row KV corruption fixed) for comparison.  NOTE:
        grouped dispatching is inherently wrong for recurrent (SSM /
        hybrid) state — every extra per-tick dispatch re-advances all
        rows' recurrences (KV writes are idempotent, recurrences are
        not).  That unfixable property is part of why the fused path
        exists; use grouped mode only on attention-family models.
        """
        self._refill()
        if not any(s.req is not None for s in self.slots):
            return 0
        emitted = 0
        if self._use_prefill:
            emitted += self._ingest_prompts()
        if self.dispatch_mode == "grouped":
            emitted += self._decode_tick_grouped()
        else:
            emitted += self._decode_tick_fused()
        return emitted

    # -- prompt ingestion (fused chunked prefill) ---------------------------
    def _ingest_prompts(self) -> int:
        emitted = 0
        B, C = self.max_batch, self.prefill_chunk
        while True:
            if self.cache_mode == "paged":
                # reservation pass BEFORE building dispatch inputs: CoW /
                # eviction / preemption all mutate slot state, and a later
                # row's allocation may park an earlier one — the rows list
                # below is computed only after every survivor holds pages
                for i, s in enumerate(self.slots):
                    if s.req is not None and s.remaining_prompt:
                        n = min(C, len(s.remaining_prompt))
                        self._ensure_pages(i, s.pos + n, write_start=s.pos)
            rows = [
                i for i, s in enumerate(self.slots) if s.req is not None and s.remaining_prompt
            ]
            if not rows:
                return emitted
            tokens = np.zeros((B, C), np.int32)
            offsets = np.zeros((B,), np.int32)
            lengths = np.zeros((B,), np.int32)
            temps = np.zeros((B,), np.float32)
            streams = np.zeros((B,), np.int32)
            steps = np.zeros((B,), np.int32)
            stops = np.full((B,), -1, np.int32)
            max_news = np.full((B,), 1 << 30, np.int32)
            for i in rows:
                slot = self.slots[i]
                n = min(C, len(slot.remaining_prompt))
                tokens[i, :n] = slot.remaining_prompt[:n]
                offsets[i] = slot.pos
                lengths[i] = n
                temps[i] = slot.req.temperature
                streams[i] = slot.req.sample_stream
                if slot.req.stop_token is not None:
                    stops[i] = slot.req.stop_token
                max_news[i] = slot.req.max_new_tokens
            self._push_table()
            if self.sample_on_device:
                nxt, done, self.cache = self._prefill(
                    self.params, self.cache, tokens, offsets, lengths, temps,
                    streams, steps, stops, max_news,
                )
                nxt, done, lg = np.asarray(nxt), np.asarray(done), None
            else:
                logits, self.cache = self._prefill(
                    self.params, self.cache, tokens, offsets, lengths
                )
                nxt, done, lg = None, None, np.asarray(logits)
            self.prefill_dispatches += 1
            self.dispatches += 1
            self.heartbeat()
            for i in rows:
                slot = self.slots[i]
                n = min(C, len(slot.remaining_prompt))
                del slot.remaining_prompt[:n]
                slot.pos += n
                self.prompt_tokens_ingested += n
                if not slot.remaining_prompt:
                    # prompt fully resident: publish its full pages to the
                    # prefix cache BEFORE accept (which may finish the row
                    # and drop its references)
                    self._prefix_insert(i)
                    # the chunk's last-token logits seed generation
                    tok = (
                        int(nxt[i])
                        if nxt is not None
                        else self._host_sample(
                            lg[i], slot.req.temperature,
                            stream=slot.req.sample_stream,
                            step=len(slot.req.output),
                        )
                    )
                    self._accept_token(i, tok, bool(done[i]) if done is not None else None)
                    emitted += 1

    # -- decode -------------------------------------------------------------
    def _build_decode_inputs(self):
        B = self.max_batch
        if self.cache_mode == "paged":
            # reservation pass first (see _ingest_prompts): allocation may
            # CoW a shared page or preempt a slot, so inputs are built only
            # from the rows that still hold their pages afterwards
            for i, s in enumerate(self.slots):
                if s.req is not None:
                    self._ensure_pages(i, s.pos + 1, write_start=s.pos)
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        streams = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        stops = np.full((B,), -1, np.int32)
        max_news = np.full((B,), 1 << 30, np.int32)
        active = []
        for i, slot in enumerate(self.slots):
            # parked rows keep their stale pos: dense mode confines the
            # write to their own (dead) row, which is zeroed at refill;
            # paged mode drops it on the OOB page-table sentinel
            pos[i] = slot.pos
            if slot.req is None:
                continue
            active.append(i)
            if slot.remaining_prompt:  # decode-path ingestion fallback
                tokens[i, 0] = slot.remaining_prompt[0]
            elif slot.req.output:
                tokens[i, 0] = slot.req.output[-1]
            else:
                tokens[i, 0] = slot.req.prompt[-1]
            temps[i] = slot.req.temperature
            streams[i] = slot.req.sample_stream
            steps[i] = len(slot.req.output)
            if slot.req.stop_token is not None:
                stops[i] = slot.req.stop_token
            max_news[i] = slot.req.max_new_tokens
        return active, tokens, pos, temps, streams, steps, stops, max_news

    def _decode_dispatch(
        self, tokens, pos, temps, streams, steps, stops, max_news
    ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray], Optional[np.ndarray]]:
        self._push_table()
        if self.sample_on_device:
            nxt, done, self.cache = self._decode(
                self.params, self.cache, tokens, pos, temps, streams, steps,
                stops, max_news,
            )
            out = (np.asarray(nxt), np.asarray(done), None)
        else:
            logits, self.cache = self._decode(self.params, self.cache, tokens, pos)
            out = (None, None, np.asarray(logits))
        self.decode_dispatches += 1
        self.steps_executed += 1
        self.dispatches += 1
        self.heartbeat()
        return out

    def _advance_rows(self, rows, nxt, done, lg) -> int:
        emitted = 0
        for i in rows:
            slot = self.slots[i]
            slot.pos += 1
            if slot.remaining_prompt:
                slot.remaining_prompt.pop(0)
                self.prompt_tokens_ingested += 1
                if slot.remaining_prompt:
                    continue  # still ingesting the prompt
                # decode-path ingestion just wrote the last prompt token:
                # publish the prompt's full pages (MoE/MLA archs reach the
                # prefix cache through this path)
                self._prefix_insert(i)
            tok = (
                int(nxt[i])
                if nxt is not None
                else self._host_sample(
                    lg[i], slot.req.temperature,
                    stream=slot.req.sample_stream, step=len(slot.req.output),
                )
            )
            self._accept_token(i, tok, bool(done[i]) if done is not None else None)
            emitted += 1
        return emitted

    def _decode_tick_fused(self) -> int:
        active, *inputs = self._build_decode_inputs()
        if not active:
            return 0
        nxt, done, lg = self._decode_dispatch(*inputs)
        return self._advance_rows(active, nxt, done, lg)

    def _decode_tick_grouped(self) -> int:
        """Seed-style dispatching: one jitted call per distinct slot
        position.  Every call carries the full per-row position vector, so
        cache writes are correct and idempotent across the tick's calls
        (the seed's scalar-pos variant overwrote OTHER rows' histories);
        only the group's rows consume their call's outputs."""
        active, *inputs = self._build_decode_inputs()
        if not active:
            return 0
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(self.slots[i].pos, []).append(i)
        emitted = 0
        for _, rows in sorted(groups.items()):
            nxt, done, lg = self._decode_dispatch(*inputs)
            emitted += self._advance_rows(rows, nxt, done, lg)
        return emitted

    # -- bookkeeping ---------------------------------------------------------
    def _accept_token(self, row: int, tok: int, done: Optional[bool] = None) -> None:
        slot = self.slots[row]
        slot.req.output.append(tok)
        self.tokens_emitted += 1
        if done is None:
            # host fallback (sample_on_device=False): re-derive the mask
            done = len(slot.req.output) >= slot.req.max_new_tokens or (
                slot.req.stop_token is not None and tok == slot.req.stop_token
            )
        if done or slot.pos >= self.max_len - 1:
            slot.req.done = True
            self.finished.append(slot.req)
            slot.req = None
            slot.remaining_prompt = []
            if self.cache_mode == "paged":
                self._release_slot_pages(row)

    def _host_sample(
        self,
        lg_row: np.ndarray,
        temperature: float,
        stream: Optional[int] = None,
        step: Optional[int] = None,
    ) -> int:
        """Host fallback sampler (``sample_on_device=False``): greedy or
        max-subtracted softmax — ``np.exp(lg / T)`` on raw logits overflows
        for large-magnitude logits.

        When the caller passes the request's ``(stream, step)``, the draw
        comes from an rng keyed on ``(seed, stream, step)`` — like the
        on-device path, independent of scheduling, slot assignment, and
        preemption replays.  Without them (direct/debug calls) it falls
        back to the engine-level rng."""
        lg = np.asarray(lg_row, np.float64)
        if temperature <= 0:
            return int(np.argmax(lg))
        z = (lg - lg.max()) / temperature
        p = np.exp(z)
        p /= p.sum()
        rng = (
            np.random.default_rng((self._rng_seed, stream, step))
            if stream is not None
            else self.rng
        )
        return int(rng.choice(len(p), p=p))

    def run_to_completion(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.pending or any(s.req for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

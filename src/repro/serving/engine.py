"""Serving engine: continuous batching over the decode step.

Slot-based continuous batching (vLLM-style at miniature scale): a fixed
pool of ``max_batch`` slots, each holding one request's cache position;
finished slots are refilled from the pending queue every step, so the
batch stays full under ragged request lengths.  The decode step is the
same jit'd function the multi-pod dry-run lowers — on TPU the cache and
weights are sharded by the decode rule set (DESIGN §3: sequence-sharded
flash-decode).

Prompt ingestion uses the decode path token-by-token (exactly correct,
cache-consistent).  Fused parallel prefill is lowered/validated by the
dry-run (`serve_prefill`); fusing its cache write into this engine is a
documented TODO that does not change the API.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclass
class Request:
    uid: str
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    # filled by the engine
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next cache position to write
    remaining_prompt: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
        heartbeat: Callable[[], None] = lambda: None,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.heartbeat = heartbeat
        self.cache = model.init_cache(max_batch, max_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.pending: List[Request] = []
        self.finished: List[Request] = []
        self.rng = np.random.default_rng(rng_seed)
        self._step = jax.jit(model.decode_step)
        self.steps_executed = 0

    # ------------------------------------------------------------- intake
    def submit(self, reqs: List[Request]) -> None:
        self.pending.extend(reqs)

    def _refill(self) -> None:
        for slot in self.slots:
            if slot.req is None and self.pending:
                req = self.pending.pop(0)
                slot.req = req
                slot.pos = 0
                slot.remaining_prompt = list(req.prompt)
                # NOTE: each slot owns a batch row; row state for a new
                # request starts fresh because positions restart at 0 and
                # attention masks by position.  SSM rows are reset below.
                self._reset_row(self.slots.index(slot))

    def _reset_row(self, row: int) -> None:
        def zero_row(x):
            if x.ndim >= 2 and x.shape[1] == self.max_batch:
                return x.at[:, row].set(jnp.zeros_like(x[:, row]))
            return x

        self.cache = jax.tree.map(zero_row, self.cache)

    # ------------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine tick: every active slot consumes/produces one token."""
        self._refill()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if slot.remaining_prompt:
                tokens[i, 0] = slot.remaining_prompt[0]
            elif slot.req.output:
                tokens[i, 0] = slot.req.output[-1]
            else:
                tokens[i, 0] = slot.req.prompt[-1]

        # all slots share one position counter per row; rows advance in
        # lockstep with their own pos — we step at the max and mask
        # per-row via each row's own position.  Simpler: rows run their own
        # pos by calling decode per distinct pos group.
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(self.slots[i].pos, []).append(i)

        emitted = 0
        for pos, rows in sorted(groups.items()):
            logits, self.cache = self._step(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos)
            )
            self.steps_executed += 1
            self.heartbeat()
            lg = np.asarray(logits[:, 0, : self.model.cfg.vocab_size])
            for i in rows:
                slot = self.slots[i]
                slot.pos += 1
                if slot.remaining_prompt:
                    slot.remaining_prompt.pop(0)
                    if slot.remaining_prompt:
                        continue  # still ingesting the prompt
                # sample the next token
                if slot.req.temperature > 0:
                    p = np.exp(lg[i] / slot.req.temperature)
                    p /= p.sum()
                    nxt = int(self.rng.choice(len(p), p=p))
                else:
                    nxt = int(np.argmax(lg[i]))
                slot.req.output.append(nxt)
                emitted += 1
                if (
                    len(slot.req.output) >= slot.req.max_new_tokens
                    or slot.pos >= self.max_len - 1
                ):
                    slot.req.done = True
                    self.finished.append(slot.req)
                    slot.req = None
        return emitted

    def run_to_completion(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.pending or any(s.req for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

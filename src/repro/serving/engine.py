"""Serving engine: continuous batching, fused chunked prefill, and
single-dispatch vectorized decode.

Slot-based continuous batching (vLLM-style at miniature scale): a fixed
pool of ``max_batch`` slots, each holding one request's cache position;
finished slots are refilled from the pending queue every step, so the
batch stays full under ragged request lengths.

Hot-path structure (this is the whole point — throughput limited by the
hardware, not by dispatch count):

- **decode**: ONE jitted dispatch per tick for any mix of slot positions.
  ``Model.decode_step`` takes a per-row position vector ``[B]``, so rows
  at different depths advance together; the seed engine's one-dispatch-
  per-distinct-position loop (up to B sequential device calls per token)
  is retained only as ``dispatch_mode="grouped"`` for benchmarking.
- **prefill**: prompts are ingested through ``Model.prefill_chunk`` in
  ``prefill_chunk``-token slices — the KV/SSM cache for a whole chunk is
  written in one dispatch instead of token-at-a-time through the decode
  path.  Architectures without fused-prefill support (enc-dec, VLM, MoE
  capacity routing, rolling sliding-window caches) fall back to decode-
  path ingestion, still at one dispatch per tick.
- **sampling**: greedy/temperature sampling runs on-device inside the
  same dispatch (``repro.serving.sampling``); only ``B`` token ids cross
  the host boundary per tick instead of ``(B, vocab)`` logits.
  ``sample_on_device=False`` restores the host path (now numerically
  stable: max-subtracted softmax).

- **cache**: ``cache_mode="paged"`` replaces the dense per-slot
  ``max_len`` reservation with a shared pool of fixed-size KV pages and
  a per-slot page table.  The engine owns the allocator: pages are
  claimed *as positions are written* (allocate-on-write, ahead of each
  dispatch) and returned to the free list the moment a request finishes,
  so cache memory tracks tokens actually resident instead of the
  worst-case ``max_batch * max_len`` reservation.  Freed slots' table
  entries hold an out-of-bounds sentinel, so a parked row's (stale)
  write is dropped on device rather than corrupting a page that has been
  re-issued to another slot.  ``peak_pages`` / ``peak_cache_bytes``
  record the high-water mark the benchmark compares against the dense
  reservation.
- **stop tokens**: requests may carry a ``stop_token``; the fused
  dispatches return a done mask computed on device
  (``repro.serving.sampling.done_mask``), so the host finalizes rows
  straight off the mask instead of re-deriving the stop condition, and
  finished rows are parked (pages freed) before the next tick's
  dispatch.

Dispatch accounting: ``decode_dispatches`` / ``prefill_dispatches`` /
``dispatches`` (their sum) and ``tokens_emitted`` /
``prompt_tokens_ingested`` feed ``benchmarks/bench_serving.py``'s
dispatches-per-token metric.  ``steps_executed`` keeps its seed meaning
(number of jitted decode calls).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models import Model
from repro.serving.sampling import make_decode_step, make_prefill_step


@dataclass
class Request:
    uid: str
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    # emitting this token id finishes the request (it is kept in the
    # output); None disables.  Checked on device via the fused done mask.
    stop_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    done: bool = False
    # per-request sampling stream id (assigned at submit; scheduling- and
    # slot-independent so fused and grouped modes draw identical samples)
    sample_stream: int = field(default=0, compare=False, repr=False)


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next cache position to write
    remaining_prompt: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 256,
        rng_seed: int = 0,
        heartbeat: Callable[[], None] = lambda: None,
        prefill_chunk: int = 16,
        dispatch_mode: str = "fused",
        sample_on_device: bool = True,
        cache_mode: str = "dense",
        page_size: int = 16,
        total_pages: Optional[int] = None,
    ):
        if dispatch_mode not in ("fused", "grouped"):
            raise ValueError(f"dispatch_mode must be fused|grouped, got {dispatch_mode!r}")
        if cache_mode not in ("dense", "paged"):
            raise ValueError(f"cache_mode must be dense|paged, got {cache_mode!r}")
        if cache_mode == "paged" and not model.supports_paged_cache:
            raise ValueError(
                "cache_mode='paged' unsupported for arch "
                f"{model.cfg.name!r} (no pageable KV cache)"
            )
        if dispatch_mode == "grouped" and model.cfg.family in ("ssm", "hybrid"):
            # per-group re-dispatch re-advances recurrent state every extra
            # call per tick (KV writes are idempotent, recurrences are not):
            # grouped output would be silently wrong, so refuse up front
            raise ValueError(
                "dispatch_mode='grouped' corrupts recurrent SSM/hybrid state; "
                "use the fused engine for family "
                f"{model.cfg.family!r}"
            )
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.heartbeat = heartbeat
        self.prefill_chunk = int(prefill_chunk)
        self.dispatch_mode = dispatch_mode
        self.sample_on_device = sample_on_device
        self.cache_mode = cache_mode
        self.page_size = int(page_size)
        if cache_mode == "paged":
            self.pages_per_slot = -(-max_len // self.page_size)
            dense_pages = max_batch * self.pages_per_slot
            self.n_pages = int(total_pages) if total_pages else dense_pages
            self.cache = model.init_cache(
                max_batch, max_len,
                paged=True, page_size=self.page_size, n_pages=self.n_pages,
            )
            # host-side allocator: free list + per-slot page lists + the
            # numpy shadow of the device page table (OOB sentinel = free)
            self._free_pages = list(range(self.n_pages))
            self._slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
            self._table = np.full(
                (max_batch, self.pages_per_slot), self.n_pages, np.int32
            )
            self._table_dirty = True
            # bytes of ONE page across every layer and pool leaf (k+v, or
            # the MLA latent pool) — peak_cache_bytes = peak_pages * this
            self.page_bytes = sum(
                leaf.size * leaf.dtype.itemsize // self.n_pages
                for name, leaf in self.cache.items()
                if name.endswith("_pages")
            )
            self.dense_cache_bytes = dense_pages * self.page_bytes
            self.pages_in_use = 0
            self.peak_pages = 0
            self.page_allocs = 0  # lifetime allocations (> n_pages => reuse)
        else:
            self.cache = model.init_cache(max_batch, max_len)
        self.slots = [_Slot() for _ in range(max_batch)]
        self.pending: List[Request] = []
        self.finished: List[Request] = []
        self.rng = np.random.default_rng(rng_seed)
        self._n_submitted = 0
        self._decode = jax.jit(make_decode_step(model, rng_seed, sample_on_device))
        self._use_prefill = (
            dispatch_mode == "fused"
            and self.prefill_chunk > 0
            and model.supports_fused_prefill
            and not self._cache_is_rolling()
        )
        self._prefill = (
            jax.jit(make_prefill_step(model, rng_seed, sample_on_device))
            if self._use_prefill
            else None
        )
        # dispatch accounting
        self.steps_executed = 0  # jitted decode calls (seed-compatible name)
        self.decode_dispatches = 0
        self.prefill_dispatches = 0
        self.dispatches = 0
        self.tokens_emitted = 0
        self.prompt_tokens_ingested = 0

    def _cache_is_rolling(self) -> bool:
        """Sliding-window KV caches wrap writes mod t; right-padded prefill
        chunks could then alias still-visible slots — decode-path ingest."""
        k = self.cache.get("k") if isinstance(self.cache, dict) else None
        return k is not None and k.shape[2] < self.max_len

    # ------------------------------------------------------- page allocator
    @property
    def peak_cache_bytes(self) -> int:
        """High-water cache footprint: pages actually resident (paged) or
        the full dense reservation."""
        if self.cache_mode != "paged":
            return sum(
                leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(self.cache)
            )
        return self.peak_pages * self.page_bytes

    def _ensure_pages(self, row: int, n_tokens: int) -> None:
        """Back row ``row``'s first ``n_tokens`` positions with physical
        pages (allocate-on-write, called ahead of every dispatch that will
        write those positions)."""
        need = -(-n_tokens // self.page_size)
        if need > self.pages_per_slot:
            raise ValueError(
                f"request needs {n_tokens} cache positions but max_len="
                f"{self.max_len} caps a slot at {self.pages_per_slot} pages "
                f"of {self.page_size} tokens"
            )
        pages = self._slot_pages[row]
        while len(pages) < need:
            if not self._free_pages:
                raise RuntimeError(
                    f"paged KV pool exhausted ({self.n_pages} pages of "
                    f"{self.page_size} tokens); raise total_pages or lower "
                    "concurrency"
                )
            pid = self._free_pages.pop()
            self._table[row, len(pages)] = pid
            pages.append(pid)
            self.pages_in_use += 1
            self.page_allocs += 1
            self._table_dirty = True
        self.peak_pages = max(self.peak_pages, self.pages_in_use)

    def _free_slot_pages(self, row: int) -> None:
        """Free-on-finish: return the slot's pages to the pool and reset
        its table row to the OOB sentinel (stale writes become no-ops)."""
        pages = self._slot_pages[row]
        if not pages:
            return
        self._free_pages.extend(reversed(pages))  # LIFO: reuse hot pages
        self.pages_in_use -= len(pages)
        self._slot_pages[row] = []
        self._table[row, :] = self.n_pages
        self._table_dirty = True

    def _push_table(self) -> None:
        """Sync the host page table to the device cache before a dispatch."""
        if self.cache_mode == "paged" and self._table_dirty:
            import jax.numpy as jnp

            self.cache["page_table"] = jnp.asarray(self._table)
            self._table_dirty = False

    # ------------------------------------------------------------- intake
    def submit(self, reqs: List[Request]) -> None:
        for r in reqs:
            r.sample_stream = self._n_submitted
            self._n_submitted += 1
        self.pending.extend(reqs)

    def _refill(self) -> None:
        for row, slot in enumerate(self.slots):
            if slot.req is None and self.pending:
                req = self.pending.pop(0)
                slot.req = req
                slot.pos = 0
                slot.remaining_prompt = list(req.prompt)
                # row identity comes from ENUMERATION — _Slot is a value-
                # comparing dataclass, so slots.index(slot) can return a
                # different-but-equal slot and zero the wrong row
                self._reset_row(row)

    def _reset_row(self, row: int) -> None:
        if self.cache_mode == "paged":
            # nothing to zero: the row's pages went back to the free list
            # at finish, its table row is the OOB sentinel, and stale data
            # inside a re-issued page sits past the new owner's write
            # frontier where the causal mask excludes it
            return
        import jax.numpy as jnp

        def zero_row(x):
            if x.ndim >= 2 and x.shape[1] == self.max_batch:
                return x.at[:, row].set(jnp.zeros_like(x[:, row]))
            return x

        self.cache = jax.tree.map(zero_row, self.cache)

    # ------------------------------------------------------------- stepping
    def step(self) -> int:
        """One engine tick.

        Fused mode: pending prompt chunks are ingested first (>= chunk-size
        tokens per prefill dispatch), then every generating slot advances
        one token in a SINGLE decode dispatch regardless of position mix.
        Grouped mode reproduces the seed's per-position-group dispatching
        (with its cross-row KV corruption fixed) for comparison.  NOTE:
        grouped dispatching is inherently wrong for recurrent (SSM /
        hybrid) state — every extra per-tick dispatch re-advances all
        rows' recurrences (KV writes are idempotent, recurrences are
        not).  That unfixable property is part of why the fused path
        exists; use grouped mode only on attention-family models.
        """
        self._refill()
        if not any(s.req is not None for s in self.slots):
            return 0
        emitted = 0
        if self._use_prefill:
            emitted += self._ingest_prompts()
        if self.dispatch_mode == "grouped":
            emitted += self._decode_tick_grouped()
        else:
            emitted += self._decode_tick_fused()
        return emitted

    # -- prompt ingestion (fused chunked prefill) ---------------------------
    def _ingest_prompts(self) -> int:
        emitted = 0
        B, C = self.max_batch, self.prefill_chunk
        while True:
            rows = [
                i for i, s in enumerate(self.slots) if s.req is not None and s.remaining_prompt
            ]
            if not rows:
                return emitted
            tokens = np.zeros((B, C), np.int32)
            offsets = np.zeros((B,), np.int32)
            lengths = np.zeros((B,), np.int32)
            temps = np.zeros((B,), np.float32)
            streams = np.zeros((B,), np.int32)
            steps = np.zeros((B,), np.int32)
            stops = np.full((B,), -1, np.int32)
            max_news = np.full((B,), 1 << 30, np.int32)
            for i in rows:
                slot = self.slots[i]
                n = min(C, len(slot.remaining_prompt))
                tokens[i, :n] = slot.remaining_prompt[:n]
                offsets[i] = slot.pos
                lengths[i] = n
                temps[i] = slot.req.temperature
                streams[i] = slot.req.sample_stream
                if slot.req.stop_token is not None:
                    stops[i] = slot.req.stop_token
                max_news[i] = slot.req.max_new_tokens
                if self.cache_mode == "paged":
                    self._ensure_pages(i, slot.pos + n)
            self._push_table()
            if self.sample_on_device:
                nxt, done, self.cache = self._prefill(
                    self.params, self.cache, tokens, offsets, lengths, temps,
                    streams, steps, stops, max_news,
                )
                nxt, done, lg = np.asarray(nxt), np.asarray(done), None
            else:
                logits, self.cache = self._prefill(
                    self.params, self.cache, tokens, offsets, lengths
                )
                nxt, done, lg = None, None, np.asarray(logits)
            self.prefill_dispatches += 1
            self.dispatches += 1
            self.heartbeat()
            for i in rows:
                slot = self.slots[i]
                n = min(C, len(slot.remaining_prompt))
                del slot.remaining_prompt[:n]
                slot.pos += n
                self.prompt_tokens_ingested += n
                if not slot.remaining_prompt:
                    # the chunk's last-token logits seed generation
                    tok = (
                        int(nxt[i])
                        if nxt is not None
                        else self._host_sample(lg[i], slot.req.temperature)
                    )
                    self._accept_token(i, tok, bool(done[i]) if done is not None else None)
                    emitted += 1

    # -- decode -------------------------------------------------------------
    def _build_decode_inputs(self):
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        streams = np.zeros((B,), np.int32)
        steps = np.zeros((B,), np.int32)
        stops = np.full((B,), -1, np.int32)
        max_news = np.full((B,), 1 << 30, np.int32)
        active = []
        for i, slot in enumerate(self.slots):
            # parked rows keep their stale pos: dense mode confines the
            # write to their own (dead) row, which is zeroed at refill;
            # paged mode drops it on the OOB page-table sentinel
            pos[i] = slot.pos
            if slot.req is None:
                continue
            active.append(i)
            if slot.remaining_prompt:  # decode-path ingestion fallback
                tokens[i, 0] = slot.remaining_prompt[0]
            elif slot.req.output:
                tokens[i, 0] = slot.req.output[-1]
            else:
                tokens[i, 0] = slot.req.prompt[-1]
            temps[i] = slot.req.temperature
            streams[i] = slot.req.sample_stream
            steps[i] = len(slot.req.output)
            if slot.req.stop_token is not None:
                stops[i] = slot.req.stop_token
            max_news[i] = slot.req.max_new_tokens
            if self.cache_mode == "paged":
                self._ensure_pages(i, slot.pos + 1)
        return active, tokens, pos, temps, streams, steps, stops, max_news

    def _decode_dispatch(
        self, tokens, pos, temps, streams, steps, stops, max_news
    ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray], Optional[np.ndarray]]:
        self._push_table()
        if self.sample_on_device:
            nxt, done, self.cache = self._decode(
                self.params, self.cache, tokens, pos, temps, streams, steps,
                stops, max_news,
            )
            out = (np.asarray(nxt), np.asarray(done), None)
        else:
            logits, self.cache = self._decode(self.params, self.cache, tokens, pos)
            out = (None, None, np.asarray(logits))
        self.decode_dispatches += 1
        self.steps_executed += 1
        self.dispatches += 1
        self.heartbeat()
        return out

    def _advance_rows(self, rows, nxt, done, lg) -> int:
        emitted = 0
        for i in rows:
            slot = self.slots[i]
            slot.pos += 1
            if slot.remaining_prompt:
                slot.remaining_prompt.pop(0)
                self.prompt_tokens_ingested += 1
                if slot.remaining_prompt:
                    continue  # still ingesting the prompt
            tok = (
                int(nxt[i]) if nxt is not None else self._host_sample(lg[i], slot.req.temperature)
            )
            self._accept_token(i, tok, bool(done[i]) if done is not None else None)
            emitted += 1
        return emitted

    def _decode_tick_fused(self) -> int:
        active, *inputs = self._build_decode_inputs()
        if not active:
            return 0
        nxt, done, lg = self._decode_dispatch(*inputs)
        return self._advance_rows(active, nxt, done, lg)

    def _decode_tick_grouped(self) -> int:
        """Seed-style dispatching: one jitted call per distinct slot
        position.  Every call carries the full per-row position vector, so
        cache writes are correct and idempotent across the tick's calls
        (the seed's scalar-pos variant overwrote OTHER rows' histories);
        only the group's rows consume their call's outputs."""
        active, *inputs = self._build_decode_inputs()
        if not active:
            return 0
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(self.slots[i].pos, []).append(i)
        emitted = 0
        for _, rows in sorted(groups.items()):
            nxt, done, lg = self._decode_dispatch(*inputs)
            emitted += self._advance_rows(rows, nxt, done, lg)
        return emitted

    # -- bookkeeping ---------------------------------------------------------
    def _accept_token(self, row: int, tok: int, done: Optional[bool] = None) -> None:
        slot = self.slots[row]
        slot.req.output.append(tok)
        self.tokens_emitted += 1
        if done is None:
            # host fallback (sample_on_device=False): re-derive the mask
            done = len(slot.req.output) >= slot.req.max_new_tokens or (
                slot.req.stop_token is not None and tok == slot.req.stop_token
            )
        if done or slot.pos >= self.max_len - 1:
            slot.req.done = True
            self.finished.append(slot.req)
            slot.req = None
            slot.remaining_prompt = []
            if self.cache_mode == "paged":
                self._free_slot_pages(row)

    def _host_sample(self, lg_row: np.ndarray, temperature: float) -> int:
        """Host fallback sampler (``sample_on_device=False``): greedy or
        max-subtracted softmax — ``np.exp(lg / T)`` on raw logits overflows
        for large-magnitude logits."""
        lg = np.asarray(lg_row, np.float64)
        if temperature <= 0:
            return int(np.argmax(lg))
        z = (lg - lg.max()) / temperature
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def run_to_completion(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.pending or any(s.req for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

"""RequestScheduler — the serving tier's admission and batching layer.

Owns request lifecycle: the pending queue, the slot pool (continuous
batching — freed rows are refilled with queued requests every tick, not
only at drain), the preemption/requeue policy the allocator escalates
to under pool pressure, and per-request latency accounting (queue wait
and time-to-first-token, denominated in engine ticks so the numbers are
deterministic under the virtual clock).

Knobs:

- ``refill_policy``: ``"continuous"`` (default) admits into every freed
  row at each tick — the continuous-batching behaviour; ``"drain"``
  only admits when *all* slots are empty (the naive serve-a-batch,
  drain, serve-the-next-batch loop) and exists as the baseline the
  benchmark's staggered-arrival scenario compares against.
- ``prefill_token_budget``: cap on prompt tokens ingested per tick.
  ``None`` (default) drains every pending prompt chunk before decoding
  — the historical schedule, kept exactly so the benchmark's
  dispatch-parity gates hold.  A finite budget interleaves chunked
  prefill with decode: long cold prompts stop starving the tick's
  decode dispatch, at the cost of extra prefill dispatches.

The scheduler never touches device state.  Admission calls into the
:class:`~repro.serving.cache_manager.KVCacheManager` (row reset +
prefix stitching); the cache manager calls back into
:meth:`preempt_for` when the page pool is exhausted — preemption policy
(youngest-first, requeue-at-front, counter rollback) lives HERE, page
release lives there.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.serving.types import EngineStats, Request, Slot, percentiles


class RequestScheduler:
    # sentinel returned by preempt_for: no victim younger than the
    # requester exists — the requester itself must yield, which the
    # allocator does at a clean seam AFTER its allocation loop unwinds
    # (see preempt_for's docstring)
    YIELD = -1

    def __init__(
        self,
        max_batch: int,
        stats: EngineStats,
        *,
        refill_policy: str = "continuous",
        prefill_token_budget: Optional[int] = None,
        role: str = "unified",
    ):
        if refill_policy not in ("continuous", "drain"):
            raise ValueError(
                f"refill_policy must be continuous|drain, got {refill_policy!r}"
            )
        if prefill_token_budget is not None and prefill_token_budget <= 0:
            raise ValueError("prefill_token_budget must be positive or None")
        if role not in ("unified", "prefill", "decode"):
            raise ValueError(
                f"role must be unified|prefill|decode, got {role!r}"
            )
        self.max_batch = max_batch
        self.stats = stats
        self.refill_policy = refill_policy
        self.prefill_token_budget = prefill_token_budget
        # disaggregated-serving role: a "decode" scheduler admits ONLY
        # sealed handoff records (fresh prefill work is refused at
        # submit — it belongs on the request queue, not here); a
        # "prefill" scheduler refuses handoffs and never runs a decode
        # tick (the engine finishes each prompt at ingest completion)
        self.role = role
        self.slots = [Slot() for _ in range(max_batch)]
        self.pending: List[Request] = []
        self.finished: List[Request] = []
        self.tick = 0  # engine steps begun; the unit of all latency stats
        self._admit_seq = 0
        self._n_submitted = 0
        # latency samples in ticks (appended as events happen; consumers
        # scope a measurement window with sample_marks()/timing(); None =
        # sample voided by preemption rollback).  The lists are bounded by
        # trim_samples; the *_dropped counters record how many samples
        # fell off the front, so a mark recorded with sample_marks() stays
        # an absolute sample id across trims
        self.queue_waits: List[Optional[int]] = []
        self.ttfts: List[Optional[int]] = []
        self.waits_dropped = 0
        self.ttfts_dropped = 0
        # wired by the engine to KVCacheManager: admission stitches
        # prefixes, finish/preempt release pages
        self.cache = None

    # ------------------------------------------------------------- intake
    def submit(self, reqs: List[Request]) -> None:
        if self.role == "decode":
            fresh = [r.uid for r in reqs if not r.handoff]
            if fresh:
                raise RuntimeError(
                    "decode-role scheduler refuses fresh prefill work "
                    f"(uids {fresh}); route it through a prefill worker "
                    "and submit the sealed handoff via submit_handoff"
                )
        for r in reqs:
            # per-request sampling stream: submit-order, scheduling-
            # independent, so any admission policy draws identical samples
            r.sample_stream = self._n_submitted
            self._n_submitted += 1
            if r.submit_tick < 0:
                r.submit_tick = self.tick
        self.pending.extend(reqs)

    def submit_handoff(self, req: Request) -> None:
        """Queue a request admitted from a prefill worker's sealed
        handoff record.  Like :meth:`submit_resume` the sampling stream
        is NOT reassigned — byte-identical decode requires the stream
        the original request-queue submission drew on the prefill
        worker — and the local counter advances past it so later local
        submissions cannot collide.  Unlike a resume the record carries
        no emitted output, so it queues at the BACK like fresh work
        (handoffs arrive in decode-queue order; there is no interrupted
        attempt to get back ahead of)."""
        if self.role == "prefill":
            raise RuntimeError(
                "prefill-role scheduler refuses handoff admissions "
                f"(uid {req.uid!r}); handoffs are decode-side work"
            )
        req.handoff = True
        self._n_submitted = max(self._n_submitted, req.sample_stream + 1)
        if req.submit_tick < 0:
            req.submit_tick = self.tick
        self.pending.append(req)

    def submit_resume(self, req: Request) -> None:
        """Queue a checkpoint-resumed request WITHOUT reassigning its
        sampling stream: byte-identical continuation requires the stream
        the original submission drew, not this worker's next id.  The
        local stream counter still advances so later fresh submissions
        on this scheduler cannot collide with the resumed stream."""
        self._n_submitted = max(self._n_submitted, req.sample_stream + 1)
        if req.submit_tick < 0:
            req.submit_tick = self.tick
        self.pending.insert(0, req)

    # ------------------------------------------------------------ admission
    def begin_tick(self) -> None:
        """Advance the tick clock and run the admission policy."""
        self.tick += 1
        self.stats.ticks += 1
        self.refill()

    def refill(self) -> None:
        if self.refill_policy == "drain" and any(
            s.req is not None for s in self.slots
        ):
            return
        for row, slot in enumerate(self.slots):
            if slot.req is None and self.pending:
                # admission control: a request admitted into a pool with
                # neither a free nor an evictable page can only yield
                # straight back to the queue on its first allocation (as
                # the youngest slot it has nobody to preempt) — pure
                # admit/rollback churn; hold the queue until capacity
                # exists — active slots finish and reopen the gate
                if not self.cache.can_admit():
                    break
                self._admit(row, self.pending.pop(0))

    def _admit(self, row: int, req: Request) -> None:
        slot = self.slots[row]
        slot.req = req
        slot.pos = 0
        slot.seq = self._admit_seq
        self._admit_seq += 1
        slot.remaining_prompt = list(req.prompt)
        slot.hit_tokens = 0
        slot.hit_tokens_partial = 0
        slot.skipped_tokens = 0
        req.admit_tick = self.tick
        self.stats.admissions += 1
        slot.wait_idx = len(self.queue_waits)
        slot.ttft_idx = -1
        self.queue_waits.append(self.tick - req.submit_tick)
        # row identity comes from ENUMERATION — Slot is a value-comparing
        # dataclass, so slots.index(slot) can return a different-but-equal
        # slot and zero the wrong row
        self.cache.reset_row(row)
        self.cache.stitch_prefix(row, slot)

    def has_active(self) -> bool:
        return any(s.req is not None for s in self.slots)

    # ----------------------------------------------------------- lifecycle
    def on_token(self, row: int) -> None:
        """Called by the executor for every accepted token."""
        slot = self.slots[row]
        req = slot.req
        if req.first_token_tick < 0:
            req.first_token_tick = self.tick
            slot.ttft_idx = len(self.ttfts)
            self.ttfts.append(self.tick - req.submit_tick)

    def drain_finished(self) -> List[Request]:
        """Hand over (and forget) the finished requests accumulated so
        far.  Long-lived consumers (the queue-streaming lease) use this
        instead of reading ``finished`` so served requests do not pile
        up in memory for the lease's lifetime."""
        done, self.finished = self.finished, []
        return done

    def finish(self, row: int) -> None:
        """Retire a completed request and free its row for refill."""
        slot = self.slots[row]
        slot.req.done = True
        slot.req.done_tick = self.tick
        self.finished.append(slot.req)
        slot.req = None
        slot.remaining_prompt = []
        self.cache.release_slot(row)

    # ----------------------------------------------------------- preemption
    def preempt_for(self, row: int) -> Optional[int]:
        """Pool-pressure escalation point (called by the cache manager's
        allocator): preempt the youngest active slot *other than* — and
        strictly younger than — the requesting ``row``, and return its
        row.  The requester is never selected as victim here: preempting
        it mid-allocation would release the very pages being assembled
        and hand its own row back to the allocator (the old bug).  When
        the requester is itself the youngest active slot, age priority
        says the requester is the one that must go — but that yield is
        NOT performed here: ``YIELD`` is returned and the cache manager
        requeues the row (via :meth:`preempt`) only after its allocation
        loop has fully unwound.  Preempting an *older* slot instead
        would invert age priority and can live-lock — two slots
        preempting each other forever with neither finishing.  Returns
        None — allocator raises — only when no *other* slot is active (a
        lone request that cannot fit the pool must fail loudly, not
        live-lock)."""
        me = self.slots[row].seq
        victim = None
        others = False
        for i, s in enumerate(self.slots):
            if i == row or s.req is None:
                continue
            others = True
            if s.seq > me and (victim is None or s.seq > self.slots[victim].seq):
                victim = i
        if victim is None:
            return self.YIELD if others else None
        self.preempt(victim)
        return victim

    def preempt(self, row: int) -> None:
        """Release the slot and requeue its request at the queue front.
        Any generated tokens are discarded — the per-request sampling
        stream replays them identically on rerun.

        Delivery counters are rolled back to what the rerun will re-earn
        (the discarded work lands in ``tokens_discarded`` instead), so
        ``tokens_emitted`` always equals tokens actually delivered and
        the paged-vs-dense parity gates stay exact across preemptions.
        The request keeps its ``submit_tick`` (its latency clock does
        not reset) but re-earns admission and first-token times."""
        slot = self.slots[row]
        req = slot.req
        self.cache.release_slot(row)
        # checkpoint-resumed requests keep their pre-seeded output: those
        # tokens live in the extended prompt and were never emitted here
        emitted = len(req.output) - req.resume_base
        ingested = min(slot.pos, len(req.prompt)) - slot.skipped_tokens
        st = self.stats
        st.tokens_emitted -= emitted
        st.prompt_tokens_ingested -= ingested
        st.tokens_discarded += emitted + ingested
        # the decode-work subset separately: this is what a generation
        # checkpoint saves a resume from re-deriving (minus the frontier
        # token), so recovery efficiency = recovered / discarded
        st.decode_tokens_discarded += emitted
        st.prefix_hit_tokens -= slot.hit_tokens
        st.prefix_hit_tokens_partial -= slot.hit_tokens_partial
        st.prompt_tokens_skipped -= slot.skipped_tokens
        del req.output[req.resume_base:]
        req.done = False
        req.admit_tick = -1
        req.first_token_tick = -1
        # void the aborted attempt's latency samples (in place: windowing
        # by list index must stay stable); the rerun records fresh ones
        if slot.wait_idx >= 0:
            self.queue_waits[slot.wait_idx] = None
        if slot.ttft_idx >= 0:
            self.ttfts[slot.ttft_idx] = None
        slot.req = None
        slot.pos = 0
        slot.remaining_prompt = []
        slot.hit_tokens = 0
        slot.hit_tokens_partial = 0
        slot.skipped_tokens = 0
        slot.wait_idx = -1
        slot.ttft_idx = -1
        self.pending.insert(0, req)
        st.preemptions += 1

    # ------------------------------------------------------------- reporting
    def trim_samples(self, max_samples: int) -> None:
        """Bound the latency-sample lists to their ``max_samples`` most
        recent entries (long-lived streaming leases call this per loop;
        their percentiles then describe the recent window).  Slots'
        recorded sample indices are remapped so preemption rollback
        keeps voiding the right entries; an index that falls off the
        front is simply no longer voidable.  The cumulative
        ``waits_dropped``/``ttfts_dropped`` offsets advance so marks
        recorded with :meth:`sample_marks` before the trim keep
        addressing the same samples through :meth:`timing`."""
        for name, dropped in (("queue_waits", "waits_dropped"),
                              ("ttfts", "ttfts_dropped")):
            lst = getattr(self, name)
            drop = len(lst) - max_samples
            if drop <= 0:
                continue
            setattr(self, name, lst[drop:])
            setattr(self, dropped, getattr(self, dropped) + drop)
            attr = "wait_idx" if name == "queue_waits" else "ttft_idx"
            for slot in self.slots:
                idx = getattr(slot, attr)
                if idx >= 0:
                    setattr(slot, attr, idx - drop if idx >= drop else -1)

    def sample_marks(self) -> Dict[str, int]:
        """Absolute sample ids marking 'now' in each latency list.  Pass
        them to :meth:`timing` to scope a measurement window; unlike raw
        list lengths they survive :meth:`trim_samples` (the ids count
        every sample ever recorded, including trimmed ones)."""
        return {
            "waits_since": self.waits_dropped + len(self.queue_waits),
            "ttfts_since": self.ttfts_dropped + len(self.ttfts),
        }

    def timing(
        self, waits_since: int = 0, ttfts_since: int = 0
    ) -> Dict[str, Dict[str, float]]:
        """Queue-wait and TTFT percentile summaries (ticks).  The two
        sample lists grow independently; callers scoping a measurement
        window record :meth:`sample_marks` beforehand and pass both
        values.  The arguments are *absolute* sample ids (0 = everything
        ever recorded): samples a trim dropped are simply no longer
        summarizable, but a pre-trim mark keeps addressing the same
        window instead of silently sliding forward."""
        return {
            "queue_wait_ticks": percentiles(
                self.queue_waits[max(0, waits_since - self.waits_dropped):]
            ),
            "ttft_ticks": percentiles(
                self.ttfts[max(0, ttfts_since - self.ttfts_dropped):]
            ),
        }

"""Shared serving-layer types: the request/slot dataclasses and the
engine-wide counter block.

The serving tier is three layers with explicit seams
(see ``docs/serving.md``):

- :class:`repro.serving.scheduler.RequestScheduler` — admission queue,
  continuous batching, preemption/requeue policy;
- :class:`repro.serving.cache_manager.KVCacheManager` — the paged
  refcounted allocator, copy-on-write, radix prefix cache and the
  optional cross-host prefix store;
- :class:`repro.serving.engine.ServeEngine` — the executor: jitted
  device dispatch and sampling, nothing else.

They communicate through the types here.  :class:`EngineStats` is ONE
shared mutable counter block all three layers write into: counters are
engine-wide facts (a preemption initiated by the allocator is rolled
back by the scheduler and observed by the benchmark), so splitting them
per-layer would force every consumer to re-aggregate.  Each field's
owner is annotated; :meth:`EngineStats.snapshot` is what the
``distributed-serve`` payload publishes to ``RESULTS.json``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional


@dataclass
class Request:
    uid: str
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    # emitting this token id finishes the request (it is kept in the
    # output); None disables.  Checked on device via the fused done mask.
    stop_token: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    done: bool = False
    # per-request sampling stream id (assigned at submit; scheduling- and
    # slot-independent so fused and grouped modes draw identical samples)
    sample_stream: int = field(default=0, compare=False, repr=False)
    # checkpoint-resume bookkeeping: number of leading output tokens that
    # were pre-seeded from a generation checkpoint (they are ALSO the
    # tail of the extended prompt).  Preemption rollback keeps them (they
    # were never emitted on this worker), and the original prompt is
    # recoverable as prompt[:len(prompt) - resume_base]
    resume_base: int = field(default=0, compare=False, repr=False)
    # admitted from a sealed prefill->decode handoff record: admission
    # runs the demand-driven hydration path (fetch exactly the chained
    # pages a prefill worker published) and counts a fallback when the
    # store cannot cover the prompt.  Decode-role schedulers accept only
    # these (see RequestScheduler).
    handoff: bool = field(default=False, compare=False, repr=False)
    # scheduler timing, in engine ticks (compare-excluded: two requests
    # with identical content are interchangeable to the batch).  -1 =
    # not yet reached.  queue wait = admit - submit; time-to-first-token
    # = first_token - submit.  A preempted request keeps submit_tick (its
    # latency clock does not reset) but re-earns admit/first-token.
    submit_tick: int = field(default=-1, compare=False, repr=False)
    admit_tick: int = field(default=-1, compare=False, repr=False)
    first_token_tick: int = field(default=-1, compare=False, repr=False)
    done_tick: int = field(default=-1, compare=False, repr=False)


@dataclass
class Slot:
    """One continuous-batching row: the scheduler owns the pool of these."""

    req: Optional[Request] = None
    pos: int = 0  # next cache position to write
    remaining_prompt: List[int] = field(default_factory=list)
    # admission order (monotonic): preemption picks the youngest = max seq
    seq: int = -1
    # prefix-cache stitch accounting for THIS admission (rolled back if
    # the slot is preempted, so counters never double-count a rerun)
    hit_tokens: int = 0
    hit_tokens_partial: int = 0  # sub-page tokens reused via CoW stitch
    skipped_tokens: int = 0
    # indices of THIS admission's latency samples in the scheduler's
    # queue_waits/ttfts lists (-1 = none recorded): preemption voids the
    # aborted attempt's samples so reruns are not double-counted
    wait_idx: int = -1
    ttft_idx: int = -1


@dataclass
class EngineStats:
    """Engine-wide counters.  Owner key: [X] executor, [S] scheduler,
    [C] cache manager, [L] serving lease (the ``distributed-serve``
    payload, writing through the engine's stat aliases).  Fields
    prefixed ``_`` are internal working state and stay out of
    :meth:`snapshot`."""

    # [X] dispatch accounting
    steps_executed: int = 0  # jitted decode calls (seed-compatible name)
    decode_dispatches: int = 0
    prefill_dispatches: int = 0
    dispatches: int = 0
    tokens_emitted: int = 0
    prompt_tokens_ingested: int = 0
    # [S] scheduling
    ticks: int = 0
    admissions: int = 0
    preemptions: int = 0
    tokens_discarded: int = 0  # preempted work (re-earned on rerun)
    # [C] paged pool
    pages_in_use: int = 0
    peak_pages: int = 0
    page_allocs: int = 0  # lifetime allocations (> n_pages => reuse)
    page_bytes: int = 0
    dense_cache_bytes: int = 0
    # [C] prefix sharing
    prefix_hit_tokens: int = 0  # prompt tokens found in the cache
    prompt_tokens_skipped: int = 0  # of those, never dispatched
    # sub-page reuse: tokens matched inside the first divergent page
    # (reused through a CoW copy of the partially-matched page) and the
    # number of such partial-page stitches performed
    prefix_hit_tokens_partial: int = 0
    cow_partial_stitches: int = 0
    pages_shared_peak: int = 0  # max pages with refcount > 1
    cow_copies: int = 0
    prefix_evictions: int = 0
    _shared_pages: int = 0  # pages with refcount > 1, kept O(1)
    # [C] cross-host prefix store
    prefix_store_pages_published: int = 0
    prefix_store_pages_hydrated: int = 0
    prefix_store_tokens_hydrated: int = 0
    # [X] speculative decoding.  One spec_dispatch is one fused verify
    # call (counted in decode_dispatches too — it replaces exactly one
    # decode dispatch); draft_dispatches are the draft model's own device
    # calls (catch-up prefill + per-draft-token decode), kept separate so
    # dispatches/token still describes the TARGET model.  Acceptance rate
    # is draft_tokens_accepted / draft_tokens_proposed; the headline
    # accepted_per_dispatch (accepted + bonus tokens per verify call) is
    # derived in snapshot().
    spec_dispatches: int = 0
    draft_dispatches: int = 0
    draft_tokens_proposed: int = 0
    draft_tokens_accepted: int = 0
    spec_tokens_emitted: int = 0  # all tokens emitted by verify dispatches
    # [L] elastic-lease robustness: spot-revocation notices observed by
    # this lease; in-flight request messages it made visible again while
    # draining; request messages it claimed that had been delivered
    # before (a requeued request resuming on a survivor); slice yields;
    # cold engine builds that found prior progress in the store (a lease
    # resuming after churn — prefix-store hydration is what makes these
    # cheap).
    revocation_notices: int = 0
    drain_requeued_requests: int = 0
    requests_resumed: int = 0
    lease_slices: int = 0
    lease_resumes: int = 0
    # [L] work-preserving recovery: generation checkpoints written at
    # drain (durable before the requeue ack); requests admitted FROM a
    # checkpoint on a surviving/replacement worker; already-emitted
    # tokens those resumes did not have to re-decode; checkpoints that
    # failed validation (missing/corrupt/hash-mismatch/prompt-mismatch)
    # and fell back down the ladder to prefix-hit or full replay.
    checkpoints_published: int = 0
    checkpoint_resumes: int = 0
    tokens_recovered: int = 0
    checkpoint_fallbacks: int = 0
    # [S] emitted tokens thrown away by preemption/drain (the subset of
    # tokens_discarded that was *decode* work — what checkpoints save)
    decode_tokens_discarded: int = 0
    # [C] store-path hardening: async publications that needed a retry
    # before landing, and fetched blobs rejected by the sha256 content
    # re-verification (counted as misses, never hydrated)
    publish_retries: int = 0
    prefix_store_hash_mismatches: int = 0
    # [C] hydration observability: store round-trips made to pull KV
    # pages into the pool (opportunistic + demand-driven) and the bytes
    # those fetches moved — handoff cost measured, not inferred.  The
    # publisher-side dedup counter mirrors AsyncPublisher.dedup_hits
    # (submits skipped because the identical page key was already
    # pending in its queue).
    hydration_fetch_ops: int = 0
    prefix_store_bytes_fetched: int = 0
    publish_dedup_hits: int = 0
    # [L]/[C] disaggregated prefill/decode: sealed handoff records a
    # prefill lease enqueued; handoff records a decode engine admitted
    # via the guaranteed-hit demand hydration path; admissions where the
    # store lied (chain pages missing/corrupt) and the slot fell back
    # down the PR 8 ladder to prefix-hit/full replay; handoff records
    # rejected at the seal/consistency check before admission.
    handoffs_published: int = 0
    handoffs_admitted: int = 0
    handoff_fallbacks: int = 0
    handoff_seal_rejects: int = 0
    # per-demand-hydration store fetch counts (deterministic round-trip
    # samples; summarized as the "hydration_ticks" percentile block)
    _hydration_ticks: List = field(default_factory=list)

    def snapshot(self) -> Dict[str, int]:
        """Every public counter as a plain dict (RESULTS.json payload),
        plus derived speculative-decoding rates."""
        snap = {
            f.name: getattr(self, f.name)
            for f in fields(self)
            if not f.name.startswith("_")
        }
        snap["accepted_per_dispatch"] = round(
            self.spec_tokens_emitted / self.spec_dispatches, 4
        ) if self.spec_dispatches else 0.0
        snap["hydration_ticks"] = percentiles(self._hydration_ticks)
        return snap


def percentiles(samples: List[Optional[int]]) -> Dict[str, float]:
    """Mean/p50/p90/max summary of tick-denominated latency samples.
    ``None`` entries (samples voided by preemption rollback — kept in
    place so windowing by list index stays stable) are excluded."""
    s = sorted(x for x in samples if x is not None)
    if not s:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0,
                "max": 0.0}
    n = len(s)
    return {
        "n": n,
        "mean": round(sum(s) / n, 3),
        # nearest-rank percentiles: index ceil(q*n) - 1
        "p50": float(s[(n - 1) // 2]),
        "p90": float(s[(9 * n - 1) // 10]),
        "p99": float(s[(99 * n - 1) // 100]),
        "max": float(s[-1]),
    }

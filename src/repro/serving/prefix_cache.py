"""Shared-prefix radix cache over the paged KV pool.

The serving engine's paged allocator (PR 2) is strictly per-slot: a
batch of N requests sharing a system prompt prefills and stores the
same KV pages N times.  This module is the host-side index that turns
those pages into shared infrastructure — the same amortize-the-common-
cost move the paper makes for clusters and Juve et al. make for
workflow data: store shared state once, reference it many times.

Structure: a radix tree keyed on *token-aligned page-size chunks*.
Each node is one full KV page — ``page_size`` consecutive prompt
tokens starting at a page-aligned offset — and holds the physical page
id that backs that chunk in the engine's pool.  A path from the root
spells out a prompt prefix at page granularity, so matching a new
prompt is a walk down the tree and every matched node is a page the
new request can reference instead of recomputing.

Ownership contract (the cache is an *index*, not the allocator):

- The cache never allocates or frees pages.  The engine's refcounted
  allocator owns page lifetime; a node's page carries one refcount held
  *by the cache* (taken when ``insert`` adopts the page, released when
  ``evict`` removes the node).  Active slots referencing the same page
  hold their own refcounts on top.
- Only **full** chunks are indexed: a page is inserted only once every
  one of its ``page_size`` positions holds a real prompt token, so a
  matched page can be referenced as-is.  Partial tail pages stay
  private to their slot.
- Matching is not limited to whole pages: ``match_partial`` also
  reports the longest common *token* prefix between the prompt's first
  divergent chunk and the cached chunks branching at that point, so
  the engine can copy-on-write the partially-matched page and resume
  prefill from a mid-page offset (sub-page prefix reuse).
- Eviction removes LRU **leaves** whose page the cache alone still
  references (``ref_of(page) == 1``): an interior node can only be
  evicted after its subtree, and a page some active slot still maps
  stays resident no matter how cold it looks.

The tree never touches device memory; stitching a hit into a slot's
page table and copy-on-write of shared pages are the engine's job
(`repro.serving.engine`).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

Chunk = Tuple[int, ...]


class RadixNode:
    """One cached KV page: ``key`` = its page_size tokens, ``page`` = the
    physical page id in the engine's pool holding their K/V."""

    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Chunk, page: int, parent: "RadixNode | None"):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Chunk, RadixNode] = {}
        self.last_used = 0


class PrefixCache:
    """Radix index of cached prompt prefixes at page granularity."""

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        self.page_size = int(page_size)
        self.root = RadixNode((), -1, None)
        self.n_nodes = 0
        self._clock = 0  # LRU timestamp source, bumped per operation

    # ------------------------------------------------------------ helpers
    def _chunks(self, tokens: Sequence[int]) -> List[Chunk]:
        """Full page-size chunks of ``tokens`` (partial tail dropped)."""
        ps = self.page_size
        end = len(tokens) - len(tokens) % ps
        return [tuple(tokens[i : i + ps]) for i in range(0, end, ps)]

    # ------------------------------------------------------------- lookup
    def match(self, tokens: Sequence[int]) -> List[RadixNode]:
        """Longest cached prefix of ``tokens``, as the node path from the
        root.  ``len(path) * page_size`` tokens are covered; the caller
        stitches ``[n.page for n in path]`` into a slot's page table."""
        self._clock += 1
        node, path = self.root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_used = self._clock
            path.append(child)
            node = child
        return path

    def match_partial(
        self, tokens: Sequence[int]
    ) -> Tuple[List[RadixNode], "RadixNode | None", int]:
        """Longest cached prefix of ``tokens`` at *token* granularity.

        Returns ``(path, partial, n_partial)``: ``path`` is the full-page
        node path (exactly :meth:`match`), and ``partial`` — when not
        None — is the child of the last matched node whose key shares
        the longest common token prefix (``n_partial >= 1`` tokens) with
        the prompt's first divergent chunk.  The caller cannot reference
        ``partial.page`` as-is (its tail belongs to another prompt); it
        copy-on-writes the page and resumes prefill mid-page.
        """
        path = self.match(tokens)
        node = path[-1] if path else self.root
        start = len(path) * self.page_size
        rest = tokens[start : start + self.page_size]
        best, best_len = None, 0
        if len(rest) > 0:
            for key, child in node.children.items():
                n = 0
                for a, b in zip(key, rest):
                    if a != b:
                        break
                    n += 1
                if n > best_len:
                    best, best_len = child, n
        if best is not None:
            best.last_used = self._clock
        return path, best, best_len

    # ------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> List[int]:
        """Index ``pages[j]`` as holding chunk ``j`` of ``tokens``.

        Chunks already present keep their existing page (first writer
        wins — a concurrent slot that prefilled the same prefix privately
        simply fails to donate; its copy is freed when it finishes).
        Returns the page ids newly adopted by the cache; the caller must
        add the cache's refcount to exactly those.
        """
        self._clock += 1
        node, adopted = self.root, []
        for chunk, pid in zip(self._chunks(tokens), pages):
            child = node.children.get(chunk)
            if child is None:
                child = RadixNode(chunk, int(pid), node)
                node.children[chunk] = child
                adopted.append(int(pid))
                self.n_nodes += 1
            child.last_used = self._clock
            node = child
        return adopted

    # ------------------------------------------------------------ evict
    def _evictable_leaves(self, ref_of: Callable[[int], int]):
        """DFS over leaves whose page only the cache references
        (``ref_of(page) == 1``) — the ONE definition of evictability,
        shared by :meth:`evict` and :meth:`evictable` so the admission
        gate can never disagree with what eviction can actually
        reclaim."""
        stack = [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                if c.children:
                    stack.append(c)
                elif ref_of(c.page) == 1:
                    yield c

    def evict(self, want: int, ref_of: Callable[[int], int]) -> List[int]:
        """Drop up to ``want`` LRU leaf nodes whose page only the cache
        still references (``ref_of(page) == 1``) and return their page
        ids; the caller releases the cache's refcount on each (freeing
        the page).  Pages mapped by any active slot are never returned.
        """
        out: List[int] = []
        while len(out) < want:
            # one DFS collects every currently evictable leaf; evicting a
            # whole LRU batch per pass keeps bulk recovery O(tree) per
            # exposed level instead of O(tree) per page
            victims = list(self._evictable_leaves(ref_of))
            if not victims:
                break  # nothing evictable: every leaf is in active use
            victims.sort(key=lambda v: v.last_used)
            for v in victims[: want - len(out)]:
                assert v.parent is not None
                del v.parent.children[v.key]
                self.n_nodes -= 1
                out.append(v.page)
        return out

    def evictable(self, ref_of: Callable[[int], int]) -> bool:
        """True when at least one leaf's page only the cache references
        — i.e. :meth:`evict` could reclaim a page right now.  Used by
        admission control: admitting a request when the pool has neither
        a free nor an evictable page can only yield straight back to the
        queue."""
        return next(self._evictable_leaves(ref_of), None) is not None

    # ------------------------------------------------------------ debug
    def pages(self) -> List[int]:
        """Every page id currently indexed (tests / accounting)."""
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            for c in n.children.values():
                out.append(c.page)
                stack.append(c)
        return out

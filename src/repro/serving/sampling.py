"""On-device token sampling for the serving engine.

The seed engine round-tripped full ``(B, vocab)`` logits to host every
token and sampled with numpy.  Here sampling is fused into the same
jitted dispatch as the decode/prefill step, so only ``B`` int32 token
ids cross the host boundary per tick.

Determinism contract: each request owns a sampling *stream* (an integer
assigned at submit time) and each emitted token an integer *step* (the
number of tokens already generated for that request).  The per-token key
is ``fold_in(fold_in(PRNGKey(base_seed), stream), step)`` — independent
of batch placement, slot assignment, and dispatch scheduling, so the
fused single-dispatch engine and the legacy per-position-group engine
draw token-for-token identical samples.

Temperature sampling uses the Gumbel-max trick on max-subtracted logits:
``argmax((logits - max(logits)) / T + gumbel)`` is an exact draw from
``softmax(logits / T)`` and never exponentiates raw logits (the seed's
host sampler overflowed ``np.exp(logits / T)`` for large logits).

Stop-token handling is on-device too: the fused dispatches take per-row
``stops`` (stop token id, ``-1`` = none) and ``max_news`` vectors and
return a *done mask* next to the sampled ids.  The engine finalizes rows
straight off that mask — the host never re-derives the stop condition
from the token stream, and a finished row is parked (and its cache pages
freed in paged mode) before the next dispatch instead of being filtered
after the fact.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def sample_tokens(
    logits: jax.Array,  # (B, V) unnormalized
    temps: jax.Array,  # (B,) 0 = greedy
    streams: jax.Array,  # (B,) per-request sampling stream ids
    steps: jax.Array,  # (B,) tokens already generated per request
    *,
    base_seed: int,
) -> jax.Array:
    """Sample one token per row; greedy rows take a plain argmax."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def row_key(stream, step):
        key = jax.random.PRNGKey(base_seed)
        return jax.random.fold_in(jax.random.fold_in(key, stream), step)

    keys = jax.vmap(row_key)(streams, steps)
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, lg.shape[-1:], jnp.float32))(keys)
    safe_t = jnp.where(temps > 0, temps, 1.0).astype(jnp.float32)
    z = (lg - jnp.max(lg, axis=-1, keepdims=True)) / safe_t[:, None] + gumbel
    sampled = jnp.argmax(z, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def done_mask(
    nxt: jax.Array,  # (B,) sampled token ids
    steps: jax.Array,  # (B,) tokens already generated (before this one)
    stops: jax.Array,  # (B,) stop token id, -1 = no stop token
    max_news: jax.Array,  # (B,) per-request new-token budget
) -> jax.Array:
    """Per-row request-finished mask, computed inside the dispatch."""
    hit_stop = jnp.logical_and(stops >= 0, nxt == stops)
    return jnp.logical_or(hit_stop, steps + 1 >= max_news)


def sample_tokens_chunk(
    logits: jax.Array,  # (B, T, V) unnormalized, one position per draft slot
    temps: jax.Array,  # (B,)
    streams: jax.Array,  # (B,)
    steps: jax.Array,  # (B,) tokens already generated BEFORE this chunk
    *,
    base_seed: int,
) -> jax.Array:
    """Per-position sampling for the speculative verify dispatch.

    Position ``t`` of row ``b`` draws from the stream key ``(streams[b],
    steps[b] + t)`` — the exact key sequential decoding would use for its
    ``steps[b] + t``-th token.  Because the key depends only on (stream,
    token index) and each position's draw is elementwise independent, the
    verified tokens are byte-identical to what ``T`` non-speculative
    decode dispatches would have sampled, for greedy AND temperature rows
    alike (no rejection-resampling correction is needed)."""
    B, T, V = logits.shape
    t_idx = jnp.arange(T, dtype=jnp.int32)
    flat = sample_tokens(
        logits.reshape(B * T, V),
        jnp.broadcast_to(temps[:, None], (B, T)).reshape(-1),
        jnp.broadcast_to(streams[:, None], (B, T)).reshape(-1),
        (steps[:, None] + t_idx[None, :]).reshape(-1),
        base_seed=base_seed,
    )
    return flat.reshape(B, T)


def make_verify_step(model, base_seed: int) -> Callable:
    """Build the speculative-verify jit target: one fused chunk-extend
    dispatch scores all ``k+1`` positions (last accepted token + ``k``
    draft tokens), samples the target token at every position, and
    applies the longest-consistent-run acceptance rule on device.

    Inputs per row: ``tokens[b] = [x0, d1 .. dm, pad...]`` where ``x0``
    is the last accepted token and ``d1..dm`` the proposer's drafts
    (``lengths[b] = 1 + m``; ``lengths[b] = 0`` parks the row).  The
    target token at position ``t`` is what non-speculative decode would
    emit after consuming ``tokens[b, :t+1]``; draft ``d_{t+1}`` is
    *consistent* iff it equals that target.  The row emits
    ``tgt[b, :n_emit[b]]``: the accepted run plus the bonus token from
    the first inconsistent (or last) position, truncated at the first
    position whose emitted token finishes the request (stop token or
    new-token budget) — sequential decode would never have sampled past
    it.  Rejected positions' KV stays in the cache past the rewound
    write frontier, where the causal mask excludes it, until the cache
    manager drops/overwrites it.

    Returns ``(tgt (B, T), n_emit (B,), done (B,), cache)``."""
    vocab = model.cfg.vocab_size

    def step(params, cache, tokens, offsets, lengths, temps, streams, steps,
             stops, max_news):
        logits, cache = model.verify_chunk(params, cache, tokens, offsets, lengths)
        B, T = tokens.shape
        t_idx = jnp.arange(T, dtype=jnp.int32)
        tgt = sample_tokens_chunk(
            logits[:, :, :vocab], temps, streams, steps, base_seed=base_seed
        )
        # longest greedy-consistent run: draft t+1 survives iff it exists
        # (inside lengths) and every draft before it survived
        is_draft = t_idx[None, 1:] < lengths[:, None]
        match = jnp.logical_and(tokens[:, 1:] == tgt[:, :-1], is_draft)
        n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        emit_cap = n_acc + 1  # accepted run + the bonus target token
        # per-position done: emitting tgt[b, t] is the request's
        # (steps[b] + t + 1)-th token — budget and stop checks per slot
        hit_stop = jnp.logical_and(stops[:, None] >= 0, tgt == stops[:, None])
        over = steps[:, None] + t_idx[None, :] + 1 >= max_news[:, None]
        pos_done = jnp.logical_and(
            jnp.logical_or(hit_stop, over), t_idx[None, :] < emit_cap[:, None]
        )
        any_done = jnp.any(pos_done, axis=1)
        first_done = jnp.argmax(pos_done, axis=1)
        n_emit = jnp.where(any_done, first_done + 1, emit_cap)
        n_emit = jnp.where(lengths > 0, n_emit, 0).astype(jnp.int32)
        return tgt, n_emit, any_done, cache

    return step


def make_decode_step(model, base_seed: int, on_device: bool) -> Callable:
    """Build the engine's jit target: vectorized-position decode, with
    sampling + stop-token done mask fused on-device (default) or raw
    logits returned for the host-sampling fallback."""
    vocab = model.cfg.vocab_size

    if not on_device:

        def logits_step(params, cache, tokens, pos):
            logits, cache = model.decode_step(params, cache, tokens, pos)
            return logits[:, 0, :vocab], cache

        return logits_step

    def step(params, cache, tokens, pos, temps, streams, steps, stops, max_news):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        nxt = sample_tokens(
            logits[:, 0, :vocab], temps, streams, steps, base_seed=base_seed
        )
        return nxt, done_mask(nxt, steps, stops, max_news), cache

    return step


def make_prefill_step(model, base_seed: int, on_device: bool) -> Callable:
    """Build the engine's fused chunked-prefill jit target (last-token
    logits sampled on-device with the done mask, or returned raw for the
    host fallback)."""
    vocab = model.cfg.vocab_size

    if not on_device:

        def logits_step(params, cache, tokens, offsets, lengths):
            logits, cache = model.prefill_chunk(params, cache, tokens, offsets, lengths)
            return logits[:, :vocab], cache

        return logits_step

    def step(params, cache, tokens, offsets, lengths, temps, streams, steps,
             stops, max_news):
        logits, cache = model.prefill_chunk(params, cache, tokens, offsets, lengths)
        nxt = sample_tokens(logits[:, :vocab], temps, streams, steps, base_seed=base_seed)
        return nxt, done_mask(nxt, steps, stops, max_news), cache

    return step

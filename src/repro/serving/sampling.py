"""On-device token sampling for the serving engine.

The seed engine round-tripped full ``(B, vocab)`` logits to host every
token and sampled with numpy.  Here sampling is fused into the same
jitted dispatch as the decode/prefill step, so only ``B`` int32 token
ids cross the host boundary per tick.

Determinism contract: each request owns a sampling *stream* (an integer
assigned at submit time) and each emitted token an integer *step* (the
number of tokens already generated for that request).  The per-token key
is ``fold_in(fold_in(PRNGKey(base_seed), stream), step)`` — independent
of batch placement, slot assignment, and dispatch scheduling, so the
fused single-dispatch engine and the legacy per-position-group engine
draw token-for-token identical samples.

Temperature sampling uses the Gumbel-max trick on max-subtracted logits:
``argmax((logits - max(logits)) / T + gumbel)`` is an exact draw from
``softmax(logits / T)`` and never exponentiates raw logits (the seed's
host sampler overflowed ``np.exp(logits / T)`` for large logits).

Stop-token handling is on-device too: the fused dispatches take per-row
``stops`` (stop token id, ``-1`` = none) and ``max_news`` vectors and
return a *done mask* next to the sampled ids.  The engine finalizes rows
straight off that mask — the host never re-derives the stop condition
from the token stream, and a finished row is parked (and its cache pages
freed in paged mode) before the next dispatch instead of being filtered
after the fact.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def sample_tokens(
    logits: jax.Array,  # (B, V) unnormalized
    temps: jax.Array,  # (B,) 0 = greedy
    streams: jax.Array,  # (B,) per-request sampling stream ids
    steps: jax.Array,  # (B,) tokens already generated per request
    *,
    base_seed: int,
) -> jax.Array:
    """Sample one token per row; greedy rows take a plain argmax."""
    lg = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def row_key(stream, step):
        key = jax.random.PRNGKey(base_seed)
        return jax.random.fold_in(jax.random.fold_in(key, stream), step)

    keys = jax.vmap(row_key)(streams, steps)
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, lg.shape[-1:], jnp.float32))(keys)
    safe_t = jnp.where(temps > 0, temps, 1.0).astype(jnp.float32)
    z = (lg - jnp.max(lg, axis=-1, keepdims=True)) / safe_t[:, None] + gumbel
    sampled = jnp.argmax(z, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def done_mask(
    nxt: jax.Array,  # (B,) sampled token ids
    steps: jax.Array,  # (B,) tokens already generated (before this one)
    stops: jax.Array,  # (B,) stop token id, -1 = no stop token
    max_news: jax.Array,  # (B,) per-request new-token budget
) -> jax.Array:
    """Per-row request-finished mask, computed inside the dispatch."""
    hit_stop = jnp.logical_and(stops >= 0, nxt == stops)
    return jnp.logical_or(hit_stop, steps + 1 >= max_news)


def make_decode_step(model, base_seed: int, on_device: bool) -> Callable:
    """Build the engine's jit target: vectorized-position decode, with
    sampling + stop-token done mask fused on-device (default) or raw
    logits returned for the host-sampling fallback."""
    vocab = model.cfg.vocab_size

    if not on_device:

        def logits_step(params, cache, tokens, pos):
            logits, cache = model.decode_step(params, cache, tokens, pos)
            return logits[:, 0, :vocab], cache

        return logits_step

    def step(params, cache, tokens, pos, temps, streams, steps, stops, max_news):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        nxt = sample_tokens(
            logits[:, 0, :vocab], temps, streams, steps, base_seed=base_seed
        )
        return nxt, done_mask(nxt, steps, stops, max_news), cache

    return step


def make_prefill_step(model, base_seed: int, on_device: bool) -> Callable:
    """Build the engine's fused chunked-prefill jit target (last-token
    logits sampled on-device with the done mask, or returned raw for the
    host fallback)."""
    vocab = model.cfg.vocab_size

    if not on_device:

        def logits_step(params, cache, tokens, offsets, lengths):
            logits, cache = model.prefill_chunk(params, cache, tokens, offsets, lengths)
            return logits[:, :vocab], cache

        return logits_step

    def step(params, cache, tokens, offsets, lengths, temps, streams, steps,
             stops, max_news):
        logits, cache = model.prefill_chunk(params, cache, tokens, offsets, lengths)
        nxt = sample_tokens(logits[:, :vocab], temps, streams, steps, base_seed=base_seed)
        return nxt, done_mask(nxt, steps, stops, max_news), cache

    return step

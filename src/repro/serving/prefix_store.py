"""Cross-host prefix page store — warm KV prefixes through the object
store.

The radix prefix cache (``repro.serving.prefix_cache``) only helps
requests landing on the *same* engine.  A fleet of queue-fed serving
workers (the whole point of the distributed-something tier) sees the
same system prompts on every host, and each host re-prefills them from
scratch.  This module applies the paper's data-sharing-via-object-store
move to KV state: a completed prompt's full pages are content-hashed
and published to the shared :class:`~repro.core.storage.ObjectStore`,
so a worker admitting a cold request can *hydrate* its radix cache from
pages another worker computed instead of dispatching prefill.

Key scheme (chained content hash):

- the chain root is ``sha256(namespace)`` — ``namespace`` must pin
  everything page bytes depend on: architecture, parameter identity
  (run name / init seed) and ``page_size``.  Two engines with the same
  namespace MUST hold byte-identical weights; nothing else is checked.
- chunk ``j`` of a prompt (its ``page_size`` token-aligned tokens) is
  keyed by ``sha256(parent_key || int64 tokens of chunk j)`` where
  ``parent_key`` is chunk ``j-1``'s key.  A chunk's key therefore
  commits to the *entire* prefix, exactly like a radix-tree path, so
  hydration is a walk: fetch chunk 0's key, then its child, until a
  miss.

Page payloads are the page's slice of every pool leaf (``k_pages`` /
``v_pages``, or the MLA ``kv_pages`` latent), ``npz``-serialized.  K/V
of a token depends only on the token and its absolute position, and
cached prefixes are position-0-aligned, so a hydrated page is
byte-identical to what a local prefill would have written (same dtype,
deterministic math).

Consistency caveats (documented in ``docs/serving.md``): publication is
atomic per page (``ObjectStore.put_bytes`` is temp-file + rename) and
last-writer-wins — concurrent publishers write identical bytes, so the
race is benign.  A page is published only once fully written and never
mutated afterwards (copy-on-write privatizes shared pages before any
write), so readers can never observe a half-warm page.  Store-side
eviction is age-based: :meth:`PrefixStore.sweep` deletes pages whose
mtime is older than a TTL (the monitor runs it at teardown when
``DSConfig.kvprefix_ttl_seconds`` is set); a fetched page is trusted to
match its key (shape/dtype are verified, token content is not
re-derived), and a sweep racing a fetch is a plain miss.
"""

from __future__ import annotations

import hashlib
import io
import logging
import queue
import threading
import time
import zipfile
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.worker import backoff_delay

_LOG = logging.getLogger(__name__)


def _retry_transient(op, *, key: str, attempts: int = 4,
                     base: float = 0.01, cap: float = 0.25):
    """Retry a store operation through *transient* faults
    (``ConnectionError`` — what chaos ``flaky_storage`` and a real S3
    SDK raise for retryable errors) with capped backoff deterministically
    jittered by ``key``.  ``FileNotFoundError`` (a plain miss) and every
    other error propagate immediately; a ``ConnectionError`` that
    survives all attempts propagates too, so callers keep their
    miss-vs-crash decision."""
    for attempt in range(1, attempts + 1):
        try:
            return op()
        except ConnectionError:
            if attempt == attempts:
                raise
            time.sleep(backoff_delay(base, attempt, cap=cap, key=key))


class PrefixStore:
    """Content-addressed KV prefix pages over an object store."""

    def __init__(self, store, namespace: str, key_prefix: str = "kvprefix"):
        self.store = store
        self.namespace = str(namespace)
        self.key_prefix = key_prefix.rstrip("/")
        self._root = hashlib.sha256(self.namespace.encode("utf-8")).hexdigest()
        # fetched blobs whose embedded sha256 content digest did not match
        # (bit flips, wrong-content writes): counted misses, never hydrated
        self.hash_mismatches = 0
        # hydration observability: store round-trips attempted by fetch()
        # and the blob bytes they actually moved (misses move 0)
        self.fetch_ops = 0
        self.bytes_fetched = 0

    # ------------------------------------------------------------- keys
    def root_key(self) -> str:
        return self._root

    def child_key(self, parent_key: str, chunk: Sequence[int]) -> str:
        h = hashlib.sha256()
        h.update(parent_key.encode("ascii"))
        h.update(np.asarray(chunk, np.int64).tobytes())
        return h.hexdigest()

    def _object_key(self, page_key: str) -> str:
        # shard the flat hash space one level deep, S3-style
        return f"{self.key_prefix}/{page_key[:2]}/{page_key}"

    # ------------------------------------------------------- page payloads
    @staticmethod
    def content_digest(page_key: str, arrays: Dict[str, np.ndarray]) -> str:
        """sha256 over the page key and every leaf's name/dtype/shape/bytes
        — binds a blob's *content* to the key it was published under, so a
        bit-flipped or wrong-content object can be rejected at fetch."""
        h = hashlib.sha256()
        h.update(page_key.encode("ascii"))
        for name in sorted(arrays):
            a = np.ascontiguousarray(arrays[name])
            h.update(name.encode("utf-8"))
            h.update(str(a.dtype).encode("ascii"))
            h.update(repr(a.shape).encode("ascii"))
            h.update(a.tobytes())
        return h.hexdigest()

    @staticmethod
    def pack(arrays: Dict[str, np.ndarray], page_key: Optional[str] = None) -> bytes:
        bio = io.BytesIO()
        if page_key is not None:
            arrays = dict(
                arrays,
                __digest__=np.array(
                    PrefixStore.content_digest(page_key, arrays)
                ),
            )
        np.savez(bio, **arrays)
        return bio.getvalue()

    @staticmethod
    def unpack(blob: bytes) -> Dict[str, np.ndarray]:
        with np.load(io.BytesIO(blob)) as z:
            return {k: z[k] for k in z.files}

    # ------------------------------------------------------------ protocol
    def exists(self, page_key: str) -> bool:
        key = self._object_key(page_key)
        return _retry_transient(lambda: self.store.exists(key), key=key)

    def publish(self, page_key: str, arrays: Dict[str, np.ndarray]) -> None:
        """Write one page's leaves unconditionally (atomic put), with the
        content digest embedded.  Callers probe :meth:`exists` first to
        skip redundant writes; a lost race is a benign last-writer-wins
        overwrite of identical bytes."""
        self.store.put_bytes(  # dslint: disable=R1(every caller retries this put: AsyncPublisher._publish_with_retry re-attempts with content-keyed backoff, and the only synchronous caller is that retry loop)
            self._object_key(page_key), self.pack(arrays, page_key=page_key)
        )

    def fetch(
        self, page_key: str, like: Dict[str, np.ndarray]
    ) -> Optional[Dict[str, np.ndarray]]:
        """Read one page's leaves, or None on miss/incompatibility.

        ``like`` maps leaf name -> an array of the expected per-page
        shape/dtype; a blob whose leaves do not match exactly (different
        arch/config published under a colliding namespace) is treated as
        a miss rather than corrupting the pool.
        """
        key = self._object_key(page_key)
        self.fetch_ops += 1
        try:
            # transient faults are retried first: before PR 10 a chaos
            # flaky-storage ConnectionError (an OSError subclass) fell
            # straight into the except below and was miscounted as a
            # miss, forcing a silent re-prefill of a page that was there
            blob = _retry_transient(
                lambda: self.store.get_bytes(key), key=key
            )
        except (FileNotFoundError, OSError):
            # covers both a plain miss and the exists/read race against
            # an operator sweeping the key prefix: hydration is
            # best-effort, so a swept page is a miss, never a crash
            return None
        self.bytes_fetched += len(blob)
        try:
            arrays = self.unpack(blob)
        except (ValueError, OSError, zipfile.BadZipFile):
            # BadZipFile is NOT a ValueError/OSError subclass: np.load
            # raises it for a PK-magic blob whose zip structure is
            # truncated/mangled (e.g. a partially swept object)
            return None  # truncated/corrupt blob: miss, not a crash
        # content re-verification: the blob must carry a digest binding
        # its bytes to THIS page key.  Absent or mismatched (bit flip,
        # wrong-content overwrite, blob copied under the wrong key) is a
        # counted miss — a poisoned page must never enter the pool
        digest = arrays.pop("__digest__", None)
        if digest is None or str(digest[()]) != self.content_digest(
            page_key, arrays
        ):
            self.hash_mismatches += 1
            return None
        if set(arrays) != set(like):
            return None
        for name, ref in like.items():
            if arrays[name].shape != ref.shape or arrays[name].dtype != ref.dtype:
                return None
        return arrays

    # ------------------------------------------------------------- eviction
    def publisher(self) -> "AsyncPublisher":
        """A background publisher bound to this store (one per call)."""
        return AsyncPublisher(self)

    def _pin_key(self, page_key: str) -> str:
        # pins live OUTSIDE key_prefix/ (string-prefix listing of
        # "kvprefix/" never sees "kvprefix-pins/..."), so a sweep's page
        # walk and its pin walk are disjoint
        return f"{self.key_prefix}-pins/{page_key[:2]}/{page_key}"

    def pin(self, page_key: str) -> None:
        """Refresh a page's sweep protection: an empty marker object whose
        mtime restarts the page's TTL clock.  A prefill worker pins every
        chain key of a handoff it enqueues, so a TTL sweep running
        between handoff-enqueue and the decode worker's fetch cannot
        delete the very pages the handoff points at.  Pins are never
        explicitly removed — they expire by the same TTL (an unpin API
        would race other workers pinning the same shared prefix), and a
        stale marker is deleted by the sweep that observes it expired."""
        key = self._pin_key(page_key)
        _retry_transient(lambda: self.store.put_bytes(key, b""), key=key)

    def sweep(self, ttl_s: float, now: Optional[float] = None) -> int:
        """Delete every page under ``key_prefix/`` older than ``ttl_s``
        seconds (by object mtime) and return the count.

        This is the store-side TTL eviction for ``kvprefix/``: published
        pages are immutable and content-addressed, so deleting a cold
        one is always safe — the worst case is a future request
        re-prefilling and re-publishing it.  A sweep racing a hydration
        is the documented exists/read race: :meth:`fetch` treats the
        vanished object as a miss.  ``ttl_s=0`` clears the whole prefix.
        ``now`` defaults to wall-clock time (object mtimes are wall
        clock even under a virtual-clock harness).

        Pages with a *fresh* pin marker (see :meth:`pin`) are exempt even
        when the page object itself is expired: a handoff in flight keeps
        its chain alive by marker mtime, not by republishing page bytes.
        Expired markers are swept alongside the pages (and not counted in
        the return value, which is pages only)."""
        if now is None:
            now = time.time()
        swept = 0
        # pin walk first: a fresh marker protects its page hash from this
        # sweep; an expired marker is itself garbage-collected here
        pinned = set()
        for info in list(self.store.list(self.key_prefix + "-pins/")):
            if now - info.mtime < ttl_s:
                pinned.add(info.key.rsplit("/", 1)[-1])
            else:
                self.store.delete(info.key)
        # one listing walk total: list() already carries each object's
        # mtime, and expired pages are deleted individually (delete_prefix
        # would re-walk the whole store root per page)
        for info in list(self.store.list(self.key_prefix + "/")):
            if info.key.rsplit("/", 1)[-1] in pinned:
                continue
            if now - info.mtime >= ttl_s:
                self.store.delete(info.key)
                swept += 1
        return swept


class AsyncPublisher:
    """Single-worker background queue in front of :meth:`PrefixStore.publish`.

    The engine's publish path used to serialize + write each page to the
    object store inline with the tick loop — per-page latency the whole
    batch's decode dispatch waited on.  This moves only the *write*
    (npz pack + ``put_bytes``) off the hot path; everything that affects
    engine state or counters stays synchronous at submit time:

    - the caller pulls the page's device arrays to host BEFORE submitting
      (a pool page can be evicted and reissued to another slot while the
      write is still queued — the snapshot, not the live page, is what
      gets published);
    - the ``exists()`` probe, the published-key memo, and the
      ``prefix_store_pages_published`` counter all stay on the submit
      path, so counter values are deterministic and independent of
      worker-thread progress.

    Writes are retried: a failed put backs off (capped exponential,
    deterministically jittered by the page's content key — the same
    ``backoff_delay`` discipline the task worker uses for queue
    redelivery) and is re-attempted in place up to ``max_attempts``
    times before being dropped (the page simply stays cold for other
    workers — the same contract as a lost last-writer-wins race).
    ``retries`` counts re-attempts that were needed; ``errors`` counts
    pages dropped after the final attempt.  Callers must :meth:`flush`
    at natural drain points (engine drain, lease end, teardown) so
    published pages are durable before the process exits or counters
    are compared across engines.  The worker thread is daemonized and
    started lazily; after :meth:`close` the publisher can be reused (a
    new submit restarts the worker)."""

    _STOP = object()

    def __init__(
        self,
        store: PrefixStore,
        *,
        max_attempts: int = 4,
        retry_base: float = 0.02,
        retry_cap: float = 0.5,
    ):
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.store = store
        self.max_attempts = int(max_attempts)
        self.retry_base = float(retry_base)
        self.retry_cap = float(retry_cap)
        self._q: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.errors = 0
        self.retries = 0
        # content keys submitted but not yet written: a second submit of
        # the same key while the first is still queued is a guaranteed
        # byte-identical duplicate (keys are content hashes), so it is
        # dropped before any snapshot/pack work — handoff publishes the
        # same chain pages a completed-prompt publish may already have
        # enqueued
        self._pending: set = set()
        self.dedup_hits = 0

    def submit(self, page_key: str, arrays) -> bool:
        """Enqueue one page write.  ``arrays`` is either a host-resident
        snapshot dict or a zero-arg callable producing one; a callable is
        invoked synchronously HERE (submit time — the pool page may be
        evicted and reissued before the queued write lands), but only
        when the key is not already pending: a deduplicated submit skips
        the snapshot and pack entirely.  Returns False (and counts a
        ``dedup_hits``) when the identical key was already queued."""
        with self._lock:
            if page_key in self._pending:
                self.dedup_hits += 1
                return False
            self._pending.add(page_key)
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._run, name="kvprefix-publisher", daemon=True
                )
                self._thread.start()
        try:
            if callable(arrays):
                arrays = arrays()
        except BaseException:
            with self._lock:
                self._pending.discard(page_key)
            raise
        self._q.put((page_key, arrays))
        return True

    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is self._STOP:
                    return
                page_key, arrays = item
                try:
                    self._publish_with_retry(page_key, arrays)
                finally:
                    with self._lock:
                        self._pending.discard(page_key)
            except Exception:  # noqa: BLE001 - best-effort, never kill the worker
                self.errors += 1
                _LOG.exception("async prefix-store publish failed (dropped)")
            finally:
                self._q.task_done()

    def _publish_with_retry(
        self, page_key: str, arrays: Dict[str, np.ndarray]
    ) -> None:
        for attempt in range(1, self.max_attempts + 1):
            try:
                self.store.publish(page_key, arrays)
                return
            except Exception:  # noqa: BLE001 - transient store faults expected
                if attempt == self.max_attempts:
                    self.errors += 1
                    _LOG.exception(
                        "async prefix-store publish of %s failed after "
                        "%d attempts (dropped)", page_key, attempt,
                    )
                    return
                self.retries += 1
                time.sleep(
                    backoff_delay(
                        self.retry_base, attempt,
                        cap=self.retry_cap, key=page_key,
                    )
                )

    def flush(self) -> None:
        """Block until every submitted write has been attempted."""
        self._q.join()

    def close(self) -> None:
        """Flush, then stop the worker thread (restartable)."""
        self.flush()
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                self._q.put(self._STOP)
                self._thread.join()
            self._thread = None

"""KVCacheManager — the serving tier's cache layer.

Owns everything about *where KV state lives*: the device cache pytree
(dense per-slot reservation or pooled pages + page table), the host-side
free-list allocator with per-page refcounts, copy-on-write of shared
pages, adaptive pool sizing, the radix prefix cache
(``repro.serving.prefix_cache``) and the optional cross-host prefix
store (``repro.serving.prefix_store``).  It never touches request
lifecycle (the scheduler's job) or device dispatch (the executor's).

Contracts with the other layers:

- the executor reads :attr:`cache`, passes it to its jitted dispatches
  and writes the returned pytree back; :meth:`push_table` must be
  called before any dispatch so the device page table matches the host
  shadow;
- :meth:`ensure_pages` is called ahead of every dispatch that will
  write a row's positions.  It allocates pages (allocate-on-write),
  privatizes shared pages in the write range (copy-on-write) and, on
  pool exhaustion, recovers by LRU prefix eviction then — through the
  scheduler-provided :attr:`preempt_for` callback — youngest-slot
  preemption.  ``False`` means the row itself was preempted and must be
  dropped from the dispatch;
- at admission the scheduler calls :meth:`stitch_prefix`; when a
  prompt becomes fully resident the executor calls
  :meth:`prefix_insert`.  Both are no-ops without the radix cache.

Allocator invariants (exercised by ``tests/test_serving_layers.py``
under randomized interleaving): a page's refcount equals the number of
slot tables mapping it plus one if the radix cache indexes it; a page
returns to the free list exactly at refcount zero; two unrelated slots
never map the same page (sharers always stitched byte-identical chunk
content); after a full drain ``pages_in_use`` equals the pages the
radix cache retains, each at refcount 1.
"""

from __future__ import annotations

import logging
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.serving.prefix_cache import PrefixCache
from repro.serving.prefix_store import PrefixStore
from repro.serving.types import EngineStats, Slot

_LOG = logging.getLogger(__name__)


class KVCacheManager:
    def __init__(
        self,
        model,
        *,
        max_batch: int,
        max_len: int,
        stats: EngineStats,
        cache_mode: str = "dense",
        page_size: int = 16,
        total_pages: Optional[int] = None,
        prefix_cache: bool = True,
        prefix_match: str = "token",
        prefix_store: Optional[PrefixStore] = None,
    ):
        if prefix_match not in ("token", "page"):
            raise ValueError(
                f"prefix_match must be token|page, got {prefix_match!r}"
            )
        self.model = model
        self.max_batch = max_batch
        self.max_len = max_len
        self.stats = stats
        self.cache_mode = cache_mode
        self.page_size = int(page_size)
        # "token" (default) additionally reuses the longest common token
        # prefix inside the first divergent page via a CoW copy of the
        # partially-matched page; "page" restores page-aligned matching
        self.prefix_match = prefix_match
        self.store = prefix_store if cache_mode == "paged" else None
        # background publish worker (created on first use): the tick loop
        # snapshots page bytes + increments counters synchronously, but
        # the object-store write itself happens off-thread.  flush_store()
        # is the drain seam (engine drain / lease end / teardown)
        self._publisher = None
        # chunk keys this engine has already published or seen present:
        # stops every later request sharing the prefix from re-paying a
        # store round-trip per chunk in prefix_insert
        self._published: set = set()
        # wired by the engine to RequestScheduler.preempt_for /
        # RequestScheduler.preempt: pool-pressure recovery crosses the
        # layer seam exactly here.  preempt_for never victimizes the
        # requester; when it answers YIELD the requester is requeued via
        # preempt_row — at a clean seam AFTER the allocation loop
        # unwinds, never mid-allocation
        self.preempt_for: Callable[[int], Optional[int]] = lambda row: None
        self.preempt_row: Callable[[int], None] = lambda row: None
        if cache_mode == "paged":
            self.pages_per_slot = -(-max_len // self.page_size)
            self.prefix = PrefixCache(self.page_size) if prefix_cache else None
            self._adaptive = not total_pages
            if total_pages:
                self._init_paged_pool(int(total_pages), queue_depth=0)
            else:
                # sized adaptively from queue depth at first submit (and
                # grown, up to the dense reservation, on later submits)
                self.n_pages: Optional[int] = None
                self.cache = None
        else:
            self.prefix = None
            self.cache = model.init_cache(max_batch, max_len)

    def cache_is_rolling(self) -> bool:
        """Sliding-window KV caches wrap writes mod t; right-padded prefill
        chunks could then alias still-visible slots — decode-path ingest.
        (Paged caches are never rolling; an adaptively-sized pool may not
        exist yet, which is fine for this check.)"""
        k = self.cache.get("k") if isinstance(self.cache, dict) else None
        return k is not None and k.shape[2] < self.max_len

    # ------------------------------------------------------------ pool setup
    def _init_paged_pool(self, total_pages: Optional[int], queue_depth: int,
                         pending: Optional[list] = None) -> None:
        """Create the device page pool and the host-side allocator state.

        ``total_pages=None`` sizes the pool adaptively from the queue at
        first submit: enough pages for the ``min(max_batch, queue depth)``
        largest queued requests (prompt + new-token budget, in whole
        pages) plus one request's worth of headroom for retained cached
        prefixes, clamped between one request and the dense reservation.
        """
        dense_pages = self.max_batch * self.pages_per_slot
        if total_pages is None:
            total_pages = self._adaptive_pages(pending or [])
            _LOG.info(
                "paged pool sized adaptively: %d pages of %d tokens "
                "(queue depth %d, max_batch %d, dense reservation %d pages)",
                total_pages, self.page_size, queue_depth, self.max_batch,
                dense_pages,
            )
        self.n_pages = int(total_pages)
        self.cache = self.model.init_cache(
            self.max_batch, self.max_len,
            paged=True, page_size=self.page_size, n_pages=self.n_pages,
        )
        # host-side allocator: free list + per-page refcounts + per-slot
        # page lists + the numpy shadow of the device page table (OOB
        # sentinel = unbacked)
        self._free_pages = list(range(self.n_pages))
        self._page_refs = [0] * self.n_pages
        self._slot_pages: List[List[int]] = [[] for _ in range(self.max_batch)]
        self._table = np.full(
            (self.max_batch, self.pages_per_slot), self.n_pages, np.int32
        )
        self._table_dirty = True
        # bytes of ONE page across every layer and pool leaf (k+v, or
        # the MLA latent pool) — peak_cache_bytes = peak_pages * this
        self.stats.page_bytes = sum(
            leaf.size * leaf.dtype.itemsize // self.n_pages
            for name, leaf in self.cache.items()
            if name.endswith("_pages")
        )
        self.stats.dense_cache_bytes = dense_pages * self.stats.page_bytes

    def _adaptive_pages(self, pending: list) -> int:
        """Pool size for the current queue: pages for the
        ``min(max_batch, queue depth)`` largest queued requests (prompt +
        new-token budget, whole pages) + one request of headroom for
        retained prefixes + pages already resident, clamped between one
        request and the dense reservation."""
        ps = self.page_size
        dense_pages = self.max_batch * self.pages_per_slot
        demands = [
            min(self.pages_per_slot, -(-(len(r.prompt) + r.max_new_tokens) // ps))
            for r in pending
        ] or [self.pages_per_slot]
        per_req = max(demands)
        conc = max(1, min(self.max_batch, len(pending)))
        want = sum(sorted(demands)[-conc:]) + per_req + self.stats.pages_in_use
        return max(per_req, min(dense_pages, want))

    def on_submit(self, pending: list) -> None:
        """Adaptive pool sizing, deferred to first (non-empty) submit so
        the queue depth is known; later submits can only GROW the pool,
        up to the dense reservation — never strand a bigger-than-pool
        request."""
        if self.cache_mode != "paged" or not self._adaptive or not pending:
            return
        if self.cache is None:
            self._init_paged_pool(None, len(pending), pending)
            return
        want = self._adaptive_pages(pending)
        if want > self.n_pages:
            # geometric step (>= 1.5x) so a stream of growing jobs
            # pays O(log) recompiles, not one per submit
            dense_pages = self.max_batch * self.pages_per_slot
            self._grow_pool(
                min(dense_pages,
                    max(want, self.n_pages + -(-self.n_pages // 2))),
                len(pending),
            )

    def _grow_pool(self, new_n: int, queue_depth: int) -> None:
        """Extend an adaptively-sized pool in place (later submits may
        queue larger requests than the first sizing saw).  Existing pages
        keep their ids; the OOB sentinel moves from old to new ``n_pages``
        in the table shadow and is re-pushed before the next dispatch.
        Growing changes the pool leaves' shapes, so the next dispatch
        retraces the jitted step — the submit path grows in geometric
        steps to bound how often that compile cliff is paid."""
        import jax.numpy as jnp

        old = self.n_pages
        for name, leaf in self.cache.items():
            if name.endswith("_pages"):
                pad = jnp.zeros(
                    leaf.shape[:1] + (new_n - old,) + leaf.shape[2:], leaf.dtype
                )
                self.cache[name] = jnp.concatenate([leaf, pad], axis=1)
        self.n_pages = new_n
        self._free_pages.extend(range(old, new_n))
        self._page_refs.extend([0] * (new_n - old))
        self._table[self._table == old] = new_n
        self._table_dirty = True
        _LOG.info(
            "paged pool grown adaptively: %d -> %d pages (queue depth %d)",
            old, new_n, queue_depth,
        )

    # ------------------------------------------------------- page allocator
    @property
    def peak_cache_bytes(self) -> int:
        """High-water cache footprint: pages actually resident (paged) or
        the full dense reservation."""
        if self.cache_mode != "paged":
            return sum(
                leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(self.cache)
            )
        return self.stats.peak_pages * self.stats.page_bytes

    def _incref(self, pid: int) -> None:
        """Add a reference (stitch / cache adoption), tracking the shared
        high-water mark at the 1 -> 2 transition."""
        self._page_refs[pid] += 1
        if self._page_refs[pid] == 2:
            self.stats._shared_pages += 1
            if self.stats._shared_pages > self.stats.pages_shared_peak:
                self.stats.pages_shared_peak = self.stats._shared_pages

    def _decref(self, pid: int) -> None:
        """Drop one reference; the page returns to the free list only when
        its last holder (slot or prefix cache) lets go."""
        self._page_refs[pid] -= 1
        if self._page_refs[pid] < 0:  # allocator invariant
            raise AssertionError(f"page {pid} refcount went negative")
        if self._page_refs[pid] == 1:
            self.stats._shared_pages -= 1
        elif self._page_refs[pid] == 0:
            self._free_pages.append(pid)  # LIFO: reuse hot pages
            self.stats.pages_in_use -= 1

    def _take_free_page(self) -> Optional[int]:
        """Pop a free page (refcount 1) WITHOUT recovery and WITHOUT peak
        tracking — callers record the high-water mark once their batch
        of allocations settles (a CoW transiently holds old + new page
        before the decref, which must not inflate the peak).  None when
        the free list is empty."""
        if not self._free_pages:
            return None
        pid = self._free_pages.pop()
        self._page_refs[pid] = 1
        self.stats.pages_in_use += 1
        self.stats.page_allocs += 1
        return pid

    def _alloc_page(self, row: int) -> Optional[int]:
        """Claim a free page for ``row`` (refcount 1).

        On exhaustion, recover in escalating order: evict LRU cached
        prefixes nobody maps, then ask the scheduler (``preempt_for``)
        to preempt the youngest active slot strictly younger than the
        requester — the scheduler never victimizes the requester itself
        mid-allocation.  A ``YIELD`` answer (the requester is the
        youngest; age priority says it is the one that must go) returns
        ``None``: the caller unwinds its allocation loop and requeues
        the row through :meth:`_yield_row`.  The ``victim == row`` guard
        is defensive against foreign ``preempt_for`` implementations.
        Raises only when a lone request cannot fit in the entire pool.
        """
        while not self._free_pages:
            if self.prefix is not None:
                evicted = self.prefix.evict(1, lambda p: self._page_refs[p])
                if evicted:
                    for pid in evicted:
                        self._decref(pid)  # cache ownership -> free list
                    self.stats.prefix_evictions += len(evicted)
                    continue
            victim = self.preempt_for(row)
            if victim is None:
                raise RuntimeError(
                    f"paged KV pool exhausted ({self.n_pages} pages of "
                    f"{self.page_size} tokens) with nothing evictable or "
                    "preemptable; raise total_pages or lower request length"
                )
            if victim < 0 or victim == row:
                return None  # requester must yield (see _yield_row)
        return self._take_free_page()  # non-None: the loop freed a page

    def _copy_page(self, src: int, dst: int) -> None:
        """Copy-on-write: duplicate one physical page across every layer
        and pool leaf (one device op per leaf, outside the jitted step)."""
        for name, leaf in self.cache.items():
            if name.endswith("_pages"):
                self.cache[name] = leaf.at[:, dst].set(leaf[:, src])

    def ensure_pages(
        self, row: int, n_tokens: int, write_start: Optional[int] = None
    ) -> bool:
        """Back row ``row``'s first ``n_tokens`` positions with physical
        pages (allocate-on-write, called ahead of every dispatch that will
        write those positions).

        ``write_start`` marks the first position the coming dispatch will
        write: any page in the write range that another holder (a sharing
        slot or the prefix cache) still references is copied to a private
        page first, so shared pages are immutable once published.  Returns
        False when the row could not be backed and was yielded back to
        the queue (or preempted by another row's recovery); the caller
        must drop it from this tick's dispatch.
        """
        need = -(-n_tokens // self.page_size)
        if need > self.pages_per_slot:
            raise ValueError(
                f"request needs {n_tokens} cache positions but max_len="
                f"{self.max_len} caps a slot at {self.pages_per_slot} pages "
                f"of {self.page_size} tokens"
            )
        pages = self._slot_pages[row]
        shortfall = (need - len(pages)) - len(self._free_pages)
        if write_start is not None:
            # the CoW pass below will also allocate one page per shared
            # page in the write range — count those into the bulk reclaim
            shortfall += sum(
                1
                for j in range(min(write_start // self.page_size, len(pages)),
                               min(need, len(pages)))
                if self._page_refs[pages[j]] > 1
            )
        if shortfall > 0 and self.prefix is not None:
            # bulk pre-eviction: reclaim the whole shortfall in one radix
            # pass instead of one tree walk per page inside _alloc_page
            evicted = self.prefix.evict(shortfall, lambda p: self._page_refs[p])
            for pid in evicted:
                self._decref(pid)
            self.stats.prefix_evictions += len(evicted)
        while len(pages) < need:
            pid = self._alloc_page(row)
            if pid is None:
                return self._yield_row(row)
            self._table[row, len(pages)] = pid
            pages.append(pid)
            self._table_dirty = True
        if write_start is not None:
            for j in range(write_start // self.page_size, need):
                old = pages[j]
                if self._page_refs[old] > 1:  # shared: copy before write
                    new = self._alloc_page(row)
                    if new is None:
                        return self._yield_row(row)
                    self._copy_page(old, new)
                    self._decref(old)  # still >= 1: another slot / the cache
                    pages[j] = new
                    self._table[row, j] = new
                    self._table_dirty = True
                    self.stats.cow_copies += 1
        if self.stats.pages_in_use > self.stats.peak_pages:
            self.stats.peak_pages = self.stats.pages_in_use
        return True

    def reserve_speculative(
        self, row: int, base_tokens: int, want_tokens: int,
        write_start: Optional[int] = None,
    ) -> Optional[int]:
        """Back ``base_tokens`` positions with full :meth:`ensure_pages`
        semantics (eviction -> preemption -> yield: this is what the
        non-speculative dispatch would have demanded), then extend the
        backing toward ``want_tokens`` *best-effort* — free pages and
        prefix eviction only.  Draft positions are optional, so their
        pages must never preempt another slot or raise pool exhaustion:
        a speculative engine must run every workload the non-speculative
        engine runs, just with fewer drafts under pressure.

        Returns the number of positions backed (``>= base_tokens``), or
        ``None`` when the row could not get even its base demand and was
        yielded/preempted — the caller drops it from this dispatch.
        """
        if not self.ensure_pages(row, base_tokens, write_start=write_start):
            return None
        pages = self._slot_pages[row]
        want = min(-(-want_tokens // self.page_size), self.pages_per_slot)
        while len(pages) < want:
            pid = self._take_free_page()
            if pid is None and self.prefix is not None:
                evicted = self.prefix.evict(1, lambda p: self._page_refs[p])
                for e in evicted:
                    self._decref(e)
                self.stats.prefix_evictions += len(evicted)
                pid = self._take_free_page()
            if pid is None:
                break
            self._table[row, len(pages)] = pid
            pages.append(pid)
            self._table_dirty = True
        # a trailing page the row holds but *shares* caps the drafts at
        # its boundary: writing it would force a CoW copy, which drafts
        # aren't worth (cannot happen today — rewind decrefs trailing
        # pages and stitched pages sit below the frontier — but cheap)
        backed = len(pages)
        base_need = -(-base_tokens // self.page_size)
        for j in range(base_need, len(pages)):
            if self._page_refs[pages[j]] > 1:
                backed = j
                break
        if self.stats.pages_in_use > self.stats.peak_pages:
            self.stats.peak_pages = self.stats.pages_in_use
        return max(base_tokens, backed * self.page_size)

    def _yield_row(self, row: int) -> bool:
        """The requester is the youngest active slot and nothing could be
        freed for it: age priority says IT yields.  The yield happens
        here — after the allocation/CoW loop has fully unwound — never
        inside ``_alloc_page`` mid-loop (the old bug: ``preempt_for``
        could select the requesting row as victim mid-allocation and
        hand its own freshly-released row back to the allocator).  The
        scheduler requeues the request at the queue front and rolls its
        counters back; the deterministic per-request sampling streams
        make the rerun byte-identical.  Always returns False (the
        caller's drop-this-row signal).  The engine's wiring skips the
        requeue when the slot is already empty (a foreign ``preempt_for``
        policy preempted it directly)."""
        self.preempt_row(row)
        return False

    def can_admit(self) -> bool:
        """Admission control under pool pressure (consulted by the
        scheduler's refill): a request admitted into a pool with neither
        a free page nor an LRU-evictable cached page can only yield
        straight back to the queue on its first allocation (it is the
        youngest slot, so preemption is not available to it) — a pure
        admit/rollback churn cycle.  Holding the queue until a page
        exists lets the active slots run to completion and open the
        gate.  (When nothing is active every pool page is free or an
        evictable cached leaf, so the gate can never deadlock.)"""
        if self.cache_mode != "paged" or self.cache is None:
            return True
        if self._free_pages:
            return True
        return self.prefix is not None and self.prefix.evictable(
            lambda p: self._page_refs[p]
        )

    def release_slot(self, row: int) -> None:
        """Drop the slot's references (free-on-finish for private pages;
        shared/cached pages stay resident) and reset its table row to the
        OOB sentinel so stale writes become no-ops."""
        if self.cache_mode != "paged" or self.cache is None:
            return
        pages = self._slot_pages[row]
        if not pages:
            return
        for pid in reversed(pages):
            self._decref(pid)
        self._slot_pages[row] = []
        self._table[row, :] = self.n_pages
        self._table_dirty = True

    def rewind_slot(self, row: int, n_tokens: int) -> None:
        """Speculative rollback: shrink row ``row``'s backing to its first
        ``n_tokens`` positions after a verify dispatch accepted fewer
        tokens than were written.

        Only whole pages past the new frontier are dropped (decref — a
        page CoW-privatized for the dispatch goes straight back to the
        free list at refcount 0; the sentinel makes any stale in-flight
        write a device no-op).  Rejected tokens inside the kept tail page
        need no touch-up: they sit at positions >= the slot's rewound
        ``pos``, past every future query under the causal mask, and the
        next dispatch's ``write_start`` overwrites them — the same
        stale-past-the-frontier argument :meth:`reset_row` relies on.
        The page holding position ``n_tokens - 1`` is never shared at
        this point (ensure_pages privatized every page in the verify
        write range), so the accepted prefix cannot be aliased away."""
        if self.cache_mode != "paged" or self.cache is None:
            return
        keep = -(-n_tokens // self.page_size)
        pages = self._slot_pages[row]
        while len(pages) > keep:
            pid = pages.pop()
            self._table[row, len(pages)] = self.n_pages
            self._table_dirty = True
            self._decref(pid)

    def reset_row(self, row: int) -> None:
        """Prepare a row for a fresh admission.  Dense mode zeroes the
        row; paged mode has nothing to do (the row's pages went back to
        the free list at finish, its table row is the OOB sentinel, and
        stale data inside a re-issued page sits past the new owner's
        write frontier where the causal mask excludes it)."""
        if self.cache_mode == "paged":
            return
        import jax.numpy as jnp

        def zero_row(x):
            if x.ndim >= 2 and x.shape[1] == self.max_batch:
                return x.at[:, row].set(jnp.zeros_like(x[:, row]))
            return x

        self.cache = jax.tree.map(zero_row, self.cache)

    # --------------------------------------------------------- prefix cache
    def stitch_prefix(self, row: int, slot: Slot) -> None:
        """Admission-time prefix reuse (see :meth:`_stitch`).  Handoff
        admissions (``slot.req.handoff``) run the *demand-driven*
        hydration variant — the chain pages are expected to exist, so
        the walk may evict unpinned cached prefixes for room — and are
        accounted here: one ``hydration_ticks`` sample (store
        round-trips this admission made) and a ``handoff_fallbacks``
        count when the store could not cover the prompt down to the
        held-back frontier token (the remainder replays through the
        PR 8 ladder, byte-identical)."""
        demand = (
            bool(getattr(slot.req, "handoff", False))
            and self.store is not None
        )
        if not demand:
            self._stitch(row, slot, False)
            return
        ops0 = self.store.fetch_ops
        self._stitch(row, slot, True)
        self.stats._hydration_ticks.append(self.store.fetch_ops - ops0)
        # a guaranteed hit leaves exactly the held-back frontier token
        # to re-dispatch; anything longer means the store lied
        if len(slot.remaining_prompt) > 1:
            self.stats.handoff_fallbacks += 1
        self._sync_store_stats()

    def _stitch(self, row: int, slot: Slot, demand: bool) -> None:
        """Admission-time prefix reuse: map the longest cached prefix of
        the new request's prompt straight into its page table and skip
        prefill for those tokens.  With a cross-host store attached, a
        local radix miss first tries to hydrate pages other workers
        published.  At least one prompt token is always held back and
        re-dispatched — its logits seed generation — so a full-prompt
        hit re-writes one position inside the last shared page, which
        copy-on-write then privatizes.

        With ``prefix_match="token"`` (the default) matching does not
        stop at the last whole page: the longest common *token* prefix
        inside the first divergent page is reused too, by copying the
        partially-matched page into a slot-private page (the donor's
        divergent tail is overwritten when prefill resumes from the
        mid-page offset; until then it sits past the slot's write
        frontier where the causal mask excludes it).  The copy is
        best-effort: it only consumes a free page (or one LRU-evictable
        cached page), never preempts — a miss just falls back to the
        page-aligned stitch."""
        if self.prefix is None:
            return
        prompt = slot.req.prompt

        def lookup():
            # page mode must not even SCAN for a partial sibling: the
            # scan refreshes its LRU stamp, which would perturb the
            # page-aligned baseline's eviction order
            if self.prefix_match == "token":
                return self.prefix.match_partial(prompt)
            return self.prefix.match(prompt), None, 0

        path, pnode, plen = lookup()
        if self.store is not None:
            n_chunks = min(len(prompt) // self.page_size, self.pages_per_slot)
            hydrate = self._hydrate_demand if demand else self._hydrate
            if len(path) < n_chunks and hydrate(
                prompt, [n.page for n in path], n_chunks
            ):
                # now extended locally (possibly exposing a new partial)
                path, pnode, plen = lookup()
        path = path[: self.pages_per_slot]
        matched = len(path) * self.page_size
        eff = min(matched, len(prompt) - 1)
        # sub-page candidate: tokens reusable inside the first divergent
        # page, capped by the hold-back (>= 1 token must be re-dispatched)
        # and dropped when the slot's table has no room for the CoW page
        partial = 0
        if (
            self.prefix_match == "token"
            and pnode is not None
            and len(path) < self.pages_per_slot
        ):
            partial = min(plen, len(prompt) - 1 - matched)
        # extended-key tail hydration: when the full-chunk match leaves a
        # sub-page remainder, another worker may have published exactly
        # that tail page under the extended content key (a drained slot's
        # generation checkpoint does — see publish_generation).  Fetching
        # it into a slot-private page turns a resume's whole frontier
        # into a hit; the hold-back still re-dispatches one token, whose
        # idempotent write lands inside the private page.  Best-effort:
        # free-list only, and skipped when a local partial sibling would
        # cover at least as many tokens
        tail_pid = None
        rem = list(prompt[matched:])
        if (
            self.store is not None
            and self.prefix_match == "token"
            and len(path) < self.pages_per_slot
            and 0 < len(rem) < self.page_size
            and len(rem) - 1 > partial
        ):
            parent = (
                self._chunk_keys(prompt, len(path))[-1]
                if path else self.store.root_key()
            )
            tkey = self.store.child_key(parent, rem)
            arrays = self.store.fetch(tkey, self._page_like())
            self._sync_store_stats()
            if arrays is not None:
                pid = self._take_free_page()
                if pid is None and demand and self.prefix is not None:
                    # demand hydration may make room; pin the matched
                    # path with transient raw refcount bumps so the
                    # eviction pass cannot reclaim the chain mid-stitch
                    for n in path:
                        self._page_refs[n.page] += 1
                    try:
                        evicted = self.prefix.evict(
                            1, lambda p: self._page_refs[p]
                        )
                        for e in evicted:
                            self._decref(e)
                        self.stats.prefix_evictions += len(evicted)
                    finally:
                        for n in path:
                            self._page_refs[n.page] -= 1
                    pid = self._take_free_page()
                if pid is not None:
                    for name, arr in arrays.items():
                        self.cache[name] = self.cache[name].at[:, pid].set(arr)
                    tail_pid = pid
                    partial = len(rem) - 1
                    self._published.add(tkey)
                    self.stats.prefix_store_pages_hydrated += 1
                    self.stats.prefix_store_tokens_hydrated += partial
        if eff <= 0 and partial <= 0:
            return
        pages = self._slot_pages[row]
        for j, node in enumerate(path):
            self._incref(node.page)
            self._table[row, j] = node.page
            pages.append(node.page)
        if partial > 0:
            if tail_pid is not None:
                pid = tail_pid
            else:
                pid = self._cow_partial(pnode.page, row)
                if pid is not None:
                    self.stats.cow_partial_stitches += 1
            if pid is None:
                partial = 0  # no page to copy into: page-aligned fallback
            else:
                self._table[row, len(path)] = pid
                pages.append(pid)
                eff += partial
                slot.hit_tokens_partial = partial
                self.stats.prefix_hit_tokens_partial += partial
                if self.stats.pages_in_use > self.stats.peak_pages:
                    self.stats.peak_pages = self.stats.pages_in_use
        if eff <= 0:
            return
        self._table_dirty = True
        slot.pos = eff
        slot.remaining_prompt = list(prompt[eff:])
        slot.hit_tokens = matched
        slot.skipped_tokens = eff
        self.stats.prefix_hit_tokens += matched
        self.stats.prompt_tokens_skipped += eff

    def _cow_partial(self, src: int, row: int) -> Optional[int]:
        """Copy the partially-matched page ``src`` into a fresh private
        page for ``row`` (refcount 1).  Best-effort: tries the free list,
        then one LRU prefix eviction — never preemption (the caller is
        mid-admission).  ``src`` is pinned by a transient raw refcount
        bump (not :meth:`_incref`: the pin is not real sharing and must
        not touch ``pages_shared_peak``) so the eviction pass cannot
        reclaim the very page being copied."""
        self._page_refs[src] += 1
        try:
            pid = self._take_free_page()
            if pid is None and self.prefix is not None:
                evicted = self.prefix.evict(1, lambda p: self._page_refs[p])
                for e in evicted:
                    self._decref(e)
                self.stats.prefix_evictions += len(evicted)
                pid = self._take_free_page()
            if pid is not None:
                self._copy_page(src, pid)
            return pid
        finally:
            self._page_refs[src] -= 1

    def prefix_insert(self, row: int, prompt: List[int]) -> None:
        """Publish a freshly-ingested prompt's full pages to the radix
        cache (called the moment the prompt is fully resident, before the
        row can finish and release them).  Chunks already cached keep the
        cache's page; only newly adopted pages gain the cache's ref.
        With a cross-host store attached, the full chunks are also
        published under their chained content hashes."""
        if self.prefix is None:
            return
        n_full = min(len(prompt) // self.page_size, len(self._slot_pages[row]))
        if n_full == 0:
            return
        pages = self._slot_pages[row][:n_full]
        adopted = self.prefix.insert(prompt, pages)
        for pid in adopted:
            self._incref(pid)
        if self.store is not None:
            self._publish(prompt, pages, n_full)

    # ----------------------------------------------- cross-host prefix store
    def _pool_leaves(self) -> Dict[str, object]:
        return {
            name: leaf for name, leaf in self.cache.items()
            if name.endswith("_pages")
        }

    def _page_arrays(self, pid: int) -> Dict[str, np.ndarray]:
        """One page's slice of every pool leaf, pulled to host."""
        return {name: np.asarray(leaf[:, pid]) for name, leaf in self._pool_leaves().items()}

    def _page_like(self) -> Dict[str, np.ndarray]:
        """Shape/dtype template a fetched page must match exactly."""
        return {
            name: np.empty(leaf.shape[:1] + leaf.shape[2:], leaf.dtype)
            for name, leaf in self._pool_leaves().items()
        }

    def _chunk_keys(self, prompt: List[int], n_chunks: int) -> List[str]:
        """Chained content keys for the first ``n_chunks`` full chunks."""
        ps = self.page_size
        keys, key = [], self.store.root_key()
        for j in range(n_chunks):
            key = self.store.child_key(key, prompt[j * ps:(j + 1) * ps])
            keys.append(key)
        return keys

    def _publish(self, prompt: List[int], pages: List[int], n_full: int) -> None:
        if len(self._published) > 100_000:
            # the memo only saves round-trips; resetting it is always
            # safe and bounds a long-lived engine on diverse traffic
            self._published.clear()
        for j, key in enumerate(self._chunk_keys(prompt, n_full)):
            if key in self._published:
                continue
            if not self.store.exists(key):
                # one existence probe, then an unconditional write: the
                # device->host page pull is deferred behind the probe,
                # and a concurrent publisher writing the same key is a
                # benign last-writer-wins race over identical bytes.
                # The pull happens at submit time (the pool page may be
                # evicted and reissued before the write lands) — passed
                # as a thunk so a submit the publisher dedups (the key
                # already pending in its queue) skips the device->host
                # pull and the pack entirely.  Serialization + the store
                # write run on the background publisher thread; counters
                # and the memo stay synchronous/deterministic
                if self._publisher is None:
                    self._publisher = self.store.publisher()
                self._publisher.submit(
                    key, lambda pid=pages[j]: self._page_arrays(pid)
                )
                self.stats.prefix_store_pages_published += 1
            self._published.add(key)

    def publish_generation(self, row: int, tokens: List[int]) -> int:
        """Publish a drained slot's resident KV — full chunks under the
        usual chained keys PLUS the sub-page tail under an extended
        content key — so a resuming worker gets a guaranteed prefix hit
        over ``tokens`` (the request's prompt + already-generated output
        minus the frontier token).  Today's page-quantized publish drops
        the partial last page; work-preserving recovery is exactly the
        case where that tail holds the paid-for decode work.  Returns
        the number of pages newly submitted for publication."""
        if self.store is None or self.cache_mode != "paged" or self.cache is None:
            return 0
        ps = self.page_size
        pages = self._slot_pages[row]
        before = self.stats.prefix_store_pages_published
        n_full = min(len(tokens) // ps, len(pages))
        if n_full:
            self._publish(tokens, pages[:n_full], n_full)
        tail = tokens[n_full * ps:]
        if tail and n_full < len(pages):
            parent = (
                self._chunk_keys(tokens, n_full)[-1]
                if n_full else self.store.root_key()
            )
            tkey = self.store.child_key(parent, tail)
            if tkey not in self._published and not self.store.exists(tkey):
                # the tail blob carries the whole physical page; rows past
                # the tail frontier are garbage, but a hydrating reader
                # never attends past the frontier (causal mask) and the
                # hold-back re-dispatch overwrites the frontier position
                if self._publisher is None:
                    self._publisher = self.store.publisher()
                self._publisher.submit(
                    tkey, lambda pid=pages[n_full]: self._page_arrays(pid)
                )
                self.stats.prefix_store_pages_published += 1
            self._published.add(tkey)
        return self.stats.prefix_store_pages_published - before

    def chain_keys_for(self, tokens: List[int]) -> List[str]:
        """Content keys covering ``tokens``: every full chunk plus, when
        a sub-page remainder exists, its extended tail key — the exact
        set a handoff's demand hydration will fetch.  The prefill lease
        pins these against the TTL sweep before enqueueing a handoff."""
        if self.store is None:
            return []
        n_full = len(tokens) // self.page_size
        keys = self._chunk_keys(tokens, n_full)
        tail = tokens[n_full * self.page_size:]
        if tail:
            parent = keys[-1] if n_full else self.store.root_key()
            keys.append(self.store.child_key(parent, tail))
        return keys

    def ensure_chain_published(self, row: int, tokens: List[int]) -> List[str]:
        """Defensively re-probe and (re)submit every chain page covering
        ``tokens`` while row ``row`` still holds them, bypassing the
        ``_published`` memo — the memo means "submitted", not "durable",
        and a handoff points other workers at these exact keys.  A key
        whose queued write has not landed yet probes as absent and is
        resubmitted; the publisher's pending-set dedup then drops the
        duplicate before any snapshot/pack work (``publish_dedup_hits``).
        Returns the chain keys (full chunks + tail)."""
        if self.store is None or self.cache_mode != "paged" or self.cache is None:
            return []
        ps = self.page_size
        pages = self._slot_pages[row]
        n_full = min(len(tokens) // ps, len(pages))
        keys = self._chunk_keys(tokens, n_full)
        if self._publisher is None:
            self._publisher = self.store.publisher()
        for j, key in enumerate(keys):
            if not self.store.exists(key):
                self._publisher.submit(
                    key, lambda pid=pages[j]: self._page_arrays(pid)
                )
        tail = tokens[n_full * ps:]
        if tail and n_full < len(pages):
            parent = keys[-1] if n_full else self.store.root_key()
            tkey = self.store.child_key(parent, tail)
            keys.append(tkey)
            if not self.store.exists(tkey):
                self._publisher.submit(
                    tkey, lambda pid=pages[n_full]: self._page_arrays(pid)
                )
        self._sync_store_stats()
        return keys

    def _sync_store_stats(self) -> None:
        """Mirror the store/publisher-owned hardening counters into the
        shared stats block (they live on PrefixStore/AsyncPublisher so
        the store path has no stats dependency)."""
        if self.store is not None:
            self.stats.prefix_store_hash_mismatches = self.store.hash_mismatches
            self.stats.hydration_fetch_ops = self.store.fetch_ops
            self.stats.prefix_store_bytes_fetched = self.store.bytes_fetched
        if self._publisher is not None:
            self.stats.publish_retries = self._publisher.retries
            self.stats.publish_dedup_hits = self._publisher.dedup_hits

    def flush_store(self) -> None:
        """Drain the background publish queue (no-op without a store or
        before the first publish).  Called at the engine's natural drain
        seams so published pages are durable before counters are compared
        or the process exits."""
        if self._publisher is not None:
            self._publisher.flush()
        self._sync_store_stats()

    # ------------------------------------------------------------ debugging
    def check_invariants(self) -> None:
        """Assert the allocator's structural invariants (enabled after
        every engine tick under ``DS_DEBUG_INVARIANTS=1``):

        - every page's refcount equals its holder count — slot tables
          mapping it plus one if the radix cache indexes it (this also
          rules out unshared cross-slot aliasing: two slots on one page
          forces refcount >= 2);
        - no slot maps the same physical page twice;
        - the free list is duplicate-free, exactly the refcount-0 pages;
        - the table shadow mirrors the slot page lists (OOB sentinel
          past each slot's backing);
        - ``pages_in_use`` equals pool size minus free pages.

        Raises AssertionError with the failing page/slot on violation."""
        if self.cache_mode != "paged" or self.cache is None:
            return
        holders = [0] * self.n_pages
        for row, pages in enumerate(self._slot_pages):
            if len(set(pages)) != len(pages):
                raise AssertionError(
                    f"slot {row} maps a physical page twice: {pages}"
                )
            for j, pid in enumerate(pages):
                holders[pid] += 1
                if self._table[row, j] != pid:
                    raise AssertionError(
                        f"table shadow desync at slot {row} page {j}: "
                        f"table={self._table[row, j]} list={pid}"
                    )
            if not np.all(self._table[row, len(pages):] == self.n_pages):
                raise AssertionError(
                    f"slot {row}: table rows past its {len(pages)}-page "
                    "backing are not the OOB sentinel"
                )
        cached = set(self.prefix.pages()) if self.prefix is not None else set()
        for pid in range(self.n_pages):
            expect = holders[pid] + (1 if pid in cached else 0)
            if self._page_refs[pid] != expect:
                raise AssertionError(
                    f"page {pid}: refcount {self._page_refs[pid]} != "
                    f"{holders[pid]} slot holder(s)"
                    f"{' + 1 cache ref' if pid in cached else ''}"
                )
        free = self._free_pages
        if len(set(free)) != len(free):
            raise AssertionError("free list contains duplicates")
        for pid in free:
            if self._page_refs[pid] != 0:
                raise AssertionError(
                    f"free page {pid} has refcount {self._page_refs[pid]}"
                )
        zero = sum(1 for r in self._page_refs if r == 0)
        if zero != len(free):
            raise AssertionError(
                f"{zero} refcount-0 pages but {len(free)} on the free list"
            )
        if self.stats.pages_in_use != self.n_pages - len(free):
            raise AssertionError(
                f"pages_in_use={self.stats.pages_in_use} != "
                f"{self.n_pages - len(free)} resident pages"
            )

    def _hydrate(
        self, prompt: List[int], pages_so_far: List[int], n_chunks: int
    ) -> int:
        """Extend the local radix path for ``prompt`` (already covering
        ``pages_so_far`` chunks) from the cross-host store: fetch chunk
        pages other workers published, copy them into freshly allocated
        pool pages and index them, so the stitch that follows hits
        locally.  Hydration is best-effort and deliberately
        side-effect-free on other slots: it only consumes already-free
        pages (never evicts or preempts) and stops at the first miss or
        when the free list runs dry.  Returns the number of pages
        hydrated."""
        ps = self.page_size
        keys = self._chunk_keys(prompt, n_chunks)
        like = self._page_like()
        pages_so_far = list(pages_so_far)
        hydrated = 0
        for j in range(len(pages_so_far), n_chunks):
            arrays = self.store.fetch(keys[j], like)
            if arrays is None:
                break
            self._published.add(keys[j])  # a fetched page is in the store
            pid = self._take_free_page()
            if pid is None:
                break
            for name, arr in arrays.items():
                self.cache[name] = self.cache[name].at[:, pid].set(arr)
            pages_so_far.append(pid)
            hydrated += 1
        if hydrated:
            # the allocation above IS the cache's refcount on each
            # hydrated page (insert adopts them; nothing further to
            # incref)
            self.prefix.insert(prompt[: len(pages_so_far) * ps], pages_so_far)
            self.stats.prefix_store_pages_hydrated += hydrated
            self.stats.prefix_store_tokens_hydrated += hydrated * ps
            if self.stats.pages_in_use > self.stats.peak_pages:
                self.stats.peak_pages = self.stats.pages_in_use
        return hydrated

    def _hydrate_demand(
        self, prompt: List[int], pages_so_far: List[int], n_chunks: int
    ) -> int:
        """Demand-driven variant of :meth:`_hydrate` for handoff
        admissions: the chain pages are *expected* to exist (a prefill
        worker just published them and pinned them against the TTL
        sweep), so instead of stopping when the free list runs dry the
        walk may reclaim room by evicting unpinned LRU cached prefixes —
        never preempting (the caller is mid-admission).  Pages already
        matched or freshly hydrated are pinned by transient raw refcount
        bumps (the :meth:`_cow_partial` pattern) so the eviction pass
        cannot reclaim the very chain being assembled.  Stops at the
        first store miss — the caller's fallback ladder replays the
        remainder, byte-identical."""
        ps = self.page_size
        keys = self._chunk_keys(prompt, n_chunks)
        like = self._page_like()
        pages_so_far = list(pages_so_far)
        hydrated = 0
        pinned: List[int] = []
        try:
            for p in pages_so_far:
                self._page_refs[p] += 1
                pinned.append(p)
            for j in range(len(pages_so_far), n_chunks):
                arrays = self.store.fetch(keys[j], like)
                if arrays is None:
                    break
                self._published.add(keys[j])
                pid = self._take_free_page()
                if pid is None and self.prefix is not None:
                    evicted = self.prefix.evict(
                        1, lambda p: self._page_refs[p]
                    )
                    for e in evicted:
                        self._decref(e)
                    self.stats.prefix_evictions += len(evicted)
                    pid = self._take_free_page()
                if pid is None:
                    break
                for name, arr in arrays.items():
                    self.cache[name] = self.cache[name].at[:, pid].set(arr)
                pages_so_far.append(pid)
                self._page_refs[pid] += 1
                pinned.append(pid)
                hydrated += 1
        finally:
            for p in pinned:
                self._page_refs[p] -= 1
        if hydrated:
            self.prefix.insert(prompt[: len(pages_so_far) * ps], pages_so_far)
            self.stats.prefix_store_pages_hydrated += hydrated
            self.stats.prefix_store_tokens_hydrated += hydrated * ps
            if self.stats.pages_in_use > self.stats.peak_pages:
                self.stats.peak_pages = self.stats.pages_in_use
        return hydrated

    # ------------------------------------------------------------- dispatch
    def push_table(self) -> None:
        """Sync the host page table to the device cache before a dispatch."""
        if self.cache_mode == "paged" and self._table_dirty:
            import jax.numpy as jnp

            self.cache["page_table"] = jnp.asarray(self._table)
            self._table_dirty = False
